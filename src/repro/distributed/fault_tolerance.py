"""Fault tolerance & straggler mitigation (host-side control plane).

Components:
  * StepWatchdog     — thread-based hang detection with configurable
                       timeout; fires a callback (alert / abort / re-mesh)
  * StragglerMonitor — per-step wall-time EWMA + z-score outlier flags;
                       on a real cluster the flagged host triggers
                       checkpoint-and-re-mesh, here it drives tests/logs
  * FailureInjector  — deterministic fault injection for tests/drills
  * elastic_restart  — rebuild a (possibly smaller) mesh from surviving
                       devices and restore the latest checkpoint onto it;
                       works because checkpoints are stored unsharded per
                       host group and the data pipeline is (seed, step)-
                       deterministic (bit-exact resume)

The training loop (launch/train.py) wires these together: every step is
`watchdog.beat()`-ed, timed into the monitor, checkpointed every N steps,
and the whole loop is wrapped in `run_with_restarts`.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Optional

import numpy as np


class StepWatchdog:
    def __init__(self, timeout_s: float, on_timeout: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._timer: Optional[threading.Timer] = None
        self._lock = threading.Lock()
        self.fired = False

    def beat(self) -> None:
        with self._lock:
            if self._timer is not None:
                self._timer.cancel()
            self._timer = threading.Timer(self.timeout_s, self._fire)
            self._timer.daemon = True
            self._timer.start()

    def _fire(self) -> None:
        self.fired = True
        self.on_timeout()

    def stop(self) -> None:
        with self._lock:
            if self._timer is not None:
                self._timer.cancel()
                self._timer = None


@dataclass
class StragglerMonitor:
    alpha: float = 0.1            # EWMA factor
    z_threshold: float = 3.0
    warmup_steps: int = 5
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: list = field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        """Returns True if this step is a straggler outlier."""
        self.n += 1
        if self.n <= self.warmup_steps:
            self.mean = dt if self.n == 1 else \
                (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = max(self.var, (dt - self.mean) ** 2)
            return False
        std = max(np.sqrt(self.var), 1e-6, 0.05 * self.mean)
        is_outlier = (dt - self.mean) > self.z_threshold * std
        if is_outlier:
            self.flagged.append((step, dt))
        else:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * dt
            self.var = (1 - self.alpha) * self.var \
                + self.alpha * (dt - self.mean) ** 2
        return is_outlier


class FailureInjector:
    """Deterministically fail at given steps (for restart drills)."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.tripped = []

    def check(self, step: int) -> None:
        if step in self.fail_at:
            self.fail_at.discard(step)
            self.tripped.append(step)
            raise self.exc(f"injected failure at step {step}")


def run_with_restarts(run_fn: Callable[[Optional[int]], int],
                      max_restarts: int = 3,
                      on_restart: Optional[Callable[[int, Exception], None]]
                      = None) -> int:
    """run_fn(resume_step|None) -> final_step; restarts from the latest
    checkpoint on failure (the trainer reads it internally)."""
    attempts = 0
    resume = None
    while True:
        try:
            return run_fn(resume)
        except Exception as e:  # noqa: BLE001 — survive any step failure
            attempts += 1
            if attempts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(attempts, e)
            resume = -1  # sentinel: resume from latest checkpoint


def surviving_mesh(n_lost: int = 0, axis_names=("data", "model"),
                   prefer_model: int = None):
    """Elastic re-mesh: build the largest power-of-two mesh from surviving
    devices. Returns (mesh, (data, model) shape)."""
    import jax
    devs = jax.devices()
    n = len(devs) - n_lost
    # largest power of two <= n
    size = 1
    while size * 2 <= n:
        size *= 2
    model = prefer_model or min(size, 2)
    while size % model:
        model //= 2
    data = size // model
    from .compat import make_mesh
    mesh = make_mesh((data, model), axis_names,
                     devices=devs[:data * model])
    return mesh, (data, model)
