"""Gradient compression for the DP all-reduce: int8 quantization with
error feedback.

Scheme (per leaf, inside shard_map over the dp axis):
  e += g                      (error feedback carry)
  scale = absmax(e)/127; q = round(e/scale) int8
  e -= q*scale                (residual stays local)
  wire: all_gather(q int8, scale f32) -> mean of dequants

all_gather of int8 moves ~(G-1)/G · bytes_int8 per link vs ~2·bytes_bf16
for a ring all-reduce: ≈4× wire reduction at f32 grads, 2× at bf16. Error
feedback makes the bias vanish over steps (tested: SGD with compressed
grads converges to the uncompressed trajectory).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .compat import shard_map


def _compress_leaf(e: jnp.ndarray):
    scale = jnp.max(jnp.abs(e)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(e / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_mean(tree, axis_name: str):
    """Mean of `tree` across `axis_name` with int8 wire format.
    Call inside shard_map/pmap. Returns (mean_tree)."""
    def leaf(g):
        q, scale = _compress_leaf(g.astype(jnp.float32))
        qs = jax.lax.all_gather(q, axis_name)            # (G, ...) int8 wire
        ss = jax.lax.all_gather(scale, axis_name)        # (G,) f32
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
        return jnp.mean(deq, axis=0)
    return jax.tree.map(leaf, tree)


def compressed_mean_with_feedback(tree, err_tree, axis_name: str):
    """Error-feedback variant: returns (mean_tree, new_err_tree)."""
    def leaf(g, e):
        acc = g.astype(jnp.float32) + e
        q, scale = _compress_leaf(acc)
        new_e = acc - q.astype(jnp.float32) * scale
        qs = jax.lax.all_gather(q, axis_name)
        ss = jax.lax.all_gather(scale, axis_name)
        deq = qs.astype(jnp.float32) * ss.reshape((-1,) + (1,) * g.ndim)
        return jnp.mean(deq, axis=0), new_e
    pairs = jax.tree.map(leaf, tree, err_tree)
    mean = jax.tree.map(lambda p: p[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda p: p[1], pairs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return mean, err


def make_grad_mean_fn(mesh, compress: bool):
    """(grads_sharded_over_dp,) -> mean over dp axes, as a shard_map fn.
    With compress=False this is a plain psum-mean (baseline)."""
    from repro.distributed.sharding import dp_axes
    dp = dp_axes(mesh)
    assert dp, "no dp axis in mesh"
    axis = dp[-1] if len(dp) == 1 else dp  # gather over combined axes

    def mean_fn(grads):
        if compress:
            return compressed_mean(grads, axis)
        return jax.tree.map(
            lambda g: jax.lax.pmean(g.astype(jnp.float32), axis), grads)

    spec_in = jax.tree.map(lambda _: P(*[None]), {})  # placeholder
    return mean_fn
