"""Version compatibility for the jax APIs that moved between releases.

``jax.shard_map`` graduated from ``jax.experimental.shard_map`` and
``jax.sharding.AxisType`` (with ``jax.make_mesh(..., axis_types=...)``)
only exists on newer releases. Import from here instead of jax directly so
the whole distributed substrate works on both sides of the move.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax >= 0.5
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

# The replication-check kwarg was renamed check_rep -> check_vma.
_CHECK_KW = next((k for k in ("check_vma", "check_rep")
                  if k in inspect.signature(_shard_map).parameters), None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kw):
    """jax.shard_map accepting the modern ``check_vma`` spelling on every
    jax version (mapped to ``check_rep`` on 0.4.x)."""
    if check_vma is not None and _CHECK_KW is not None:
        kw[_CHECK_KW] = check_vma
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def set_mesh(mesh):
    """Context manager making `mesh` ambient. New jax: jax.set_mesh;
    0.4.x: the Mesh object is itself the context manager."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def axis_size(axis_name):
    """Size of a mapped mesh axis (jax.lax.axis_size moved here late)."""
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    from jax.core import axis_frame
    frame = axis_frame(axis_name)
    return frame if isinstance(frame, int) else frame.size


def abstract_mesh(axis_shapes, axis_names):
    """jax.sharding.AbstractMesh across the signature change: new jax
    takes (sizes, names); 0.4.x takes a tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh
    params = inspect.signature(AbstractMesh.__init__).parameters
    if "axis_names" in params:
        return AbstractMesh(tuple(axis_shapes), tuple(axis_names))
    return AbstractMesh(tuple(zip(axis_names, axis_shapes)))


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh with Auto axis types where the kwarg exists."""
    kw = {} if devices is None else {"devices": devices}
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        kw["axis_types"] = (axis_type.Auto,) * len(axis_names)
    return jax.make_mesh(axis_shapes, axis_names, **kw)
