"""Compute/communication overlap: ring collective matmul.

Row-parallel TP layer: y = X @ W with the contraction dim k sharded over
the model axis (device i holds X_i (m, k/G) and W_i (k/G, n)); the naive
lowering is a full local partial product followed by a blocking
all-reduce. The ring version interleaves: the partial product is computed
one m-chunk at a time, and each chunk rides the ring (ppermute) while the
next chunk's matmul runs — every ICI hop hidden behind an MXU call
(classic reduce-scatter collective-matmul, cf. Wang et al. ASPLOS'23).

Output is naturally row-scattered (chunk idx on device idx) — exactly the
sequence-parallel layout the next layer wants; `gather=True` appends the
all-gather for layers that need the full y.

Schedule (g = ring size, device d):
    buf = P_d[chunk (d-1)]                       # create
    for t = 1 .. g-1:
        buf <- ppermute(buf, +1)                 # overlaps with:
        buf += P_d[chunk (d-1-t)]                # local MXU partial
    => buf = Σ_i P_i[chunk d]  (y rows of block d)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ring_matmul(x_shard: jnp.ndarray, w_shard: jnp.ndarray,
                axis_name: str, gather: bool = False) -> jnp.ndarray:
    """x_shard (m, k/G), w_shard (k/G, n); m divisible by G.
    Returns y rows chunk `idx` (m/G, n), or full (m, n) with gather."""
    from .compat import axis_size
    g = axis_size(axis_name)
    idx = jax.lax.axis_index(axis_name)
    m = x_shard.shape[0]
    assert m % g == 0, (m, g)
    mb = m // g
    fwd = [(i, (i + 1) % g) for i in range(g)]

    def part(c):
        rows = jax.lax.dynamic_slice_in_dim(x_shard, c * mb, mb, axis=0)
        return jnp.dot(rows, w_shard, preferred_element_type=jnp.float32)

    buf = part((idx - 1) % g)
    for t in range(1, g):
        buf = jax.lax.ppermute(buf, axis_name, fwd)
        buf = buf + part((idx - 1 - t) % g)
    if gather:
        return jax.lax.all_gather(buf, axis_name, axis=0, tiled=True)
    return buf


def reference_matmul(x_shard: jnp.ndarray, w_shard: jnp.ndarray,
                     axis_name: str) -> jnp.ndarray:
    """Unoverlapped baseline: full local partial + blocking psum."""
    part = jnp.dot(x_shard, w_shard, preferred_element_type=jnp.float32)
    return jax.lax.psum(part, axis_name)
