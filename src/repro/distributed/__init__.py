from repro.distributed import (compression, fault_tolerance, overlap,  # noqa: F401
                               pipeline_parallel, sharding)
