"""Logical sharding rules: param/batch/cache pytrees -> NamedSharding.

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single
pod. DP runs over pod×data (gradients all-reduce across both), TP/EP over
model, SP (long-context) shards the KV/sequence dim over data.

Rules are name-based over the stable param paths the model zoo emits; a
dim is sharded only when divisible by the mesh axis size (else replicated
— MQA KV heads, tiny routers, conv kernels etc. fall out naturally).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

# param-name -> which dim gets "model". Dims count from the END so the
# same rule covers stacked (L, ...) / per-expert (L, E, ...) variants.
_COL = {"wq", "wk", "wv", "wg", "wu", "we_g", "we_u", "ck",
        "in_x", "in_z", "in_b", "in_c", "unembed", "xq", "xk", "xv"}
_ROW = {"wo", "wd", "we_d", "cv", "out_proj", "xo"}
_REPL = {"router", "w_lora_a", "w_lora_b", "w0", "u", "mu_tmix", "mu_cmix",
         "conv_w", "a_log", "dt_bias", "d_skip", "in_dt", "enc_pos",
         "dec_pos"}


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def _spec_for(name: str, leaf, mesh) -> P:
    ms = _model_size(mesh)
    ndim = getattr(leaf, "ndim", len(leaf.shape))
    shape = leaf.shape

    def ok(dim_from_end):
        return shape[ndim - dim_from_end] % ms == 0

    if name == "embed":
        return P("model", None) if shape[0] % ms == 0 else P(None, None)
    if name in _COL and ndim >= 2 and ok(1):
        return P(*([None] * (ndim - 1) + ["model"]))
    if name in _ROW and ndim >= 2 and ok(2):
        return P(*([None] * (ndim - 2) + ["model", None]))
    return P(*([None] * ndim))


# QLinear / transform pytree field names (paths look like layers/wq/qweight)
_QFIELDS = {"qweight", "scale", "blocks", "inv_blocks", "ha", "hb", "sign",
            "s", "t", "t_inv"}
_WEIGHT_NAMES = _COL | _ROW | _REPL | {"embed"}


def params_sharding(params, mesh):
    """NamedSharding tree matching `params` (works on ShapeDtypeStructs).
    Quantized leaves: qweight shards like the fp weight it replaced; the
    per-output-channel scale follows column-parallel weights; transform
    leaves (small blocks/Hadamard factors/signs) replicate."""

    def walk(path, leaf):
        keys = []
        for entry in path:
            key = getattr(entry, "key", None)
            if key is None:
                key = getattr(entry, "name", None)
            if isinstance(key, str):
                keys.append(key)
        field = keys[-1] if keys and keys[-1] in _QFIELDS else None
        wname = next((k for k in reversed(keys) if k in _WEIGHT_NAMES), None)
        ms = _model_size(mesh)
        ndim = len(leaf.shape)
        if field in (None, "qweight"):
            spec = _spec_for(wname or (keys[-1] if keys else ""), leaf, mesh)
        elif field == "scale" and wname in _COL and ndim >= 1 \
                and leaf.shape[-1] % ms == 0:
            spec = P(*([None] * (ndim - 1) + ["model"]))
        else:
            spec = P(*([None] * ndim))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        walk, params,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def zero_opt_sharding(params_sh, opt_shapes, mesh, params_shapes=None):
    """ZeRO-1: m/v/master pick up an extra 'data' sharding on the first
    dim that is divisible and not already model-sharded; scalars stay
    replicated. params keep their own (model-only) sharding."""
    data = mesh.shape.get("data", 1)

    def widen(ps, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec = list(ps.spec) + [None] * (nd - len(ps.spec))
        for dim in range(nd):
            if spec[dim] is None and leaf.shape[dim] % data == 0 \
                    and leaf.shape[dim] >= data:
                spec[dim] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    out = {}
    for key in ("m", "v", "master"):
        if key in opt_shapes:
            out[key] = jax.tree.map(widen, params_sh, opt_shapes[key])
    out["step"] = NamedSharding(mesh, P())
    return out


def batch_sharding(batch, mesh, shard_seq: bool = False):
    """tokens/labels (B, S): batch over dp axes when divisible; optional SP
    shards S over 'data' (long-context, batch=1); replicate otherwise."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec(leaf):
        nd = len(leaf.shape)
        if dp and leaf.shape[0] % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))
        if shard_seq and nd >= 2 and leaf.shape[1] % mesh.shape.get(
                "data", 1) == 0 and leaf.shape[1] > 1:
            return NamedSharding(mesh, P(None, "data", *([None] * (nd - 2))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree.map(spec, batch,
                        is_leaf=lambda x: hasattr(x, "shape")
                        and not isinstance(x, dict))


def cache_sharding(cache, mesh, cfg=None, shard_seq: bool = False):
    """KV caches (L, B, T, KV, hd): batch on dp, heads on model when
    divisible; long-context (B not divisible) shards T on data instead.
    SSM states (L, B, H, dk, dv): heads on model."""
    dp = dp_axes(mesh)
    ms = _model_size(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec(leaf):
        shape = leaf.shape
        nd = len(shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        if nd == 5:  # (L, B, T, KV, hd) kv-cache or (L, B, H, dk, dv) state
            batch_ok = dp and shape[1] % dp_size == 0
            is_kv = shape[2] > shape[3]  # T dim much larger than heads
            head_ax = 3 if is_kv else 2
            heads = shape[head_ax]
            hspec = "model" if heads % ms == 0 else None
            if is_kv:
                t_ok = shape[2] % ms == 0 and shape[2] > 1
                # heads not TP-divisible (MQA/GQA-small): shard T on model
                tspec_m = "model" if (hspec is None and t_ok) else None
                if batch_ok:
                    return NamedSharding(mesh, P(None, dp, tspec_m, hspec,
                                                 None))
                t_data = "data" if shape[2] % mesh.shape.get("data", 1) == 0 \
                    else None
                return NamedSharding(mesh, P(None, None,
                                             t_data or tspec_m, hspec, None))
            if batch_ok:
                return NamedSharding(mesh, P(None, dp, hspec, None, None))
            return NamedSharding(mesh, P(None, None, hspec, None, None))
        if nd >= 2:
            batch_ax = 1 if nd >= 3 else 0
            if shape[batch_ax] % dp_size == 0:
                sp = [None] * nd
                sp[batch_ax] = dp
                return NamedSharding(mesh, P(*sp))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree.map(spec, cache,
                        is_leaf=lambda x: hasattr(x, "shape")
                        and not isinstance(x, dict))


def opt_state_sharding(params_sh, opt_state_shapes):
    """Adam m/v mirror the param shardings; scalars replicated."""
    def mirror(ps, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(ps.mesh, P())
        return ps
    m = jax.tree.map(mirror, params_sh, opt_state_shapes["m"])
    v = jax.tree.map(mirror, params_sh, opt_state_shapes["v"])
    mesh = jax.tree.leaves(params_sh)[0].mesh
    return {"m": m, "v": v,
            "step": NamedSharding(mesh, P())}
