"""Logical sharding rules: param/batch/cache pytrees -> NamedSharding.

Mesh axes: ("pod", "data", "model") multi-pod or ("data", "model") single
pod. DP runs over pod×data (gradients all-reduce across both), TP/EP over
model, SP (long-context) shards the KV/sequence dim over data.

Rules are name-based over the stable param paths the model zoo emits; a
dim is sharded only when divisible by the mesh axis size (else replicated
— MQA KV heads, tiny routers, conv kernels etc. fall out naturally).

Quantized serving adds two wrinkles this module owns:

- int4-packed ``QLinear.qweight`` is packed two-nibbles-per-byte along K,
  so row-parallel (contracted-dim) sharding must split the *packed* axis
  in packed units — each shard then holds whole bytes and ``2·K_packed/tp``
  unpacked K rows. Column-parallel weights shard d_out, which packing
  never touches. Per-output-channel scales follow column-parallel weights
  and replicate for row-parallel ones; transform factors (small
  block/Hadamard matrices acting on the *full* input dim) always
  replicate.
- quantized KV caches are a (codes int8, per-token scale f32) pair per
  K/V; both must shard the head axis congruently or a decode step would
  dequantize codes against the wrong slice of scales.
- *paged* KV pools (``models.dense.init_paged_cache``) keep the same
  5-dim leaf rank but mean (L, n_pages, page_size, KV, hd): the head
  axis (3) still shards on ``model`` — codes and scales congruently —
  while the page axis NEVER shards (every device holds its head slice
  of every physical page; the host-side page table indexes pages
  globally) and the ``page_table`` leaf replicates like ``pos``.

``tp_param_specs``/``tp_cache_specs`` emit plain PartitionSpec trees for
``shard_map`` (the serve engine's tensor-parallel mode); the
NamedSharding builders below serve ``jit``/``device_put``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.qlinear import QLinear

# param-name -> which dim gets "model". Dims count from the END so the
# same rule covers stacked (L, ...) / per-expert (L, E, ...) variants.
_COL = {"wq", "wk", "wv", "wg", "wu", "we_g", "we_u", "ck",
        "in_x", "in_z", "in_b", "in_c", "unembed", "xq", "xk", "xv"}
_ROW = {"wo", "wd", "we_d", "cv", "out_proj", "xo"}
_REPL = {"router", "w_lora_a", "w_lora_b", "w0", "u", "mu_tmix", "mu_cmix",
         "conv_w", "a_log", "dt_bias", "d_skip", "in_dt", "enc_pos",
         "dec_pos"}


def dp_axes(mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _model_size(mesh) -> int:
    return mesh.shape.get("model", 1)


def _spec_for(name: str, leaf, mesh) -> P:
    ms = _model_size(mesh)
    ndim = getattr(leaf, "ndim", len(leaf.shape))
    shape = leaf.shape

    def ok(dim_from_end):
        return shape[ndim - dim_from_end] % ms == 0

    if name == "embed":
        return P("model", None) if shape[0] % ms == 0 else P(None, None)
    if name in _COL and ndim >= 2 and ok(1):
        return P(*([None] * (ndim - 1) + ["model"]))
    if name in _ROW and ndim >= 2 and ok(2):
        return P(*([None] * (ndim - 2) + ["model", None]))
    return P(*([None] * ndim))


# QLinear / transform pytree field names (paths look like layers/wq/qweight)
_QFIELDS = {"qweight", "scale", "blocks", "inv_blocks", "ha", "hb", "sign",
            "s", "t", "t_inv"}
_WEIGHT_NAMES = _COL | _ROW | _REPL | {"embed"}


def params_sharding(params, mesh):
    """NamedSharding tree matching `params` (works on ShapeDtypeStructs).
    Quantized leaves: qweight shards like the fp weight it replaced; the
    per-output-channel scale follows column-parallel weights; transform
    leaves (small blocks/Hadamard factors/signs) replicate."""

    def walk(path, leaf):
        keys = _path_keys(path)
        field = keys[-1] if keys and keys[-1] in _QFIELDS else None
        wname = next((k for k in reversed(keys) if k in _WEIGHT_NAMES), None)
        ms = _model_size(mesh)
        ndim = len(leaf.shape)
        if field in (None, "qweight"):
            spec = _spec_for(wname or (keys[-1] if keys else ""), leaf, mesh)
        elif field == "scale" and wname in _COL and ndim >= 1 \
                and leaf.shape[-1] % ms == 0:
            spec = P(*([None] * (ndim - 1) + ["model"]))
        else:
            spec = P(*([None] * ndim))
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(
        walk, params,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def zero_opt_sharding(params_sh, opt_shapes, mesh, params_shapes=None):
    """ZeRO-1: m/v/master pick up an extra 'data' sharding on the first
    dim that is divisible and not already model-sharded; scalars stay
    replicated. params keep their own (model-only) sharding."""
    data = mesh.shape.get("data", 1)

    def widen(ps, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        spec = list(ps.spec) + [None] * (nd - len(ps.spec))
        for dim in range(nd):
            if spec[dim] is None and leaf.shape[dim] % data == 0 \
                    and leaf.shape[dim] >= data:
                spec[dim] = "data"
                break
        return NamedSharding(mesh, P(*spec))

    out = {}
    for key in ("m", "v", "master"):
        if key in opt_shapes:
            out[key] = jax.tree.map(widen, params_sh, opt_shapes[key])
    out["step"] = NamedSharding(mesh, P())
    return out


def batch_sharding(batch, mesh, shard_seq: bool = False):
    """tokens/labels (B, S): batch over dp axes when divisible; optional SP
    shards S over 'data' (long-context, batch=1); replicate otherwise."""
    dp = dp_axes(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1

    def spec(leaf):
        nd = len(leaf.shape)
        if dp and leaf.shape[0] % dp_size == 0:
            return NamedSharding(mesh, P(dp, *([None] * (nd - 1))))
        if shard_seq and nd >= 2 and leaf.shape[1] % mesh.shape.get(
                "data", 1) == 0 and leaf.shape[1] > 1:
            return NamedSharding(mesh, P(None, "data", *([None] * (nd - 2))))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree.map(spec, batch,
                        is_leaf=lambda x: hasattr(x, "shape")
                        and not isinstance(x, dict))


# KV-cache leaf names: codes and their per-token scales must shard the
# head axis congruently (a decode step dequantizes codes against scales).
_KV_KEYS = {"k", "v"}
_KV_SCALE_KEYS = {"k_scale", "v_scale"}


def cache_sharding(cache, mesh, cfg=None, shard_seq: bool = False):
    """KV caches (L, B, T, KV, hd): batch on dp, heads on model when
    divisible; long-context (B not divisible) shards T on data instead.
    SSM states (L, B, H, dk, dv): heads on model.

    Quantized caches carry per-token scale leaves (L, B, T, KV, 1) next
    to the int8 codes; leaf *names* (k/v vs k_scale/v_scale) pin the head
    axis so scales shard exactly like their codes — the shape heuristic
    alone would misread a scale (or a short-T cache) as an SSM state.

    Paged caches (a ``page_table`` leaf next to (L, n_pages, page_size,
    KV, hd) pools) shard heads on model only: the page axis stays whole
    on every device (page ids are global) and the table replicates."""
    dp = dp_axes(mesh)
    ms = _model_size(mesh)
    dp_size = int(np.prod([mesh.shape[a] for a in dp])) if dp else 1
    paged = isinstance(cache, dict) and "page_table" in cache

    def spec(path, leaf):
        shape = leaf.shape
        nd = len(shape)
        key = _last_key(path)
        if nd == 0 or key in ("pos", "page_table"):
            return NamedSharding(mesh, P(*([None] * nd)))
        if paged and nd == 5:
            hspec = "model" if shape[3] % ms == 0 else None
            return NamedSharding(mesh, P(None, None, None, hspec, None))
        if nd == 5:  # (L, B, T, KV, hd) kv-cache or (L, B, H, dk, dv) state
            batch_ok = dp and shape[1] % dp_size == 0
            if key in _KV_KEYS or key in _KV_SCALE_KEYS:
                is_kv = True          # name-pinned: head axis is 3
            else:
                is_kv = shape[2] > shape[3]  # T dim much larger than heads
            head_ax = 3 if is_kv else 2
            heads = shape[head_ax]
            hspec = "model" if heads % ms == 0 else None
            if is_kv:
                t_ok = shape[2] % ms == 0 and shape[2] > 1
                # heads not TP-divisible (MQA/GQA-small): shard T on model
                tspec_m = "model" if (hspec is None and t_ok) else None
                if batch_ok:
                    return NamedSharding(mesh, P(None, dp, tspec_m, hspec,
                                                 None))
                t_data = "data" if shape[2] % mesh.shape.get("data", 1) == 0 \
                    else None
                return NamedSharding(mesh, P(None, None,
                                             t_data or tspec_m, hspec, None))
            if batch_ok:
                return NamedSharding(mesh, P(None, dp, hspec, None, None))
            return NamedSharding(mesh, P(None, None, hspec, None, None))
        if nd >= 2:
            batch_ax = 1 if nd >= 3 else 0
            if shape[batch_ax] % dp_size == 0:
                sp = [None] * nd
                sp[batch_ax] = dp
                return NamedSharding(mesh, P(*sp))
        return NamedSharding(mesh, P(*([None] * nd)))

    return jax.tree_util.tree_map_with_path(
        spec, cache,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def _path_keys(path) -> list:
    """String keys along a jax tree path (dict keys + dataclass fields)."""
    keys = []
    for entry in path:
        key = getattr(entry, "key", None)
        if key is None:
            key = getattr(entry, "name", None)
        if isinstance(key, str):
            keys.append(key)
    return keys


def _last_key(path) -> Optional[str]:
    keys = _path_keys(path)
    return keys[-1] if keys else None


# ------------------------------------------- shard_map TP PartitionSpecs

def tp_partition(name: Optional[str]) -> str:
    """Megatron role of a weight: 'col' (output dim sharded, no comm),
    'row' (contracted input dim sharded, psum), or 'replicated'."""
    if name in _COL:
        return "col"
    if name in _ROW:
        return "row"
    return "replicated"


def _tp_qlinear_specs(p: QLinear, part: str, tp: int, axis: str) -> QLinear:
    """PartitionSpec-valued QLinear mirroring ``p`` (meta fields kept, so
    the spec tree flattens identically). Row-parallel packed weights shard
    the packed axis — whole bytes per shard, K must split in packed units."""
    qnd = p.qweight.ndim
    qspec = P(*([None] * qnd))
    snd = p.scale.ndim
    sspec = P(*([None] * snd))
    if part == "col" and p.qweight.shape[-1] % tp == 0:
        qspec = P(*([None] * (qnd - 1) + [axis]))
        if p.scale.shape[-1] % tp == 0:
            sspec = P(*([None] * (snd - 1) + [axis]))
    elif part == "row" and qnd >= 2 and p.qweight.shape[-2] % tp == 0:
        qspec = P(*([None] * (qnd - 2) + [axis, None]))
    return dataclasses.replace(
        p, qweight=qspec, scale=sspec,
        transform=jax.tree.map(lambda _: P(), p.transform))


# Attention projections shard in units of whole heads: the reshape to
# (B, S, H, hd) and RoPE assume every device holds complete heads.
_ATTN_WEIGHTS = {"wq", "wk", "wv", "wo"}


def tp_param_specs(params, mesh, axis: str = "model", cfg=None,
                   row_mode: str = "gather"):
    """PartitionSpec tree for running the model forward under shard_map
    on a tensor-parallel mesh axis.

    Column weights (wq/wk/wv/wg/wu, ...) shard d_out (whole heads / FFN
    columns per device). Row weights (wo/wd, ...) follow ``row_mode``:

    - ``"gather"`` (default): replicate them; the forward all-gathers the
      sharded activation and contracts against the full weight. Column
      slices of a matmul are bitwise exact, so the whole forward — and
      every greedy token — is **bit-identical** to one device.
    - ``"psum"``: shard the contracted dim — in *packed units* for
      int4-packed QLinear — and psum partial outputs. True Megatron row
      parallelism (half the row-weight bytes per device), but partial-sum
      order makes it rtol-level, not bitwise, equal.

    Embedding, unembed, and norms replicate (residual stream and vocab
    dim stay whole). Falls back to replication wherever a dim does not
    divide; with ``cfg`` given, the attention projections (as a group —
    wq/wk/wv/wo shard together or not at all) additionally require BOTH
    head counts to divide, so no shard ever holds a partial head and the
    GQA q→kv pairing stays intact (MQA/GQA-small then replicates instead
    of splitting head_dim)."""
    assert row_mode in ("gather", "psum"), row_mode
    tp = mesh.shape[axis]
    # The attention projections shard as a GROUP: a head-sharded wq next
    # to replicated wk/wv would scramble the contiguous-block GQA pairing
    # inside chunked_attention, so if EITHER head count fails to divide,
    # all of wq/wk/wv/wo replicate together.
    attn_ok = cfg is None or (cfg.n_heads % tp == 0
                              and cfg.n_kv_heads % tp == 0)

    def walk(path, leaf):
        keys = _path_keys(path)
        wname = next((k for k in reversed(keys) if k in _WEIGHT_NAMES), None)
        part = tp_partition(wname)
        if (wname in ("embed", "unembed")          # logits stay whole
                or (wname in _ATTN_WEIGHTS and not attn_ok)
                or (part == "row" and row_mode == "gather")):
            part = "replicated"
        if isinstance(leaf, QLinear):
            return _tp_qlinear_specs(leaf, part, tp, axis)
        nd = len(leaf.shape)
        if part == "col" and nd >= 2 and leaf.shape[-1] % tp == 0:
            return P(*([None] * (nd - 1) + [axis]))
        if part == "row" and nd >= 2 and leaf.shape[-2] % tp == 0:
            return P(*([None] * (nd - 2) + [axis, None]))
        return P(*([None] * nd))

    return jax.tree_util.tree_map_with_path(
        walk, params,
        is_leaf=lambda x: isinstance(x, QLinear)
        or (hasattr(x, "shape") and not isinstance(x, dict)))


def tp_cache_specs(cache, mesh, axis: str = "model",
                   dp_axis: Optional[str] = None):
    """PartitionSpec tree for a decode cache under shard_map: KV codes
    AND their per-token scales shard the head axis congruently when the
    head count divides; ``pos`` and anything non-divisible replicate.
    ``dp_axis`` additionally shards the slot/batch axis when it divides
    (the engine's batched decode step; prefill is batch-1, replicated).

    Paged pools ride the same rule: axis 3 is the head axis for both the
    slot layout (L, B, T, KV, hd) and the page layout (L, n_pages,
    page_size, KV, hd), so codes/scales shard congruently either way —
    but pass ``dp_axis=None`` for paged caches (the page axis must stay
    whole; the engine enforces tp-only meshes for paged serving) and the
    ``page_table`` replicates alongside ``pos``."""
    tp = mesh.shape[axis]
    dp = mesh.shape[dp_axis] if dp_axis else 1
    paged = isinstance(cache, dict) and "page_table" in cache

    def walk(path, leaf):
        nd = len(leaf.shape)
        key = _last_key(path)
        if key in ("pos", "page_table") or nd < 5:
            return P(*([None] * nd))
        heads = leaf.shape[3]
        hspec = axis if heads % tp == 0 else None
        bspec = dp_axis if (dp_axis and not paged
                            and leaf.shape[1] % dp == 0) else None
        return P(None, bspec, None, hspec, None)

    return jax.tree_util.tree_map_with_path(
        walk, cache,
        is_leaf=lambda x: hasattr(x, "shape") and not isinstance(x, dict))


def ragged_desc_specs(desc) -> dict:
    """PartitionSpecs for the unified ragged step's host-built descriptor
    arrays (packed tokens / per-token positions / page-table rows / logit
    rows / kernel query blocks): everything **replicates** — descriptors
    are tiny int32 control data indexing the *global* page pool, exactly
    like ``pos``/``page_table`` in ``tp_cache_specs``; only the KV pools
    and params shard. Works on arrays or ShapeDtypeStructs."""
    return jax.tree.map(lambda a: P(*([None] * len(a.shape))), desc,
                        is_leaf=lambda x: hasattr(x, "shape")
                        and not isinstance(x, dict))


def named(spec_tree, mesh):
    """PartitionSpec tree -> NamedSharding tree (device_put / jit)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))


def opt_state_sharding(params_sh, opt_state_shapes):
    """Adam m/v mirror the param shardings; scalars replicated."""
    def mirror(ps, leaf):
        if getattr(leaf, "ndim", 0) == 0:
            return NamedSharding(ps.mesh, P())
        return ps
    m = jax.tree.map(mirror, params_sh, opt_state_shapes["m"])
    v = jax.tree.map(mirror, params_sh, opt_state_shapes["v"])
    mesh = jax.tree.leaves(params_sh)[0].mesh
    return {"m": m, "v": v,
            "step": NamedSharding(mesh, P())}
