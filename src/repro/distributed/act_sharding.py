"""Activation sharding constraints (Megatron-style sequence parallelism).

Between transformer layers the residual stream is the single biggest
remat-surviving tensor (L × B·S·D bf16 — 70+ GB/device for granite-34b
train_4k). Constraining the carry to shard its sequence dim over the
model axis cuts that by the TP degree; GSPMD inserts the matching
all-gather before attention and reduce-scatter after (exactly Megatron
SP). The launcher activates a mesh context; without one every constraint
is a no-op so tests/benches on one device are untouched.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_STATE = threading.local()


@contextlib.contextmanager
def active_mesh(mesh: Optional[Mesh]):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def constrain_seq(x, seq_axis: int = 1):
    """Shard x's sequence dim over 'model' and batch over dp axes, when
    divisible; otherwise leave untouched."""
    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return x
    ms = mesh.shape["model"]
    if x.ndim < 3 or x.shape[seq_axis] % ms or x.shape[seq_axis] <= 1:
        return x
    from repro.distributed.sharding import dp_axes
    dp = dp_axes(mesh)
    spec = [None] * x.ndim
    if dp and x.shape[0] % max(1, _prod(mesh, dp)) == 0:
        spec[0] = dp
    spec[seq_axis] = "model"
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def constrain_batch(x):
    """Shard leading batch dim over dp axes when divisible."""
    mesh = get_mesh()
    if mesh is None:
        return x
    from repro.distributed.sharding import dp_axes
    dp = dp_axes(mesh)
    if not dp or x.ndim < 1 or x.shape[0] % max(1, _prod(mesh, dp)):
        return x
    spec = [dp] + [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*spec)))


def _prod(mesh, axes) -> int:
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out
