"""Pipeline parallelism: GPipe-style microbatched stage pipeline over a
mesh axis, built on shard_map + ppermute.

Each device owns one stage's parameters (stage-stacked leading axis,
sharded on the pipeline axis). Microbatches stream through: at step t,
device s runs stage s on microbatch (t - s) — the classic skew — with
activations hopping the ring between steps. Bubble fraction is
(G-1)/(M+G-1); the trainer picks M >= 4G by default.

This module is the PP building block the launcher wires in when the
`--pp` flag asks for it (DP×TP saturation case); it is exercised in tests
at small scale and in the dry-run as an alternative mesh mapping.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .compat import shard_map


def pipeline_apply(stage_fn: Callable, mesh: Mesh, axis: str,
                   stage_params, x_micro: jnp.ndarray) -> jnp.ndarray:
    """stage_fn(params_one_stage, x) -> y, same shape.
    stage_params: leaves with leading axis == n_stages (sharded on `axis`).
    x_micro: (M, mb, ...) microbatched input (replicated).
    Returns (M, mb, ...) outputs after all stages."""
    g = mesh.shape[axis]

    def shmap_body(params_local, x_all):
        # params_local leaves: (1, ...) — this device's stage
        p = jax.tree.map(lambda a: a[0], params_local)
        sidx = jax.lax.axis_index(axis)
        m = x_all.shape[0]
        steps = m + g - 1
        fwd = [(i, (i + 1) % g) for i in range(g)]
        out = jnp.zeros_like(x_all)
        carry = jnp.zeros_like(x_all[0])

        def body(t, state):
            carry, out = state
            # stage 0 ingests microbatch t (others use the arriving carry)
            mb_idx = jnp.clip(t, 0, m - 1)
            inp = jnp.where(sidx == 0, x_all[mb_idx], carry)
            active = (t - sidx >= 0) & (t - sidx < m)
            y = stage_fn(p, inp)
            y = jnp.where(active, y, carry)
            # last stage writes its finished microbatch t - (g-1)
            done_idx = jnp.clip(t - (g - 1), 0, m - 1)
            write = (sidx == g - 1) & (t - (g - 1) >= 0)
            out = jax.lax.cond(
                write,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, done_idx, 0),
                lambda o: o, out)
            carry = jax.lax.ppermute(y, axis, fwd)
            return carry, out

        carry, out = jax.lax.fori_loop(0, steps, body, (carry, out))
        # only the last stage holds real outputs; broadcast via psum of
        # masked contribution (cheap at small scale; a real trainer keeps
        # outputs stage-local for the loss)
        out = jnp.where(sidx == g - 1, out, jnp.zeros_like(out))
        return jax.lax.psum(out, axis)

    pspec = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(shmap_body, mesh=mesh,
                     in_specs=(pspec, P()), out_specs=P(),
                     check_vma=False)(stage_params, x_micro)


def reference_apply(stage_fn: Callable, stage_params,
                    x_micro: jnp.ndarray) -> jnp.ndarray:
    """Sequential oracle: every stage on every microbatch, no pipeline."""
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]

    def one(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], stage_params)
            x = stage_fn(p, x)
        return x

    return jax.vmap(one)(x_micro)
