"""GPTQ weight quantization (Frantar et al. 2022) in pure JAX.

Column-sequential error-compensated rounding with the inverse-Hessian
Cholesky recursion. This is calibration-time work (runs once per layer),
so we keep it in jnp with a `lax.fori_loop` rather than a Pallas kernel
(see DESIGN.md §3 — inherently serial per column).

H = E[xxᵀ] (the Σ_x already collected for CAT calibration) serves as the
Hessian proxy; per-output-channel symmetric scales follow the paper's
L2.4 range estimation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .quantizers import QuantSpec, compute_scale_zp, weight_spec


def _damped_hinv_chol(sigma_x: jnp.ndarray, damp: float = 0.01) -> jnp.ndarray:
    """Upper Cholesky factor of H⁻¹ with multiplicative damping."""
    d = sigma_x.shape[0]
    h = sigma_x.astype(jnp.float32)
    mean_diag = jnp.mean(jnp.diagonal(h))
    h = h + (damp * mean_diag + 1e-8) * jnp.eye(d, dtype=jnp.float32)
    hinv = jnp.linalg.inv(h)
    hinv = (hinv + hinv.T) / 2.0
    # Upper factor U with H⁻¹ = Uᵀ U  (cholesky returns lower L, H⁻¹ = L Lᵀ)
    l = jnp.linalg.cholesky(hinv)
    return l.T


@partial(jax.jit, static_argnames=("spec",))
def gptq_quantize(w: jnp.ndarray, sigma_x: jnp.ndarray,
                  spec: QuantSpec = None, damp: float = 0.01):
    """Quantize W (d_out, d_in) minimizing ||(W - Ŵ)X||² column-by-column.

    Returns (q int codes (d_out, d_in), scale (d_out, 1)).
    """
    if spec is None:
        spec = weight_spec(4)
    w = w.astype(jnp.float32)
    scale, _ = compute_scale_zp(w, spec)  # (d_out, 1), symmetric
    u = _damped_hinv_chol(sigma_x, damp)  # (d_in, d_in) upper
    d_in = w.shape[1]

    def body(i, carry):
        w_work, q_acc = carry
        col = w_work[:, i]
        q = jnp.clip(jnp.round(col / scale[:, 0]), spec.qmin, spec.qmax)
        err = (col - q * scale[:, 0]) / u[i, i]
        # propagate to not-yet-quantized columns (mask keeps shapes static)
        row = u[i, :]  # zeros below the diagonal handled by the mask
        mask = (jnp.arange(d_in) > i).astype(w_work.dtype)
        w_work = w_work - jnp.outer(err, row * mask)
        q_acc = q_acc.at[:, i].set(q)
        return (w_work, q_acc)

    q0 = jnp.zeros_like(w)
    _, q = jax.lax.fori_loop(0, d_in, body, (w, q0))
    return q.astype(jnp.int8 if spec.bits <= 8 else jnp.int32), scale


def gptq_dequant(q: jnp.ndarray, scale: jnp.ndarray, dtype=jnp.float32):
    return (q.astype(jnp.float32) * scale).astype(dtype)


def rtn_quantize(w: jnp.ndarray, spec: QuantSpec = None):
    """Round-to-nearest baseline with the same scale estimation."""
    if spec is None:
        spec = weight_spec(4)
    w = w.astype(jnp.float32)
    scale, _ = compute_scale_zp(w, spec)
    q = jnp.clip(jnp.round(w / scale), spec.qmin, spec.qmax)
    return q.astype(jnp.int8 if spec.bits <= 8 else jnp.int32), scale
