"""End-to-end PTQ pipeline (the paper's Section 6 setup as a system):

  calibrate -> build per-group transforms -> fuse T⁻¹ into weights ->
  quantize weights (RTN / GPTQ, L2.4 ranges) -> pack QLinear pytrees ->
  the SAME model code now serves quantized (qlinear dispatch).

Layer *groups* follow the paper: projections sharing an input activation
(q/k/v; up/gate) share one transform — "treating the layer as a single
linear layer with multiple output heads".
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import transforms as T
from .calibration import Taps, calibrate
from .cat import cat_block_stacked
from .gptq import gptq_quantize, rtn_quantize
from .qlinear import QLinear, fuse_weight_in
from .quantizers import pack_int4, weight_spec


@dataclasses.dataclass(frozen=True)
class GroupSpec:
    """One transform group inside one (logical) layer."""
    tap: str                 # tap suffix, e.g. "attn_in"
    weights: tuple           # param names in params[<scope>], e.g. ("wq","wk","wv")
    scope: str = "layers"    # which sub-tree the weights live in


def layer_groups(cfg) -> List[GroupSpec]:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        mlp_in = ("wg", "wu") if cfg.gated_mlp else ("wu",)
        return [GroupSpec("attn_in", ("wq", "wk", "wv")),
                GroupSpec("o_in", ("wo",)),
                GroupSpec("mlp_in", mlp_in),
                GroupSpec("down_in", ("wd",))]
    if fam == "moe":
        return [GroupSpec("attn_in", ("wq", "wk", "wv")),
                GroupSpec("o_in", ("wo",)),
                GroupSpec("expert_in", ("we_g", "we_u")),
                GroupSpec("down_in", ("we_d",))]
    if fam == "ssm":  # rwkv6: decay lora stays fp (nonlinear path)
        return [GroupSpec("attn_in", ("wr", "wk", "wv", "wg")),
                GroupSpec("o_in", ("wo",)),
                GroupSpec("mlp_in", ("ck",)),
                GroupSpec("down_in", ("cv",))]
    if fam == "hybrid":  # zamba2 mamba blocks; shared attn handled separately
        return [GroupSpec("mamba_in", ("in_x", "in_z", "in_b", "in_c"),
                          scope="mamba"),
                GroupSpec("mamba_out_in", ("out_proj",), scope="mamba")]
    if fam == "encdec":
        return [GroupSpec("attn_in", ("wq", "wk", "wv")),
                GroupSpec("cross_in", ("xq",)),
                GroupSpec("mlp_in", ("wg", "wu")),
                GroupSpec("down_in", ("wd",))]
    raise ValueError(fam)


def shared_groups(cfg) -> List[GroupSpec]:
    """Groups whose weights are NOT layer-stacked (zamba2 shared block)."""
    if cfg.family == "hybrid":
        return [GroupSpec("attn_in", ("wq", "wk", "wv"), scope="shared_attn"),
                GroupSpec("o_in", ("wo",), scope="shared_attn"),
                GroupSpec("mlp_in", ("wg", "wu"), scope="shared_attn"),
                GroupSpec("down_in", ("wd",), scope="shared_attn")]
    return []


@dataclasses.dataclass(frozen=True)
class QuantizeConfig:
    w_bits: int = 4
    a_bits: int = 4
    w_method: str = "rtn"            # rtn | gptq
    transform: str = "cat"           # none|smoothquant|hadamard|rotation|cat|cat_nohad
    cat_block: int = 0               # 0 => cfg.cat_block
    smooth_alpha: float = 0.5
    range_p: Optional[float] = 2.4
    seed: int = 0
    # w_bits=4 stores weight codes nibble-packed (two int4 per int8 byte,
    # halving weight memory) unless disabled.
    pack_int4: bool = True


def _sigma_w_of(ws: List[np.ndarray]) -> np.ndarray:
    """Σ_w for a group: Σ over members of W Wᵀ in input-major form —
    members are V (d_in, d_out), so Σ_w = Σ V Vᵀ (d_in × d_in)."""
    d = ws[0].shape[-2] if ws[0].ndim == 3 else ws[0].shape[0]
    sw = np.zeros((d, d), np.float64)
    for v in ws:
        v2 = np.asarray(v, np.float64)
        if v2.ndim == 3:               # experts (E, d_in, d_out)
            for e in range(v2.shape[0]):
                sw += v2[e] @ v2[e].T
        else:
            sw += v2 @ v2.T
    return sw


def build_transform(qcfg: QuantizeConfig, cfg, stats, ws: List[np.ndarray],
                    rng: np.random.Generator):
    d = ws[0].shape[-2]
    kind = qcfg.transform
    if kind == "none":
        return T.Identity()
    if kind == "smoothquant":
        wmax = np.max([np.abs(np.asarray(w, np.float64)).max(
            axis=tuple(range(w.ndim - 1))) if w.ndim == 3
            else np.abs(np.asarray(w)).max(axis=1) for w in ws], axis=0)
        return T.make_smoothquant(jnp.asarray(stats.absmax, jnp.float32),
                                  jnp.asarray(wmax, jnp.float32),
                                  alpha=qcfg.smooth_alpha)
    if kind == "hadamard":
        return T.make_hadamard(d, rng)
    if kind == "rotation":
        return T.make_rotation(d, rng)
    if kind in ("cat", "cat_nohad"):
        k = qcfg.cat_block or cfg.cat_block
        sw = jnp.asarray(_sigma_w_of(ws), jnp.float32)
        sx = jnp.asarray(stats.sigma, jnp.float32)
        return T.make_cat_block(sw, sx, k=min(k, d),
                                hadamard=(kind == "cat"), rng=rng)
    raise ValueError(kind)


def _make_qlinear(codes: jnp.ndarray, scale: jnp.ndarray, t,
                  qcfg: QuantizeConfig) -> QLinear:
    """Wrap quantized codes; at w_bits=4 pack two nibbles per int8 byte."""
    if qcfg.w_bits == 4 and qcfg.pack_int4:
        d_in = codes.shape[-2]
        return QLinear(pack_int4(codes, axis=-2), scale, t,
                       act_bits=qcfg.a_bits, w_bits=4, d_in=d_in)
    return QLinear(codes, scale, t, act_bits=qcfg.a_bits, w_bits=qcfg.w_bits)


def _quantize_weight(v: jnp.ndarray, sigma_t: Optional[jnp.ndarray],
                     qcfg: QuantizeConfig):
    """v (d_in, d_out) [or (E, d_in, d_out)] -> (codes, scale (1, d_out))."""
    spec = weight_spec(qcfg.w_bits, qcfg.range_p)
    if v.ndim == 3:
        fn = lambda vv: _quantize_weight(vv, sigma_t, qcfg)
        codes, scales = jax.vmap(fn)(v)
        return codes, scales
    w = v.T  # (d_out, d_in) — quantizer convention
    if qcfg.w_method == "gptq" and sigma_t is not None:
        q, s = gptq_quantize(w, sigma_t, spec)
    else:
        q, s = rtn_quantize(w, spec)
    return q.T, s.T  # codes (d_in, d_out), scale (1, d_out)


def quantize_model(model, params, qcfg: QuantizeConfig,
                   calib_batches) -> dict:
    """Returns a new params pytree with quantizable linears replaced by
    QLinear. Works for every arch family."""
    cfg = model.cfg
    taps = calibrate(model, params, calib_batches)
    rng = np.random.default_rng(qcfg.seed)
    qparams = jax.tree.map(lambda x: x, params)  # shallow copy

    def quantize_group(scope_tree, group: GroupSpec, tap_name: str,
                       layer_idx: Optional[int]):
        stats = taps[tap_name]
        ws = []
        for name in group.weights:
            w = scope_tree[name]
            ws.append(np.asarray(w[layer_idx] if layer_idx is not None else w))
        t = build_transform(qcfg, cfg, stats, ws, rng)
        sigma_t = T.fuse_cov(t, jnp.asarray(stats.sigma, jnp.float32))
        out = {}
        for name, w_np in zip(group.weights, ws):
            v = jnp.asarray(w_np, jnp.float32)
            if v.ndim == 3:
                vf = jax.vmap(lambda vv: fuse_weight_in(t, vv))(v)
            else:
                vf = fuse_weight_in(t, v)
            codes, scale = _quantize_weight(vf, sigma_t, qcfg)
            out[name] = _make_qlinear(codes, scale, t, qcfg)
        return out

    # --- layer-stacked groups
    n_layers = cfg.n_layers
    for group in layer_groups(cfg):
        scope = qparams[group.scope]
        per_layer = []
        for i in range(n_layers):
            tap_name = (f"layers.{i}.{group.tap}" if group.scope != "mamba"
                        else f"layers.{i}.{group.tap}")
            per_layer.append(quantize_group(scope, group, tap_name, i))
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
        for name in group.weights:
            scope[name] = stacked[name]

    # --- shared (non-stacked) groups: aggregate taps over invocation sites
    for group in shared_groups(cfg):
        scope = qparams[group.scope]
        site_names = [n for n in taps.names()
                      if n.startswith("shared.") and n.endswith(group.tap)]
        merged = _merge_stats(taps, site_names)
        ws = [np.asarray(scope[name]) for name in group.weights]
        t = build_transform(qcfg, cfg, merged, ws, rng)
        sigma_t = T.fuse_cov(t, jnp.asarray(merged.sigma, jnp.float32))
        for name, w_np in zip(group.weights, ws):
            vf = fuse_weight_in(t, jnp.asarray(w_np, jnp.float32))
            codes, scale = _quantize_weight(vf, sigma_t, qcfg)
            scope[name] = _make_qlinear(codes, scale, t, qcfg)

    # encoder layers (whisper): same groups, enc scope
    if cfg.family == "encdec":
        enc_groups = [GroupSpec("attn_in", ("wq", "wk", "wv"), "enc_layers"),
                      GroupSpec("mlp_in", ("wg", "wu"), "enc_layers"),
                      GroupSpec("down_in", ("wd",), "enc_layers")]
        # encoder taps were only recorded for attn_in; quantize that group
        scope = qparams["enc_layers"]
        for group in enc_groups:
            per_layer = []
            ok = all(f"enc.{i}.{group.tap}" in taps.stats
                     for i in range(cfg.n_enc_layers))
            if not ok:
                continue
            for i in range(cfg.n_enc_layers):
                per_layer.append(quantize_group(scope, group,
                                                f"enc.{i}.{group.tap}", i))
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *per_layer)
            for name in group.weights:
                scope[name] = stacked[name]

    return qparams


def _merge_stats(taps: Taps, names: List[str]):
    assert names, "no taps recorded for shared group"
    base = taps[names[0]]
    if len(names) == 1:
        return base
    import copy
    merged = copy.deepcopy(base)
    for n in names[1:]:
        st = taps[n]
        merged.cov.sigma += st.cov.sigma
        merged.cov.sq += st.cov.sq
        merged.cov.amax = np.maximum(merged.cov.amax, st.cov.amax)
        merged.cov.n += st.cov.n
        merged.samples.extend(st.samples)
    return merged


def eval_quantized(model, params, qparams, eval_batches) -> dict:
    """Held-out CE of fp vs quantized params (the Table-1 metric proxy)."""
    losses_fp, losses_q = [], []
    loss_fn = jax.jit(model.loss)
    for batch in eval_batches:
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        losses_fp.append(float(loss_fn(params, b)[1]["ce"]))
        losses_q.append(float(loss_fn(qparams, b)[1]["ce"]))
    fp, q = float(np.mean(losses_fp)), float(np.mean(losses_q))
    return {"ce_fp": fp, "ce_quant": q, "delta": q - fp,
            "ppl_fp": float(np.exp(fp)), "ppl_quant": float(np.exp(q))}
