"""Quantized linear dispatch — the bridge between the PTQ pipeline and the
model zoo.

Model code calls ``dense(p, x)`` for every linear layer. ``p`` is either a
raw jnp array ``V`` of shape (d_in, d_out) (fp path) or a ``QLinear``
pytree (serving path): int8 weight codes + per-output-channel scales +
an online activation transform + dynamic activation fake-quant. PTQ swaps
the params pytree; the model code is identical.

The jnp ops here are the *portable* path (and what the multi-pod dry-run
lowers). ``repro.kernels.ops`` provides the Pallas TPU fast path with the
same semantics (int8 MXU matmul with fused dequant epilogue).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import transforms as T
from .quantizers import QuantSpec, act_spec, fake_quant, unpack_int4


@dataclasses.dataclass(frozen=True)
class QLinear:
    qweight: jnp.ndarray          # int8 codes, (d_in, d_out) [or stacked (L, ...)];
                                  # int4-packed: (ceil(d_in/2), d_out), two nibbles/byte
    scale: jnp.ndarray            # f32, (1, d_out)
    transform: Any                # transform pytree acting on the input dim
    act_bits: int = 4             # static: dynamic per-token act quant bits (0 = off)
    w_bits: int = 8               # bit width of the stored weight codes
    d_in: int = 0                 # unpacked input dim when int4-packed; 0 = unpacked

    @property
    def packed(self) -> bool:
        return self.d_in > 0


jax.tree_util.register_dataclass(
    QLinear, data_fields=["qweight", "scale", "transform"],
    meta_fields=["act_bits", "w_bits", "d_in"]
)


def unpacked_qweight(p: QLinear) -> jnp.ndarray:
    """The int8 code tensor (..., d_in, d_out), unpacking int4 storage."""
    if p.packed:
        return unpack_int4(p.qweight, p.d_in, axis=-2)
    return p.qweight


def iter_qlinear(tree) -> list:
    """(path, QLinear) pairs for every QLinear leaf of a params pytree —
    the one tree walk shared by serving memory reports and checkpoint
    manifest flags."""
    out = []

    def walk(path, leaf):
        if isinstance(leaf, QLinear):
            out.append((path, leaf))
        return leaf

    jax.tree_util.tree_map_with_path(
        walk, tree, is_leaf=lambda x: isinstance(x, QLinear))
    return out


def fuse_weight_in(t, v: jnp.ndarray) -> jnp.ndarray:
    """Fuse T⁻¹ into an input-major weight V (d_in, d_out): V' = T⁻ᵀ V."""
    return T.fuse_weight(t, v.T).T


def dense(p, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    """y = x @ V (fp) or the quantized equivalent (transform -> dyn act
    quant -> int8-weight matmul with dequant)."""
    if isinstance(p, QLinear):
        cd = compute_dtype or x.dtype
        x = T.apply(p.transform, x)
        if p.act_bits:
            x = fake_quant(x, act_spec(p.act_bits))
        w = unpacked_qweight(p).astype(cd) * p.scale.astype(cd)
        return x.astype(cd) @ w
    cd = compute_dtype or x.dtype
    return x @ p.astype(cd)


def dense_tp(p, x: jnp.ndarray, axis: str, compute_dtype=None,
             use_kernel: bool = False,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Row-parallel ``dense`` under ``shard_map``: the contracted input
    dim is sharded over mesh axis ``axis`` (x (..., K_local), weight
    (K_local, d_out) — packed: (K_local/2, d_out)); returns the full
    (..., d_out) output psummed over ``axis``.

    fp weights contract locally and psum. For QLinear the fused transform
    mixes the FULL input dim (block-CAT / Hadamard factors span head
    boundaries), so the activation is all-gathered first, transformed and
    fake-quantized globally — per-token act scales are then identical to
    the single-device path — and only the local K slice contracts against
    the local weight shard before the psum. ``use_kernel=True`` runs that
    local contraction through the packed W4A8 Pallas kernels
    (``ops.qgemv_w4`` for decode shapes, ``ops.qmatmul_w4`` otherwise)
    on real int8 activation codes instead of the portable fake-quant
    matmul (rtol-level, not bitwise, equal to it)."""
    cd = compute_dtype or x.dtype

    def psum_matmul(xl, w):
        # Partial contractions accumulate in f32 and round to the compute
        # dtype ONCE after the psum — products of bf16 inputs are exact in
        # f32, so this matches the single-device matmul's f32 accumulation
        # instead of stacking a bf16 rounding per shard.
        y = xl.astype(cd).astype(jnp.float32) @ w.astype(jnp.float32)
        return jax.lax.psum(y, axis).astype(cd)

    if not isinstance(p, QLinear):
        return psum_matmul(x, p.astype(cd))
    k_local = p.qweight.shape[-2] * (2 if p.packed else 1)
    idx = jax.lax.axis_index(axis)
    xf = jax.lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True)
    if k_local >= xf.shape[-1]:
        # spec fallback left this row weight replicated (its K dim didn't
        # divide the axis): every device holds the full contraction, so
        # slicing + psum would multiply the output by the axis size —
        # compute it whole instead.
        return dense(p, xf, compute_dtype=compute_dtype)
    xf = T.apply(p.transform, xf)
    if use_kernel:
        from repro.kernels import ops
        kw = {} if interpret is None else {"interpret": interpret}
        lead = xf.shape[:-1]
        qx, sx, zpx = ops.dyn_quant(xf.reshape(-1, xf.shape[-1]),
                                    bits=p.act_bits or 8, symmetric=False,
                                    **kw)
        qx = jax.lax.dynamic_slice_in_dim(qx, idx * k_local, k_local, axis=1)
        if p.packed:
            from repro.kernels.quant_matmul_w4 import _GEMV_M
            run = ops.qgemv_w4 if qx.shape[0] <= _GEMV_M else ops.qmatmul_w4
        else:
            run = ops.qmatmul
        y = run(qx, sx, zpx, p.qweight, p.scale, **kw)
        y = y.reshape(*lead, p.scale.shape[-1]).astype(cd)
        return jax.lax.psum(y, axis)
    if p.act_bits:
        xf = fake_quant(xf, act_spec(p.act_bits))
    xl = jax.lax.dynamic_slice_in_dim(xf, idx * k_local, k_local,
                                      axis=xf.ndim - 1)
    # p is the LOCAL shard: unpack to k_local rows, not the global d_in
    w = unpack_int4(p.qweight, k_local, axis=-2) if p.packed else p.qweight
    return psum_matmul(xl, w.astype(cd) * p.scale.astype(cd))


def dense_params(p) -> jnp.ndarray:
    """Materialize the effective fp weight of either param kind (analysis)."""
    if isinstance(p, QLinear):
        return unpacked_qweight(p).astype(jnp.float32) * p.scale
    return jnp.asarray(p, jnp.float32)


def num_weight_bytes(p) -> int:
    if isinstance(p, QLinear):
        return p.qweight.size * p.qweight.dtype.itemsize + p.scale.size * 4
    return p.size * p.dtype.itemsize
