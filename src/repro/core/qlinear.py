"""Quantized linear dispatch — the bridge between the PTQ pipeline and the
model zoo.

Model code calls ``dense(p, x)`` for every linear layer. ``p`` is either a
raw jnp array ``V`` of shape (d_in, d_out) (fp path) or a ``QLinear``
pytree (serving path): int8 weight codes + per-output-channel scales +
an online activation transform + dynamic activation fake-quant. PTQ swaps
the params pytree; the model code is identical.

The jnp ops here are the *portable* path (and what the multi-pod dry-run
lowers). ``repro.kernels.ops`` provides the Pallas TPU fast path with the
same semantics (int8 MXU matmul with fused dequant epilogue).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import transforms as T
from .quantizers import QuantSpec, act_spec, fake_quant


@dataclasses.dataclass(frozen=True)
class QLinear:
    qweight: jnp.ndarray          # int8 codes, (d_in, d_out) [or stacked (L, ...)]
    scale: jnp.ndarray            # f32, (1, d_out)
    transform: Any                # transform pytree acting on the input dim
    act_bits: int = 4             # static: dynamic per-token act quant bits (0 = off)


jax.tree_util.register_dataclass(
    QLinear, data_fields=["qweight", "scale", "transform"], meta_fields=["act_bits"]
)


def fuse_weight_in(t, v: jnp.ndarray) -> jnp.ndarray:
    """Fuse T⁻¹ into an input-major weight V (d_in, d_out): V' = T⁻ᵀ V."""
    return T.fuse_weight(t, v.T).T


def dense(p, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    """y = x @ V (fp) or the quantized equivalent (transform -> dyn act
    quant -> int8-weight matmul with dequant)."""
    if isinstance(p, QLinear):
        cd = compute_dtype or x.dtype
        x = T.apply(p.transform, x)
        if p.act_bits:
            x = fake_quant(x, act_spec(p.act_bits))
        w = p.qweight.astype(cd) * p.scale.astype(cd)
        return x.astype(cd) @ w
    cd = compute_dtype or x.dtype
    return x @ p.astype(cd)


def dense_params(p) -> jnp.ndarray:
    """Materialize the effective fp weight of either param kind (analysis)."""
    if isinstance(p, QLinear):
        return p.qweight.astype(jnp.float32) * p.scale
    return jnp.asarray(p, jnp.float32)


def num_weight_bytes(p) -> int:
    if isinstance(p, QLinear):
        return p.qweight.size * p.qweight.dtype.itemsize + p.scale.size * 4
    return p.size * p.dtype.itemsize
