"""Quantized linear dispatch — the bridge between the PTQ pipeline and the
model zoo.

Model code calls ``dense(p, x)`` for every linear layer. ``p`` is either a
raw jnp array ``V`` of shape (d_in, d_out) (fp path) or a ``QLinear``
pytree (serving path): int8 weight codes + per-output-channel scales +
an online activation transform + dynamic activation fake-quant. PTQ swaps
the params pytree; the model code is identical.

The jnp ops here are the *portable* path (and what the multi-pod dry-run
lowers). ``repro.kernels.ops`` provides the Pallas TPU fast path with the
same semantics (int8 MXU matmul with fused dequant epilogue).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import transforms as T
from .quantizers import QuantSpec, act_spec, fake_quant, unpack_int4


@dataclasses.dataclass(frozen=True)
class QLinear:
    qweight: jnp.ndarray          # int8 codes, (d_in, d_out) [or stacked (L, ...)];
                                  # int4-packed: (ceil(d_in/2), d_out), two nibbles/byte
    scale: jnp.ndarray            # f32, (1, d_out)
    transform: Any                # transform pytree acting on the input dim
    # Serving-only precomputes, None outside serving params
    # (``make_serving`` fills them; ``dense`` dispatches on ``colsum``):
    # colsum — Σ_k qweight[k, n] (f32, (..., 1, d_out)) for the fused
    # integer-accumulation zero-point epilogue;
    # w_eff — the dequantized compute-dtype weight (codes·scale, exactly
    # the tensor the portable path rebuilds from codes every step),
    # materialized once at build time for the off-TPU XLA hot path.
    colsum: Optional[jnp.ndarray] = None
    w_eff: Optional[jnp.ndarray] = None
    act_bits: int = 4             # static: dynamic per-token act quant bits (0 = off)
    w_bits: int = 8               # bit width of the stored weight codes
    d_in: int = 0                 # unpacked input dim when int4-packed; 0 = unpacked

    @property
    def packed(self) -> bool:
        return self.d_in > 0


jax.tree_util.register_dataclass(
    QLinear, data_fields=["qweight", "scale", "transform", "colsum",
                          "w_eff"],
    meta_fields=["act_bits", "w_bits", "d_in"]
)


def unpacked_qweight(p: QLinear) -> jnp.ndarray:
    """The int8 code tensor (..., d_in, d_out), unpacking int4 storage."""
    if p.packed:
        return unpack_int4(p.qweight, p.d_in, axis=-2)
    return p.qweight


def iter_qlinear(tree) -> list:
    """(path, QLinear) pairs for every QLinear leaf of a params pytree —
    the one tree walk shared by serving memory reports and checkpoint
    manifest flags."""
    out = []

    def walk(path, leaf):
        if isinstance(leaf, QLinear):
            out.append((path, leaf))
        return leaf

    jax.tree_util.tree_map_with_path(
        walk, tree, is_leaf=lambda x: isinstance(x, QLinear))
    return out


def fuse_weight_in(t, v: jnp.ndarray) -> jnp.ndarray:
    """Fuse T⁻¹ into an input-major weight V (d_in, d_out): V' = T⁻ᵀ V."""
    return T.fuse_weight(t, v.T).T


def dense(p, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    """y = x @ V (fp) or the quantized equivalent (transform -> dyn act
    quant -> int8-weight matmul with dequant)."""
    if isinstance(p, QLinear):
        if p.colsum is not None and p.act_bits:
            return dense_fused(p, x, compute_dtype)
        cd = compute_dtype or x.dtype
        x = T.apply(p.transform, x)
        if p.act_bits:
            x = fake_quant(x, act_spec(p.act_bits))
        w = unpacked_qweight(p).astype(cd) * p.scale.astype(cd)
        return x.astype(cd) @ w
    cd = compute_dtype or x.dtype
    return x @ p.astype(cd)


def dense_fused(p: QLinear, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    """Serving hot path for QLinears prepared by ``make_serving``.

    Three routes, fastest applicable first:

    1. **TPU + decomposable transform** — the single-launch Pallas fused
       kernel (``ops.fused_cat_matmul``): transform + quant + W4A8 in
       one grid, activations cross HBM once (rtol-level numerics).
    2. **``w_eff`` present (off-TPU default)** — the portable fake-quant
       matmul against the build-time dequantized weight. Bitwise
       IDENTICAL to ``dense`` on unprepared params (same transform, same
       quantize call, same matmul on the same weight values) — it just
       skips rebuilding codes·scale from (packed) storage every step.
    3. **integer accumulation** — real activation codes against stored
       codes with the precomputed-``colsum`` zero-point epilogue:
       y = s_x·s_w·(q_x @ q_w − zp_x·Σ_k q_w). Mathematically the exact
       dequantized product (int32 accumulation), but NOT bitwise equal
       to route 2 (the portable path rounds the dequantized activation/
       weight to the compute dtype before its matmul)."""
    cd = compute_dtype or x.dtype
    lead, d = x.shape[:-1], x.shape[-1]
    if _use_fused_kernel() and p.act_bits:
        from repro.kernels import ops
        dec = ops.fused_transform_operands(p.transform)
        if dec is not None:
            blocks, ha, hb, sign = dec
            y = ops.fused_cat_matmul(x.reshape(-1, d), blocks, ha, hb,
                                     sign, p.qweight, p.scale,
                                     act_bits=p.act_bits, packed=p.packed)
            return y.reshape(*lead, y.shape[-1]).astype(cd)
    xt = T.apply(p.transform, x)
    if p.w_eff is not None:
        if p.act_bits:
            xt = fake_quant(xt, act_spec(p.act_bits))
        return xt.astype(cd) @ p.w_eff.astype(cd)
    from .quantizers import quantize
    xf = xt.reshape(-1, d)
    q, s, zp = quantize(xf, act_spec(p.act_bits))
    acc = jnp.dot(q.astype(jnp.int32),
                  unpacked_qweight(p).astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    y = s * p.scale * (acc.astype(jnp.float32) - zp * p.colsum)
    return y.reshape(*lead, y.shape[-1]).astype(cd)


def _gemv_m() -> int:
    from repro.kernels.quant_matmul_w4 import _GEMV_M
    return _GEMV_M


def _use_fused_kernel() -> bool:
    """Route ``dense_fused`` through the single-launch Pallas kernel only
    on a real TPU backend — interpreted Pallas on CPU runs the kernel
    body in Python and would be slower than the XLA integer path, and
    the golden fixtures pin the XLA path's bitwise behaviour on CPU."""
    return jax.default_backend() == "tpu"


def make_serving(p: QLinear, keep_packed: Optional[bool] = None,
                 compute_dtype=None) -> QLinear:
    """Prepare one QLinear for the fused serving hot path: precompute the
    weight-code column sums for the zero-point epilogue and — off-TPU,
    where ``dense_fused`` runs the portable fake-quant matmul — the
    dequantized compute-dtype weight ``w_eff`` once at build time, so no
    step ever unpacks nibbles or rebuilds codes·scale again. ``w_eff``
    holds exactly the tensor the unprepared path materializes per call,
    keeping the off-TPU hot path bitwise identical to ``dense``.

    ``keep_packed=None`` keeps packed-only storage exactly when the
    Pallas fused kernel (which unpacks in VMEM) will serve the layer."""
    if keep_packed is None:
        keep_packed = _use_fused_kernel()
    w = unpacked_qweight(p)
    colsum = jnp.sum(w.astype(jnp.float32), axis=-2, keepdims=True)
    if keep_packed:
        return dataclasses.replace(p, colsum=colsum)
    cd = compute_dtype or jnp.float32
    w_eff = w.astype(cd) * p.scale.astype(cd)
    return dataclasses.replace(p, colsum=colsum, w_eff=w_eff)


def concat_out(ps, keep_packed: Optional[bool] = None, compute_dtype=None):
    """Column-concatenate linears that consume the SAME input into one
    (d_in, Σ d_out) linear — exact: each output column depends on one
    member only. For QLinears this additionally requires identical meta
    and a shared input transform (guaranteed for pipeline group members,
    which quantize against one group transform); the concat keeps the
    first member's transform and goes through ``make_serving``. Returns
    None when the members aren't uniformly concatenable."""
    if all(isinstance(p, jnp.ndarray) for p in ps):
        return jnp.concatenate(ps, axis=-1)
    if not all(isinstance(p, QLinear) for p in ps):
        return None
    head = ps[0]
    if any((p.act_bits, p.w_bits, p.d_in) !=
           (head.act_bits, head.w_bits, head.d_in) for p in ps[1:]):
        return None
    cat = dataclasses.replace(
        head,
        qweight=jnp.concatenate([p.qweight for p in ps], axis=-1),
        scale=jnp.concatenate([p.scale for p in ps], axis=-1))
    return make_serving(cat, keep_packed, compute_dtype)


def dense_tp(p, x: jnp.ndarray, axis: str, compute_dtype=None,
             use_kernel: bool = False,
             interpret: Optional[bool] = None) -> jnp.ndarray:
    """Row-parallel ``dense`` under ``shard_map``: the contracted input
    dim is sharded over mesh axis ``axis`` (x (..., K_local), weight
    (K_local, d_out) — packed: (K_local/2, d_out)); returns the full
    (..., d_out) output psummed over ``axis``.

    fp weights contract locally and psum. For QLinear the fused transform
    mixes the FULL input dim (block-CAT / Hadamard factors span head
    boundaries), so the activation is all-gathered first, transformed and
    fake-quantized globally — per-token act scales are then identical to
    the single-device path — and only the local K slice contracts against
    the local weight shard before the psum. ``use_kernel=True`` runs that
    local contraction through the packed W4A8 Pallas kernels
    (``ops.qgemv_w4`` for decode shapes, ``ops.qmatmul_w4`` otherwise)
    on real int8 activation codes instead of the portable fake-quant
    matmul (rtol-level, not bitwise, equal to it)."""
    cd = compute_dtype or x.dtype

    def psum_matmul(xl, w):
        # Partial contractions accumulate in f32 and round to the compute
        # dtype ONCE after the psum — products of bf16 inputs are exact in
        # f32, so this matches the single-device matmul's f32 accumulation
        # instead of stacking a bf16 rounding per shard.
        y = xl.astype(cd).astype(jnp.float32) @ w.astype(jnp.float32)
        return jax.lax.psum(y, axis).astype(cd)

    if not isinstance(p, QLinear):
        return psum_matmul(x, p.astype(cd))
    k_local = p.qweight.shape[-2] * (2 if p.packed else 1)
    idx = jax.lax.axis_index(axis)
    xf = jax.lax.all_gather(x, axis, axis=x.ndim - 1, tiled=True)
    if k_local >= xf.shape[-1]:
        # spec fallback left this row weight replicated (its K dim didn't
        # divide the axis): every device holds the full contraction, so
        # slicing + psum would multiply the output by the axis size —
        # compute it whole instead.
        return dense(p, xf, compute_dtype=compute_dtype)
    xf = T.apply(p.transform, xf)
    if use_kernel:
        from repro.kernels import ops
        kw = {} if interpret is None else {"interpret": interpret}
        lead = xf.shape[:-1]
        qx, sx, zpx = ops.dyn_quant(xf.reshape(-1, xf.shape[-1]),
                                    bits=p.act_bits or 8, symmetric=False,
                                    **kw)
        qx = jax.lax.dynamic_slice_in_dim(qx, idx * k_local, k_local, axis=1)
        if p.packed:
            from repro.kernels.quant_matmul_w4 import _GEMV_M
            run = ops.qgemv_w4 if qx.shape[0] <= _GEMV_M else ops.qmatmul_w4
        else:
            run = ops.qmatmul
        y = run(qx, sx, zpx, p.qweight, p.scale, **kw)
        y = y.reshape(*lead, p.scale.shape[-1]).astype(cd)
        return jax.lax.psum(y, axis)
    if p.act_bits:
        xf = fake_quant(xf, act_spec(p.act_bits))
    xl = jax.lax.dynamic_slice_in_dim(xf, idx * k_local, k_local,
                                      axis=xf.ndim - 1)
    # p is the LOCAL shard: unpack to k_local rows, not the global d_in
    w = unpack_int4(p.qweight, k_local, axis=-2) if p.packed else p.qweight
    return psum_matmul(xl, w.astype(cd) * p.scale.astype(cd))


def dense_params(p) -> jnp.ndarray:
    """Materialize the effective fp weight of either param kind (analysis)."""
    if isinstance(p, QLinear):
        return unpacked_qweight(p).astype(jnp.float32) * p.scale
    return jnp.asarray(p, jnp.float32)


def num_weight_bytes(p) -> int:
    if isinstance(p, QLinear):
        return p.qweight.size * p.qweight.dtype.itemsize + p.scale.size * 4
    return p.size * p.dtype.itemsize
