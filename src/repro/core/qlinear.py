"""Quantized linear dispatch — the bridge between the PTQ pipeline and the
model zoo.

Model code calls ``dense(p, x)`` for every linear layer. ``p`` is either a
raw jnp array ``V`` of shape (d_in, d_out) (fp path) or a ``QLinear``
pytree (serving path): int8 weight codes + per-output-channel scales +
an online activation transform + dynamic activation fake-quant. PTQ swaps
the params pytree; the model code is identical.

The jnp ops here are the *portable* path (and what the multi-pod dry-run
lowers). ``repro.kernels.ops`` provides the Pallas TPU fast path with the
same semantics (int8 MXU matmul with fused dequant epilogue).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import transforms as T
from .quantizers import QuantSpec, act_spec, fake_quant, unpack_int4


@dataclasses.dataclass(frozen=True)
class QLinear:
    qweight: jnp.ndarray          # int8 codes, (d_in, d_out) [or stacked (L, ...)];
                                  # int4-packed: (ceil(d_in/2), d_out), two nibbles/byte
    scale: jnp.ndarray            # f32, (1, d_out)
    transform: Any                # transform pytree acting on the input dim
    act_bits: int = 4             # static: dynamic per-token act quant bits (0 = off)
    w_bits: int = 8               # bit width of the stored weight codes
    d_in: int = 0                 # unpacked input dim when int4-packed; 0 = unpacked

    @property
    def packed(self) -> bool:
        return self.d_in > 0


jax.tree_util.register_dataclass(
    QLinear, data_fields=["qweight", "scale", "transform"],
    meta_fields=["act_bits", "w_bits", "d_in"]
)


def unpacked_qweight(p: QLinear) -> jnp.ndarray:
    """The int8 code tensor (..., d_in, d_out), unpacking int4 storage."""
    if p.packed:
        return unpack_int4(p.qweight, p.d_in, axis=-2)
    return p.qweight


def iter_qlinear(tree) -> list:
    """(path, QLinear) pairs for every QLinear leaf of a params pytree —
    the one tree walk shared by serving memory reports and checkpoint
    manifest flags."""
    out = []

    def walk(path, leaf):
        if isinstance(leaf, QLinear):
            out.append((path, leaf))
        return leaf

    jax.tree_util.tree_map_with_path(
        walk, tree, is_leaf=lambda x: isinstance(x, QLinear))
    return out


def fuse_weight_in(t, v: jnp.ndarray) -> jnp.ndarray:
    """Fuse T⁻¹ into an input-major weight V (d_in, d_out): V' = T⁻ᵀ V."""
    return T.fuse_weight(t, v.T).T


def dense(p, x: jnp.ndarray, compute_dtype=None) -> jnp.ndarray:
    """y = x @ V (fp) or the quantized equivalent (transform -> dyn act
    quant -> int8-weight matmul with dequant)."""
    if isinstance(p, QLinear):
        cd = compute_dtype or x.dtype
        x = T.apply(p.transform, x)
        if p.act_bits:
            x = fake_quant(x, act_spec(p.act_bits))
        w = unpacked_qweight(p).astype(cd) * p.scale.astype(cd)
        return x.astype(cd) @ w
    cd = compute_dtype or x.dtype
    return x @ p.astype(cd)


def dense_params(p) -> jnp.ndarray:
    """Materialize the effective fp weight of either param kind (analysis)."""
    if isinstance(p, QLinear):
        return unpacked_qweight(p).astype(jnp.float32) * p.scale
    return jnp.asarray(p, jnp.float32)


def num_weight_bytes(p) -> int:
    if isinstance(p, QLinear):
        return p.qweight.size * p.qweight.dtype.itemsize + p.scale.size * 4
    return p.size * p.dtype.itemsize
