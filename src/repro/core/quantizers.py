"""Uniform integer quantizers (the paper's Section 2 setting).

Supports every axis of the paper's quantization setup:
  * symmetric / asymmetric range
  * static (calibrated) / dynamic (per-call) range estimation
  * per-tensor / per-token (activations) / per-channel (weights) granularity
  * fake-quant (quantize->dequantize in fp, used for analysis & training
    numerics) and real int8 storage (used by the serving path)

W4 is represented as int4-range values stored in int8 (TPU v5e has no
native int4; values are exactly representable so accuracy is unaffected —
see DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

# Tiny epsilon guarding divide-by-zero on all-zero ranges.
_EPS = 1e-12


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Declarative description of one quantizer."""

    bits: int = 4
    symmetric: bool = True
    # Axis/axes that get *independent* quantization parameters.
    # For per-token activations of shape (..., tokens, d): channel_axis=-1
    # is REDUCED over, i.e. params are per leading index. We express it as
    # the axes to reduce when estimating ranges.
    per: str = "tensor"  # "tensor" | "token" | "channel"
    dynamic: bool = True
    # L_p norm-minimizing range search (GPTQ's L2.4 trick) — weights only.
    range_p: Optional[float] = None
    # Number of grid points for the L_p range search.
    range_grid: int = 64

    @property
    def n_levels(self) -> int:
        """N(b) = 2^b - 1 quantization intervals (paper notation)."""
        return 2**self.bits - 1

    @property
    def qmin(self) -> int:
        return -(2 ** (self.bits - 1)) if self.symmetric else 0

    @property
    def qmax(self) -> int:
        return 2 ** (self.bits - 1) - 1 if self.symmetric else 2**self.bits - 1


def _reduce_axes(x: jnp.ndarray, spec: QuantSpec) -> tuple:
    if spec.per == "tensor":
        return tuple(range(x.ndim))
    # "token": params per row => reduce the last (feature) axis.
    # "channel": params per output channel (row of W) => also reduce last.
    return (x.ndim - 1,)


def compute_scale_zp(x: jnp.ndarray, spec: QuantSpec):
    """Range estimation -> (scale, zero_point). Keeps reduced dims (size 1)."""
    axes = _reduce_axes(x, spec)
    if spec.range_p is not None:
        return _lp_optimal_scale(x, spec, axes)
    if spec.symmetric:
        amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
        scale = jnp.maximum(amax, _EPS) / spec.qmax
        zp = jnp.zeros_like(scale)
    else:
        xmin = jnp.min(x, axis=axes, keepdims=True)
        xmax = jnp.max(x, axis=axes, keepdims=True)
        scale = jnp.maximum(xmax - xmin, _EPS) / spec.n_levels
        zp = jnp.round(-xmin / scale)
    return scale, zp


def _lp_optimal_scale(x: jnp.ndarray, spec: QuantSpec, axes):
    """Grid-search the clipping range minimizing E|x - Q(x)|^p (p=2.4 per
    GPTQ / the paper's weight range estimation)."""
    p = spec.range_p
    amax = jnp.max(jnp.abs(x), axis=axes, keepdims=True)
    amax = jnp.maximum(amax, _EPS)
    fracs = jnp.linspace(0.35, 1.0, spec.range_grid)

    def err_for(frac):
        scale = amax * frac / spec.qmax
        q = jnp.clip(jnp.round(x / scale), spec.qmin, spec.qmax)
        err = jnp.abs(q * scale - x) ** p
        return jnp.sum(err, axis=axes, keepdims=True)

    errs = jax.vmap(err_for)(fracs)  # (grid, ...)
    best = jnp.argmin(errs, axis=0)  # (...)
    best_frac = fracs[best]
    scale = amax * best_frac / spec.qmax
    zp = jnp.zeros_like(scale)
    return scale, zp


def quantize(x: jnp.ndarray, spec: QuantSpec, scale=None, zp=None):
    """-> (q int8/int16/int32 codes, scale, zp). Static params may be passed."""
    if scale is None:
        scale, zp = compute_scale_zp(x, spec)
    if zp is None:
        zp = jnp.zeros_like(scale)
    q = jnp.round(x / scale + zp)
    q = jnp.clip(q, spec.qmin, spec.qmax)
    if spec.qmin >= -128 and spec.qmax <= 127:
        store = jnp.int8
    elif spec.qmin >= 0 and spec.qmax <= 255:
        store = jnp.uint8
    else:
        store = jnp.int32
    return q.astype(store), scale, zp


def dequantize(q: jnp.ndarray, scale: jnp.ndarray, zp: jnp.ndarray, dtype=jnp.float32):
    return ((q.astype(jnp.float32) - zp) * scale).astype(dtype)


def fake_quant(x: jnp.ndarray, spec: QuantSpec, scale=None, zp=None) -> jnp.ndarray:
    """Quantize-dequantize in the input dtype (the analysis workhorse)."""
    q, scale, zp = quantize(x, spec, scale, zp)
    return dequantize(q, scale, zp, x.dtype)


# ---------------------------------------------------------------------------
# Int4 packing (two nibbles per int8 byte, serving storage format)
# ---------------------------------------------------------------------------
#
# Layout: codes are packed pairwise along `axis` (default -2, the input/K
# dim of an input-major weight V (d_in, d_out)). Even index -> low nibble,
# odd index -> high nibble:  byte[i] = (q[2i] & 0xF) | (q[2i+1] << 4).
# Odd-sized axes are zero-padded before packing (code 0 dequantizes to 0,
# so padded rows are inert in any contraction).

def pack_int4(q: jnp.ndarray, axis: int = -2) -> jnp.ndarray:
    """Pack int4-range codes (int8 storage, values in [-8, 7]) two per byte
    along `axis`. Output size along `axis` is ceil(n/2)."""
    q = jnp.asarray(q)
    axis = axis % q.ndim
    if q.shape[axis] % 2:
        pad = [(0, 0)] * q.ndim
        pad[axis] = (0, 1)
        q = jnp.pad(q, pad)
    lo = jax.lax.slice_in_dim(q, 0, None, stride=2, axis=axis).astype(jnp.int32)
    hi = jax.lax.slice_in_dim(q, 1, None, stride=2, axis=axis).astype(jnp.int32)
    return ((lo & 0xF) | ((hi & 0xF) << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray, n: Optional[int] = None,
                axis: int = -2) -> jnp.ndarray:
    """Inverse of :func:`pack_int4`: -> int8 codes in [-8, 7], sized `n`
    along `axis` (pass the original size to strip odd-size padding)."""
    p = jnp.asarray(packed).astype(jnp.int32)
    axis = axis % p.ndim
    # ((v & 0xF) ^ 8) - 8 sign-extends a nibble without relying on
    # arithmetic-shift semantics (portable across interpret/Mosaic).
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    q = jnp.stack([lo, hi], axis=axis + 1)  # (..., n//2, 2, ...)
    shape = list(p.shape)
    shape[axis] *= 2
    q = q.reshape(shape).astype(jnp.int8)
    if n is not None:
        q = jax.lax.slice_in_dim(q, 0, n, axis=axis)
    return q


def quant_range(x: jnp.ndarray, spec: QuantSpec) -> jnp.ndarray:
    """r(x) from the paper: the full quantized interval size.

    Asymmetric: max - min. Symmetric: 2*max|x|. Per-token/channel: per row.
    Returns shape with reduced dims squeezed out.
    """
    axes = _reduce_axes(x, spec)
    if spec.symmetric:
        r = 2.0 * jnp.max(jnp.abs(x), axis=axes)
    else:
        r = jnp.max(x, axis=axes) - jnp.min(x, axis=axes)
    return r


# ---------------------------------------------------------------------------
# Paper defaults (Section 6 experimental setup)
# ---------------------------------------------------------------------------

def act_spec(bits: int = 4) -> QuantSpec:
    """Activations: dynamic, per-token, asymmetric."""
    return QuantSpec(bits=bits, symmetric=False, per="token", dynamic=True)


def weight_spec(bits: int = 4, range_p: Optional[float] = 2.4) -> QuantSpec:
    """Weights: static, per-channel, symmetric, L2.4 range estimation."""
    return QuantSpec(bits=bits, symmetric=True, per="channel", dynamic=False,
                     range_p=range_p)


def kv_spec(bits: int = 8) -> QuantSpec:
    """KV cache: dynamic per-token asymmetric (paper setup)."""
    return QuantSpec(bits=bits, symmetric=False, per="token", dynamic=True)


@partial(jax.jit, static_argnames=("bits",))
def fake_quant_act(x: jnp.ndarray, bits: int = 4) -> jnp.ndarray:
    return fake_quant(x, act_spec(bits))


@partial(jax.jit, static_argnames=("bits", "range_p"))
def fake_quant_weight(w: jnp.ndarray, bits: int = 4, range_p=2.4) -> jnp.ndarray:
    return fake_quant(w, weight_spec(bits, range_p))
