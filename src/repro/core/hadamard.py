"""Hadamard matrix constructions.

Model dims are rarely powers of two (2304, 5120, 14336, ...), so we build
H_n = H_{2^a} ⊗ H_m via Sylvester doubling plus Paley constructions for
the odd-part factor m (12, 20, 28, 36, 44, 60 cover every assigned
architecture's hidden/ff dims). Entries are ±1; `normalized` divides by
sqrt(n) to make the matrix orthonormal.

The TPU-native application is the Kronecker two-matmul form
   y = reshape(H_a @ X @ H_bᵀ)   for x reshaped to X (a, b),
which maps straight onto the MXU (see repro/kernels/hadamard.py).
"""
from __future__ import annotations

from functools import lru_cache

import numpy as np


def _is_prime(n: int) -> bool:
    if n < 2:
        return False
    for p in range(2, int(n**0.5) + 1):
        if n % p == 0:
            return False
    return True


def _quadratic_residues(q: int) -> np.ndarray:
    """χ(a) for a in 0..q-1: 0 if a=0, +1 if QR, -1 otherwise."""
    chi = -np.ones(q, dtype=np.int64)
    chi[0] = 0
    chi[np.unique((np.arange(1, q) ** 2) % q)] = 1
    return chi


def _jacobsthal(q: int) -> np.ndarray:
    chi = _quadratic_residues(q)
    idx = (np.arange(q)[:, None] - np.arange(q)[None, :]) % q
    return chi[idx]


def _paley_I(q: int) -> np.ndarray:
    """Order q+1, q prime ≡ 3 (mod 4)."""
    assert _is_prime(q) and q % 4 == 3
    Q = _jacobsthal(q)
    n = q + 1
    S = np.zeros((n, n), dtype=np.int64)
    S[0, 1:] = 1
    S[1:, 0] = -1
    S[1:, 1:] = Q
    H = S + np.eye(n, dtype=np.int64)
    return H


def _paley_II(q: int) -> np.ndarray:
    """Order 2(q+1), q prime ≡ 1 (mod 4)."""
    assert _is_prime(q) and q % 4 == 1
    Q = _jacobsthal(q)
    n = q + 1
    S = np.zeros((n, n), dtype=np.int64)
    S[0, 1:] = 1
    S[1:, 0] = 1
    S[1:, 1:] = Q
    # Substitute 2x2 blocks: 0 -> [[1,-1],[-1,-1]]; ±1 -> ±[[1,1],[1,-1]]
    Z = np.array([[1, -1], [-1, -1]], dtype=np.int64)
    P = np.array([[1, 1], [1, -1]], dtype=np.int64)
    H = np.zeros((2 * n, 2 * n), dtype=np.int64)
    for i in range(n):
        for j in range(n):
            H[2 * i : 2 * i + 2, 2 * j : 2 * j + 2] = Z if S[i, j] == 0 else S[i, j] * P
    return H


@lru_cache(maxsize=None)
def _base_hadamard(m: int) -> np.ndarray:
    """Hadamard matrix of order m for m in {1, 2} ∪ Paley-constructible."""
    if m == 1:
        return np.array([[1]], dtype=np.int64)
    if m == 2:
        return np.array([[1, 1], [1, -1]], dtype=np.int64)
    if m % 4 == 0 and _is_prime(m - 1) and (m - 1) % 4 == 3:
        return _paley_I(m - 1)
    if m % 4 == 0 and m % 2 == 0 and _is_prime(m // 2 - 1) and (m // 2 - 1) % 4 == 1:
        return _paley_II(m // 2 - 1)
    raise ValueError(f"no Hadamard construction for order {m}")


def _odd_part(n: int) -> tuple[int, int]:
    a = 0
    while n % 2 == 0:
        n //= 2
        a += 1
    return a, n


@lru_cache(maxsize=None)
def hadamard_matrix(n: int, normalized: bool = True) -> np.ndarray:
    """Hadamard matrix of order n (float64). n must be 1, 2, or have its
    odd part coverable by a Paley construction of order 4*odd or 8*odd."""
    a, m = _odd_part(n)
    if m == 1:
        H = _base_hadamard(2) if n >= 2 else _base_hadamard(1)
        while H.shape[0] < n:
            H = np.kron(_base_hadamard(2), H)
    else:
        base = None
        for mult in (4, 8, 16):  # order mult*m must divide n
            order = mult * m
            if n % order == 0:
                try:
                    base = _base_hadamard(order)
                    break
                except ValueError:
                    continue
        if base is None:
            raise ValueError(f"cannot build Hadamard of order {n} (odd part {m})")
        H = base
        while H.shape[0] < n:
            H = np.kron(_base_hadamard(2), H)
    assert H.shape[0] == n, (H.shape, n)
    H = H.astype(np.float64)
    return H / np.sqrt(n) if normalized else H


def hadamard_factors(n: int) -> tuple[np.ndarray, np.ndarray]:
    """(H_a, H_b) with H_n = H_a ⊗ H_b, both factors near sqrt(n) and
    individually constructible. Used by the Kronecker two-matmul fast path."""
    a2, m = _odd_part(n)
    # Put the (Paley) odd-order factor into H_b, pad with 2s to balance.
    if m == 1:
        fb = 1 << (a2 // 2)
    else:
        base_order = next(mult * m for mult in (4, 8, 16) if n % (mult * m) == 0)
        fb = base_order
        while fb * 2 <= n // fb and n % (fb * 2) == 0:
            fb *= 2
    fa = n // fb
    return hadamard_matrix(fa), hadamard_matrix(fb)


def is_hadamard_constructible(n: int) -> bool:
    try:
        hadamard_matrix(n)
        return True
    except ValueError:
        return False
