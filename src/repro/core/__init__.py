"""repro.core — the paper's contribution: the Concentration-Alignment
quantization framework (SQNR decomposition, CAT transforms, calibration,
GPTQ/RTN weight solvers, and the end-to-end PTQ pipeline).
"""
from . import cat, gptq, hadamard, qlinear, quantizers, sqnr, transforms  # noqa: F401
