"""CAT: Concentration-Alignment Transforms (paper Section 4).

The alignment-optimal invertible transform for a linear layer with weight
autocorrelation Σ_w = WᵀW and activation autocorrelation Σ_x = E[xxᵀ] is

    M̂ = (Σ_w # Σ_x⁻¹)^(1/2)

where # is the matrix geometric mean (Pusz & Woronowicz 1975):

    A # B = A^(1/2) (A^(-1/2) B A^(-1/2))^(1/2) A^(1/2).

M̂ satisfies  M̂ Σ_x M̂ = M̂⁻¹ Σ_w M̂⁻¹ = (Σ_x^(-1/2) Σ_w Σ_x^(-1/2))^(1/2)
(eq. 8) — it maps activation and weight variation into the same space.

The practical transform is the block-diagonal approximation composed with
a Hadamard rotation (rotations leave alignment invariant but restore
concentration):   T̂ᵏ_block = H · M̂ᵏ_block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _sym(a):
    return (a + a.T) / 2.0


def spd_power(a: jnp.ndarray, p: float, eps: float = 1e-9) -> jnp.ndarray:
    """A^p for symmetric PSD A via eigendecomposition, with eigenvalue floor
    eps * max(eig) for numerical robustness on rank-deficient Σ."""
    a = _sym(a.astype(jnp.float64) if a.dtype == jnp.float64 else a.astype(jnp.float32))
    lam, q = jnp.linalg.eigh(a)
    floor = jnp.maximum(jnp.max(lam), 0.0) * eps + 1e-30
    lam = jnp.maximum(lam, floor)
    return _sym((q * lam**p) @ q.T)


def geometric_mean(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Matrix geometric mean A # B for SPD A, B."""
    a_h = spd_power(a, 0.5)
    a_mh = spd_power(a, -0.5)
    mid = spd_power(a_mh @ _sym(b) @ a_mh, 0.5)
    return _sym(a_h @ mid @ a_h)


def cat_optimal(sigma_w: jnp.ndarray, sigma_x: jnp.ndarray) -> jnp.ndarray:
    """M̂ = (Σ_w # Σ_x⁻¹)^(1/2) — the full-rank alignment-optimal transform.

    Equivalent closed form used here (numerically friendlier):
        M̂² = Σ_x^(-1/2) (Σ_x^(1/2) Σ_w Σ_x^(1/2))^(1/2) Σ_x^(-1/2)
    which is exactly Σ_w # Σ_x⁻¹.
    """
    x_h = spd_power(sigma_x, 0.5)
    x_mh = spd_power(sigma_x, -0.5)
    mid = spd_power(x_h @ _sym(sigma_w) @ x_h, 0.5)
    m2 = _sym(x_mh @ mid @ x_mh)
    return spd_power(m2, 0.5)


def cat_diagonal(sigma_w: jnp.ndarray, sigma_x: jnp.ndarray) -> jnp.ndarray:
    """k=1 closed form: M̂¹ = Diag(m), m_i = (Σw_ii / Σx_ii)^(1/4).

    Derivation: minimizing ‖W M⁻¹‖_F² · E‖Mx‖² = (Σᵢ aᵢ/mᵢ²)(Σᵢ bᵢ mᵢ²)
    with aᵢ = Σⱼw²ⱼᵢ (column norms, diag of Σ_w) and bᵢ = E[xᵢ²] gives
    mᵢ ∝ (aᵢ/bᵢ)^(1/4) — exactly the scalar matrix geometric mean
    (a # 1/b)^(1/2) = (a/b)^(1/4), consistent with `cat_optimal` on
    diagonal inputs. (The paper's printed k=1 formula
    mᵢ = sqrt(E[xᵢ²]/Σⱼw²ᵢⱼ) appears to carry a typo — the inverse ratio —
    since it would *amplify* high-variance channels; tests verify our form
    matches `cat_optimal` restricted to diagonals.)
    """
    dw = jnp.diagonal(sigma_w)
    dx = jnp.diagonal(sigma_x)
    m = (dw / jnp.maximum(dx, 1e-30)) ** 0.25
    return jnp.diag(m)


def block_slices(d: int, k: int):
    """Partition [0, d) into ceil(d/k) contiguous blocks (last may be short)."""
    return [(i, min(i + k, d)) for i in range(0, d, k)]


def cat_block(sigma_w: jnp.ndarray, sigma_x: jnp.ndarray, k: int = 128) -> jnp.ndarray:
    """Block-diagonal M̂ᵏ_block: each k×k diagonal block of (Σ_w, Σ_x) gets
    its own optimal transform. Returns the full (d, d) block-diag matrix."""
    d = sigma_w.shape[0]
    if k >= d:
        return cat_optimal(sigma_w, sigma_x)
    if k == 1:
        return cat_diagonal(sigma_w, sigma_x)
    blocks = []
    for lo, hi in block_slices(d, k):
        blocks.append(cat_optimal(sigma_w[lo:hi, lo:hi], sigma_x[lo:hi, lo:hi]))
    return jax.scipy.linalg.block_diag(*blocks)


def cat_block_stacked(sigma_w: jnp.ndarray, sigma_x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Same as cat_block but returns (d//k, k, k) stacked blocks (the shape
    the block-diag Pallas kernel and the serving path consume). Requires
    k | d."""
    d = sigma_w.shape[0]
    assert d % k == 0, f"block size {k} must divide {d}"
    n = d // k
    sw = _extract_diag_blocks(sigma_w, n, k)
    sx = _extract_diag_blocks(sigma_x, n, k)
    return jax.vmap(cat_optimal)(sw, sx)


def _extract_diag_blocks(a: jnp.ndarray, n: int, k: int) -> jnp.ndarray:
    a = a.reshape(n, k, n, k)
    return jax.vmap(lambda i: a[i, :, i, :])(jnp.arange(n))


def blocks_to_dense(blocks: jnp.ndarray) -> jnp.ndarray:
    return jax.scipy.linalg.block_diag(*[blocks[i] for i in range(blocks.shape[0])])


def apply_block_diag(x: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    """y = x @ Mᵀ_blockdiag for x (..., d), blocks (n, k, k) — einsum form.
    (The Pallas kernel in repro.kernels.block_matmul is the TPU fast path.)

    Each output block_i = x_block_i @ blocks_i^T, i.e. y[..., i, a] =
    Σ_b blocks[i, a, b] x[..., i, b]   — matching y = M x for column vec x.
    """
    n, k, _ = blocks.shape
    shape = x.shape
    xb = x.reshape(*shape[:-1], n, k)
    yb = jnp.einsum("...nk,nak->...na", xb, blocks)
    return yb.reshape(shape)


def inv_blocks(blocks: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(jnp.linalg.inv)(blocks.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Σ_x estimation (streaming, calibration-time)
# ---------------------------------------------------------------------------

class CovAccumulator:
    """Streaming E[xxᵀ] (autocorrelation, not centered) + E[x²] + count.

    Host-side numpy accumulation in float64 — calibration sets are small
    (128 × 2048 tokens in the paper) and this runs once, offline.
    """

    def __init__(self, d: int):
        self.d = d
        self.sigma = np.zeros((d, d), dtype=np.float64)
        self.sq = np.zeros((d,), dtype=np.float64)
        self.amax = np.zeros((d,), dtype=np.float64)
        self.n = 0

    def update(self, x) -> None:
        x = np.asarray(x, dtype=np.float64).reshape(-1, self.d)
        self.sigma += x.T @ x
        self.sq += (x**2).sum(0)
        self.amax = np.maximum(self.amax, np.abs(x).max(0))
        self.n += x.shape[0]

    def cov(self) -> np.ndarray:
        assert self.n > 0, "no calibration data accumulated"
        return self.sigma / self.n

    def mean_sq(self) -> np.ndarray:
        return self.sq / self.n
