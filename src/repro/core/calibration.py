"""Calibration: run the model unrolled on a small calibration set and
collect, per tap point (layer-group input):

  * Σ_x = E[xxᵀ]  (drives CAT + GPTQ)
  * E[x²], per-channel absmax (drives SmoothQuant / diagnostics)
  * a bounded reservoir of raw rows (drives SQNR evaluation benchmarks)

The unrolled (eager) forward is the standard PTQ pattern — calibration is
an offline, once-per-model cost; models run layer-by-layer so activations
can be observed without retracing.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, Optional

import numpy as np

from .cat import CovAccumulator


@dataclasses.dataclass
class TapStats:
    cov: CovAccumulator
    samples: list
    max_sample_rows: int = 2048

    def update(self, x: np.ndarray) -> None:
        self.cov.update(x)
        have = sum(s.shape[0] for s in self.samples)
        if have < self.max_sample_rows:
            take = min(self.max_sample_rows - have, x.shape[0])
            idx = np.linspace(0, x.shape[0] - 1, take).astype(int)
            self.samples.append(x[idx].astype(np.float32))

    @property
    def sigma(self) -> np.ndarray:
        return self.cov.cov()

    @property
    def absmax(self) -> np.ndarray:
        return self.cov.amax

    @property
    def mean_sq(self) -> np.ndarray:
        return self.cov.mean_sq()

    def sample_matrix(self) -> np.ndarray:
        return np.concatenate(self.samples, axis=0)


class Taps:
    """Passed through model forward (unroll mode); collects named stats."""

    def __init__(self, max_sample_rows: int = 2048,
                 max_rows_per_call: int = 4096):
        self.stats: Dict[str, TapStats] = {}
        self.max_sample_rows = max_sample_rows
        self.max_rows_per_call = max_rows_per_call

    def record(self, name: str, x) -> None:
        arr = np.asarray(x, dtype=np.float32).reshape(-1, x.shape[-1])
        if arr.shape[0] > self.max_rows_per_call:
            idx = np.linspace(0, arr.shape[0] - 1,
                              self.max_rows_per_call).astype(int)
            arr = arr[idx]
        st = self.stats.get(name)
        if st is None:
            st = TapStats(CovAccumulator(arr.shape[1]), [],
                          self.max_sample_rows)
            self.stats[name] = st
        st.update(arr)

    def __getitem__(self, name: str) -> TapStats:
        return self.stats[name]

    def names(self):
        return sorted(self.stats)


def calibrate(model, params, batches: Iterable[dict],
              taps: Optional[Taps] = None) -> Taps:
    """Run the model unrolled over calibration batches, collecting taps."""
    import jax.numpy as jnp
    taps = taps or Taps()
    for batch in batches:
        kw = {}
        if "enc_embed" in batch:
            kw["enc_embed"] = jnp.asarray(batch["enc_embed"])
        if "patch_embed" in batch:
            kw["extra_embed"] = jnp.asarray(batch["patch_embed"])
        model.forward(params, jnp.asarray(batch["tokens"]), taps=taps,
                      unroll=True, **kw)
    return taps
