"""Function-preserving linear transforms for quantization (paper Section 3-4).

A transform T acts on a linear layer as  Wx = (W T⁻¹)(T x): the inverse is
fused into the weights offline, T is applied to activations online (or
fused into a preceding op when diagonal).

Conventions: activations are row-major batches x of shape (..., d), so
  apply(t, x)          = x @ Tᵀ          ("T x" in column-vector math)
  fuse_weight(t, W)    = W @ T⁻¹          for W of shape (d_out, d_in)
  fuse_cov(t, Σ)       = T Σ Tᵀ           (transformed E[xxᵀ])

All transform objects are JAX pytrees (registered dataclasses) so they can
live inside jitted serving parameter trees.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import cat as cat_lib
from .hadamard import hadamard_factors, hadamard_matrix


def _register(cls, data_fields, meta_fields=()):
    return jax.tree_util.register_dataclass(
        cls, data_fields=list(data_fields), meta_fields=list(meta_fields)
    )


@dataclasses.dataclass(frozen=True)
class Identity:
    pass


_register(Identity, [])


@dataclasses.dataclass(frozen=True)
class Scale:
    """T = Diag(s): per-channel scaling (SmoothQuant / CAT k=1 family)."""

    s: jnp.ndarray  # (d,)


_register(Scale, ["s"])


@dataclasses.dataclass(frozen=True)
class Dense:
    """Arbitrary invertible T (full CAT, random rotations)."""

    t: jnp.ndarray      # (d, d)
    t_inv: jnp.ndarray  # (d, d)


_register(Dense, ["t", "t_inv"])


@dataclasses.dataclass(frozen=True)
class Hadamard:
    """Randomized orthonormal Hadamard T = H_norm · Diag(sign).

    Stored in Kronecker-factored form (H = Ha ⊗ Hb) — the full matrix is
    never materialized for large d. sign=None disables randomization.
    """

    ha: jnp.ndarray  # (a, a) orthonormal
    hb: jnp.ndarray  # (b, b) orthonormal
    sign: jnp.ndarray  # (d,) ±1


_register(Hadamard, ["ha", "hb", "sign"])


@dataclasses.dataclass(frozen=True)
class BlockDiag:
    """T = Diag(M_1..M_{d/k}) — the CAT block transform."""

    blocks: jnp.ndarray      # (n, k, k)
    inv_blocks: jnp.ndarray  # (n, k, k)


_register(BlockDiag, ["blocks", "inv_blocks"])


@dataclasses.dataclass(frozen=True)
class Compose:
    """T = parts[-1] · ... · parts[0]  (parts[0] applied first)."""

    parts: Tuple


_register(Compose, ["parts"])


Transform = (Identity, Scale, Dense, Hadamard, BlockDiag, Compose)


# ---------------------------------------------------------------------------
# Constructors
# ---------------------------------------------------------------------------

def make_hadamard(d: int, rng: np.random.Generator | None = None) -> Hadamard:
    ha, hb = hadamard_factors(d)
    sign = (
        rng.integers(0, 2, size=d).astype(np.float32) * 2 - 1
        if rng is not None
        else np.ones(d, dtype=np.float32)
    )
    return Hadamard(jnp.asarray(ha, jnp.float32), jnp.asarray(hb, jnp.float32),
                    jnp.asarray(sign))


def make_rotation(d: int, rng: np.random.Generator) -> Dense:
    """Random orthogonal matrix (SpinQuant-style, untrained)."""
    q, r = np.linalg.qr(rng.standard_normal((d, d)))
    q = q * np.sign(np.diag(r))[None, :]
    t = jnp.asarray(q, jnp.float32)
    return Dense(t, t.T)


def make_smoothquant(act_absmax: jnp.ndarray, weight_absmax: jnp.ndarray,
                     alpha: float = 0.5) -> Scale:
    """SmoothQuant: divide activations by s, multiply weights.
    s_i = max|x_i|^α / max_j|w_ji|^(1-α)  ⇒  T = Diag(1/s)."""
    s = jnp.maximum(act_absmax, 1e-5) ** alpha / jnp.maximum(
        weight_absmax, 1e-5) ** (1 - alpha)
    s = jnp.maximum(s, 1e-5)
    return Scale(1.0 / s)


def make_cat_full(sigma_w, sigma_x) -> Dense:
    m = cat_lib.cat_optimal(sigma_w, sigma_x)
    return Dense(m, jnp.linalg.inv(m))


def make_cat_block(sigma_w, sigma_x, k: int = 128,
                   hadamard: bool = True,
                   rng: np.random.Generator | None = None):
    """The paper's T̂ᵏ_block = H · M̂ᵏ_block (eq. 10)."""
    d = sigma_w.shape[0]
    if d % k != 0:  # fall back to the largest divisor ≤ k
        k = max(j for j in range(1, k + 1) if d % j == 0)
    if k == 1:
        m = jnp.diagonal(cat_lib.cat_diagonal(sigma_w, sigma_x))
        mt: object = Scale(m)
    else:
        blocks = cat_lib.cat_block_stacked(sigma_w, sigma_x, k)
        mt = BlockDiag(blocks, cat_lib.inv_blocks(blocks))
    if not hadamard:
        return mt
    return Compose((mt, make_hadamard(d, rng)))


# ---------------------------------------------------------------------------
# Application / fusion
# ---------------------------------------------------------------------------

def apply(t, x: jnp.ndarray) -> jnp.ndarray:
    """Online activation transform: x -> x @ Tᵀ (leading dims preserved)."""
    if isinstance(t, Identity):
        return x
    if isinstance(t, Scale):
        return x * t.s.astype(x.dtype)
    if isinstance(t, Dense):
        return x @ t.t.T.astype(x.dtype)
    if isinstance(t, Hadamard):
        return _hadamard_apply(x * t.sign.astype(x.dtype), t.ha, t.hb)
    if isinstance(t, BlockDiag):
        return cat_lib.apply_block_diag(x, t.blocks.astype(x.dtype))
    if isinstance(t, Compose):
        for p in t.parts:
            x = apply(p, x)
        return x
    raise TypeError(type(t))


def _hadamard_apply(x: jnp.ndarray, ha: jnp.ndarray, hb: jnp.ndarray) -> jnp.ndarray:
    """y = x @ Hᵀ with H = ha ⊗ hb:  Y = ha @ X @ hbᵀ on X = x.reshape(a, b)."""
    a, b = ha.shape[0], hb.shape[0]
    shape = x.shape
    xr = x.reshape(*shape[:-1], a, b)
    y = jnp.einsum("ij,...jk,lk->...il", ha.astype(x.dtype), xr, hb.astype(x.dtype))
    return y.reshape(shape)


def fuse_weight(t, w: jnp.ndarray) -> jnp.ndarray:
    """Offline: W -> W T⁻¹ so that (W T⁻¹)(T x) = W x. W: (d_out, d_in)."""
    if isinstance(t, Identity):
        return w
    if isinstance(t, Scale):
        return w / t.s[None, :].astype(w.dtype)
    if isinstance(t, Dense):
        return w @ t.t_inv.astype(w.dtype)
    if isinstance(t, Hadamard):
        # T = H·Diag(sign) ⇒ T⁻¹ = Diag(sign)·Hᵀ ⇒ W T⁻¹ = (W·Diag(sign))·Hᵀ.
        return _hadamard_apply(w * t.sign[None, :].astype(w.dtype), t.ha, t.hb)
    if isinstance(t, BlockDiag):
        n, k, _ = t.inv_blocks.shape
        d_out = w.shape[0]
        wb = w.reshape(d_out, n, k)
        out = jnp.einsum("onk,nkb->onb", wb, t.inv_blocks.astype(w.dtype))
        return out.reshape(d_out, n * k)
    if isinstance(t, Compose):
        for p in t.parts:
            w = fuse_weight(p, w)
        return w
    raise TypeError(type(t))


def fuse_cov(t, sigma: jnp.ndarray) -> jnp.ndarray:
    """Σ -> T Σ Tᵀ (autocorrelation of transformed activations)."""
    if isinstance(t, Identity):
        return sigma
    if isinstance(t, Scale):
        return sigma * t.s[:, None] * t.s[None, :]
    if isinstance(t, Dense):
        return t.t @ sigma @ t.t.T
    if isinstance(t, Hadamard):
        d = sigma.shape[0]
        s = sigma * t.sign[:, None] * t.sign[None, :]
        s = _hadamard_apply(s, t.ha, t.hb)       # rows
        s = _hadamard_apply(s.T, t.ha, t.hb).T   # cols
        return s
    if isinstance(t, BlockDiag):
        dense = cat_lib.blocks_to_dense(t.blocks)
        return dense @ sigma @ dense.T
    if isinstance(t, Compose):
        for p in t.parts:
            sigma = fuse_cov(p, sigma)
        return sigma
    raise TypeError(type(t))


def as_dense_matrix(t, d: int) -> jnp.ndarray:
    """Materialize T as a (d, d) matrix — tests/small models only."""
    return apply(t, jnp.eye(d, dtype=jnp.float32).reshape(d, d)).T


def online_flops(t, d: int) -> float:
    """Serving-time FLOPs per token for the online transform."""
    if isinstance(t, Identity):
        return 0.0
    if isinstance(t, Scale):
        return d
    if isinstance(t, Dense):
        return 2.0 * d * d
    if isinstance(t, Hadamard):
        a, b = t.ha.shape[0], t.hb.shape[0]
        return 2.0 * d * (a + b) + d
    if isinstance(t, BlockDiag):
        n, k, _ = t.blocks.shape
        return 2.0 * n * k * k
    if isinstance(t, Compose):
        return sum(online_flops(p, d) for p in t.parts)
    raise TypeError(type(t))
