"""SQNR / Concentration / Alignment framework (paper Section 2).

All quantities operate on a weight matrix ``W`` of shape (d_out, d_in) and
a batch of activations ``X`` of shape (n, d_in) treated as samples from
p(x). Expectations are empirical means over the n samples.

Decibel convention: dB(v) = 10 log10(v).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .quantizers import QuantSpec, act_spec, weight_spec, fake_quant, quant_range

_EPS = 1e-30


def db(v):
    return 10.0 * jnp.log10(jnp.maximum(v, _EPS))


def parallel(a, b):
    """Harmonic sum a ∥ b = (1/a + 1/b)^-1 (Lemma 2.1)."""
    return 1.0 / (1.0 / a + 1.0 / b)


# ---------------------------------------------------------------------------
# Measured SQNR (definition, eq. 1)
# ---------------------------------------------------------------------------

def sqnr_measured(W, X, Wq, Xq):
    """SQNR(W̃x̃) = E||Wx||² / E||Wx - W̃x̃||²  with empirical E over rows of X."""
    y = X @ W.T
    yq = Xq @ Wq.T
    sig = jnp.mean(jnp.sum(y.astype(jnp.float32) ** 2, axis=-1))
    noise = jnp.mean(jnp.sum((y - yq).astype(jnp.float32) ** 2, axis=-1))
    return sig / jnp.maximum(noise, _EPS)


def sqnr_quantized_layer(W, X, wspec: QuantSpec, xspec: QuantSpec):
    """Measured joint SQNR under fake quantization of both operands."""
    return sqnr_measured(W, X, fake_quant(W, wspec), fake_quant(X, xspec))


def sqnr_act_only(W, X, xspec: QuantSpec):
    return sqnr_measured(W, X, W, fake_quant(X, xspec))


def sqnr_weight_only(W, X, wspec: QuantSpec):
    return sqnr_measured(W, X, fake_quant(W, wspec), X)


# ---------------------------------------------------------------------------
# The three factors (Lemmas 2.2, 2.3)
# ---------------------------------------------------------------------------

def n_levels(bits: int) -> float:
    return float(2**bits - 1)


def concentration_act(X, xspec: QuantSpec):
    """C(x) = E||x||² / E[r(x)²]; r per token for per-token quant."""
    norm2 = jnp.mean(jnp.sum(X.astype(jnp.float32) ** 2, axis=-1))
    r = quant_range(X, xspec).astype(jnp.float32)
    return norm2 / jnp.maximum(jnp.mean(r**2), _EPS)


def concentration_weight(W, wspec: QuantSpec):
    """C(W) = Σᵢ||wᵢ||² / Σᵢ r(wᵢ)² over rows (output channels)."""
    norms = jnp.sum(W.astype(jnp.float32) ** 2, axis=-1)
    r = quant_range(W, wspec).astype(jnp.float32)
    return jnp.sum(norms) / jnp.maximum(jnp.sum(r**2), _EPS)


def alignment(W, X):
    """A(x, W) = E||Wx||² / (||W||_F² E||x||²)  (second-order alignment)."""
    Wf = W.astype(jnp.float32)
    Xf = X.astype(jnp.float32)
    num = jnp.mean(jnp.sum((Xf @ Wf.T) ** 2, axis=-1))
    den = jnp.sum(Wf**2) * jnp.mean(jnp.sum(Xf**2, axis=-1))
    return num / jnp.maximum(den, _EPS)


def alignment_from_cov(W, sigma_x):
    """A(x,W) computed from the activation autocorrelation Σ_x = E[xxᵀ]:
    A = Tr(W Σ_x Wᵀ) / (||W||_F² Tr(Σ_x))."""
    Wf = W.astype(jnp.float32)
    S = sigma_x.astype(jnp.float32)
    num = jnp.trace(Wf @ S @ Wf.T)
    den = jnp.sum(Wf**2) * jnp.trace(S)
    return num / jnp.maximum(den, _EPS)


def alignment_optimal(W, sigma_x):
    """Best achievable alignment (eq. 9): A* = Σμᵢ² / (Σμᵢ)² over the
    eigenvalues μ of G = (Σx^½ Σw Σx^½)^½ — equivalently μᵢ = √λᵢ with λ
    the eigenvalues of Σ_y = W Σ_x Wᵀ.

    Note: the paper's eq. 9 prints Σλᵢ²/(Σλᵢ)² with λ "eigenvalues of Σ_y",
    which does not match what M̂ attains; the geometric-mean derivation
    (min ‖WM⁻¹‖_F²·E‖Mx‖² = Tr(G)²) gives A* = Tr(ΣwΣx)/Tr(G)² =
    Σλ/(Σ√λ)². We verified numerically that CAT-transformed layers attain
    the √λ form exactly (tests/test_core_transforms.py), so we implement
    that; the printed form overstates the bound.
    """
    Wf = W.astype(jnp.float32)
    sy = Wf @ sigma_x.astype(jnp.float32) @ Wf.T
    lam = jnp.linalg.eigvalsh((sy + sy.T) / 2.0)
    mu = jnp.sqrt(jnp.maximum(lam, 0.0))
    return jnp.sum(mu**2) / jnp.maximum(jnp.sum(mu) ** 2, _EPS)


# ---------------------------------------------------------------------------
# Theorem 2.4 approximation
# ---------------------------------------------------------------------------

def sqnr_approx_act(W, X, xspec: QuantSpec):
    """Lemma 2.2: SQNR(Wx̃) ≈ 12 N(b_x)² C(x) A(x,W)."""
    return 12.0 * n_levels(xspec.bits) ** 2 * concentration_act(X, xspec) * alignment(W, X)


def sqnr_approx_weight(W, X, wspec: QuantSpec):
    """Lemma 2.3: SQNR(W̃x) ≈ 12 N(b_w)² C(W) A(x,W)."""
    return 12.0 * n_levels(wspec.bits) ** 2 * concentration_weight(W, wspec) * alignment(W, X)


def sqnr_approx_joint(W, X, wspec: QuantSpec, xspec: QuantSpec):
    """Theorem 2.4: 12 (N(b_x)²C(x) ∥ N(b_w)²C(W)) A(x,W)."""
    cx = n_levels(xspec.bits) ** 2 * concentration_act(X, xspec)
    cw = n_levels(wspec.bits) ** 2 * concentration_weight(W, wspec)
    return 12.0 * parallel(cx, cw) * alignment(W, X)


def sqnr_ratio(W, X, wspec: QuantSpec, xspec: QuantSpec):
    """r(x, W) = SQNR(Wx̃)/SQNR(W̃x) (eq. 2): <1 ⇒ activations dominate."""
    return sqnr_approx_act(W, X, xspec) / sqnr_approx_weight(W, X, wspec)


def layer_report(W, X, bits_w=4, bits_x=4):
    """Full per-layer diagnostic used by benchmarks & tests."""
    wspec, xspec = weight_spec(bits_w), act_spec(bits_x)
    sigma_x = (X.astype(jnp.float32).T @ X.astype(jnp.float32)) / X.shape[0]
    return {
        "sqnr_measured_db": db(sqnr_quantized_layer(W, X, wspec, xspec)),
        "sqnr_approx_db": db(sqnr_approx_joint(W, X, wspec, xspec)),
        "sqnr_act_db": db(sqnr_act_only(W, X, xspec)),
        "sqnr_weight_db": db(sqnr_weight_only(W, X, wspec)),
        "concentration_x_db": db(concentration_act(X, xspec)),
        "concentration_w_db": db(concentration_weight(W, wspec)),
        "alignment_db": db(alignment(W, X)),
        "alignment_optimal_db": db(alignment_optimal(W, sigma_x)),
    }
