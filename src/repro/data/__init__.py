from repro.data.synthetic import calibration_batches, make_batch, token_stream  # noqa: F401
