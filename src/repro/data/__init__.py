from repro.data.synthetic import (calibration_batches, make_batch,  # noqa: F401
                                  request_workload, token_stream)
