"""Deterministic synthetic data pipeline.

Structured synthetic tokens (a mixture of Zipfian unigrams and repeated
motifs) so models trained on it exhibit non-trivial, learnable statistics
(the PTQ benchmarks need a trained model whose activations have realistic
correlations/outliers). Deterministic per (seed, step) => bit-exact
restart after failure, any host can regenerate any shard (fault tolerance
without a data service).
"""
from __future__ import annotations

import numpy as np


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    r = np.arange(1, vocab + 1, dtype=np.float64)
    p = r ** (-alpha)
    return p / p.sum()


def token_stream(vocab: int, seq_len: int, batch: int, *, seed: int = 0,
                 step: int = 0, motif_len: int = 16, n_motifs: int = 64):
    """-> tokens (batch, seq_len) int32. Mixture: 60% motif copies (learnable
    structure), 40% zipf noise."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    motif_rng = np.random.default_rng(seed)  # motifs fixed across steps
    motifs = motif_rng.integers(0, vocab, size=(n_motifs, motif_len))
    probs = _zipf_probs(vocab)
    out = np.empty((batch, seq_len), dtype=np.int64)
    for b in range(batch):
        toks = []
        while sum(len(t) for t in toks) < seq_len:
            if rng.random() < 0.6:
                toks.append(motifs[rng.integers(n_motifs)])
            else:
                toks.append(rng.choice(vocab, size=motif_len, p=probs))
        out[b] = np.concatenate(toks)[:seq_len]
    return out.astype(np.int32)


def make_batch(cfg, seq_len: int, batch: int, *, seed: int = 0,
               step: int = 0) -> dict:
    """Training batch for any arch family (adds stub modality inputs)."""
    toks = token_stream(cfg.vocab, seq_len + 1, batch, seed=seed, step=step)
    out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "encdec":
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, 1]))
        out["enc_embed"] = rng.standard_normal(
            (batch, cfg.enc_seq, cfg.d_model)).astype(np.float32)
    if cfg.family == "vlm":
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, 2]))
        out["patch_embed"] = rng.standard_normal(
            (batch, cfg.n_patches, cfg.d_model)).astype(np.float32) * 0.02
    return out


def request_workload(cfg, n_requests: int = 8, *, gen: int = 16,
                     lengths: tuple = (8, 12, 16, 24), min_gen: int = 0,
                     seed: int = 0, shared_prefix: int = 0) -> list:
    """Mixed-prompt-length serving workload for the continuous-batching
    engine: a list of ``{"rid", "tokens" (P,) int32, "max_new_tokens"}``.

    Prompt lengths are drawn from the small ``lengths`` set (every
    distinct length costs one prefill compile in the engine); decode
    budgets are uniform in [min_gen or gen, gen]. Deterministic per
    (seed, rid): request ``rid``'s tokens do not depend on n_requests, so
    a prefix of the workload is a smaller workload.

    ``shared_prefix > 0`` prepends that many common "system prompt"
    tokens (identical across all requests, deterministic per seed) to
    every per-request suffix — the workload the paged engine's prefix
    cache deduplicates."""
    common = (token_stream(cfg.vocab, shared_prefix, 1, seed=seed,
                           step=999)[0] if shared_prefix else None)
    reqs = []
    for rid in range(n_requests):
        rng = np.random.default_rng(np.random.SeedSequence([seed, 7, rid]))
        p = int(rng.choice(lengths))
        toks = token_stream(cfg.vocab, p, 1, seed=seed, step=1000 + rid)[0]
        if common is not None:
            toks = np.concatenate([common, toks])
        g = int(rng.integers(min_gen, gen + 1)) if min_gen else gen
        reqs.append({"rid": rid, "tokens": toks, "max_new_tokens": g})
    return reqs


def calibration_batches(cfg, n_seqs: int = 16, seq_len: int = 128,
                        batch: int = 4, seed: int = 1234):
    """The paper uses 128 x 2048-token calibration sequences; smoke-scale
    defaults here, overridable."""
    for step in range(-(-n_seqs // batch)):
        yield make_batch(cfg, seq_len, batch, seed=seed, step=step)
