from repro.optim.optimizer import AdamW, warmup_cosine  # noqa: F401
