"""AdamW with warmup-cosine schedule and global-norm clipping.

Self-contained (no optax in this environment). States are f32; params may
be f32 or bf16 (updates computed in f32 and cast back).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


def warmup_cosine(base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / jnp.maximum(warmup, 1)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1),
                        0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return lr


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        step = state["step"] + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], gf)
        t = step.astype(jnp.float32)
        mhat_c = 1.0 / (1 - b1 ** t)
        vhat_c = 1.0 / (1 - b2 ** t)

        def upd(p, m_, v_):
            u = (m_ * mhat_c) / (jnp.sqrt(v_ * vhat_c) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}


@dataclasses.dataclass(frozen=True)
class AdamWMaster(AdamW):
    """Mixed-precision variant: bf16 working params, f32 master copy kept
    in the optimizer state (ZeRO-1 friendly — master/m/v all carry an
    extra data-axis sharding; GSPMD turns the update into
    reduce-scatter(grads) -> sharded update -> all-gather(params))."""

    def init(self, params) -> dict:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params),
                "master": jax.tree.map(lambda p: p.astype(jnp.float32),
                                       params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, params, grads, state):
        step = state["step"] + 1
        gf = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm:
            gn = jnp.sqrt(sum(jnp.sum(g * g) for g in jax.tree.leaves(gf)))
            scale = jnp.minimum(1.0, self.clip_norm / (gn + 1e-9))
            gf = jax.tree.map(lambda g: g * scale, gf)
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g,
                         state["m"], gf)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g,
                         state["v"], gf)
        t = step.astype(jnp.float32)
        mhat_c = 1.0 / (1 - b1 ** t)
        vhat_c = 1.0 / (1 - b2 ** t)

        def upd(mast, m_, v_):
            u = (m_ * mhat_c) / (jnp.sqrt(v_ * vhat_c) + self.eps)
            return mast - lr * (u + self.weight_decay * mast)

        master = jax.tree.map(upd, state["master"], m, v)
        new_params = jax.tree.map(lambda mast, p: mast.astype(p.dtype),
                                  master, params)
        return new_params, {"m": m, "v": v, "master": master, "step": step}


def cast_params(params, dtype):
    """Cast float params (not int codes / not norms' f32 need) to dtype."""
    def cast(p):
        if p.dtype in (jnp.float32, jnp.float64, jnp.bfloat16, jnp.float16):
            return p.astype(dtype)
        return p
    return jax.tree.map(cast, params)
