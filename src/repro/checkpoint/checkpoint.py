"""Shard-aware checkpointing without external deps.

Layout:  <dir>/step_<N>/
           manifest.json      — step, flat key list, dtypes/shapes, meta
           <group>.npz        — flattened param arrays (host shards)

On a real multi-host cluster each process writes only its addressable
shards (key-sliced by process index); restore device_puts with the target
mesh's NamedSharding — which also implements *elastic* restarts onto a
different mesh size (arrays are stored unsharded per host group).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np

_SEP = "/"


_NATIVE_KINDS = set("fiub?c")


def _path_key(path) -> str:
    """Flat manifest key for a tree_map_with_path entry path."""
    return _SEP.join(str(getattr(e, "key", getattr(e, "idx", e)))
                     for e in path)


def _flatten(tree) -> dict:
    flat = {}

    def walk(path, leaf):
        flat[_path_key(path)] = np.asarray(leaf)
        return leaf

    jax.tree_util.tree_map_with_path(walk, tree)
    return flat


def _encode(arr: np.ndarray) -> np.ndarray:
    """npz can't store ml_dtypes (bfloat16 etc.) — ship raw bytes; the
    manifest carries the true dtype/shape for decode."""
    if arr.dtype.kind in _NATIVE_KINDS:
        return arr
    return np.ascontiguousarray(arr).view(np.uint8)


def _decode(raw: np.ndarray, dtype: str, shape) -> np.ndarray:
    want = np.dtype(dtype)
    if raw.dtype.kind in _NATIVE_KINDS and raw.dtype == want:
        return raw
    return raw.view(want).reshape(shape)


def _packed_int4_layers(tree) -> list:
    """Flat keys of int4-packed QLinear leaves (their qweight buffers are
    nibble-packed int8 — consumers must unpack along the input dim)."""
    from repro.core.qlinear import iter_qlinear
    return [_path_key(path) for path, leaf in iter_qlinear(tree)
            if leaf.packed]


def save(ckpt_dir: str, step: int, params, opt_state=None,
         meta: Optional[dict] = None) -> str:
    """Atomic save (write to tmp, rename)."""
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_ckpt_")
    groups = {"params": params}
    if opt_state is not None:
        groups["opt_state"] = opt_state
    meta = dict(meta or {})
    packed = _packed_int4_layers(params)
    meta["packed_int4"] = bool(packed)
    if packed:
        meta["packed_int4_layers"] = packed
    manifest: dict[str, Any] = {"step": step, "meta": meta,
                                "groups": {}}
    for gname, tree in groups.items():
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, f"{gname}.npz"),
                 **{k: _encode(v) for k, v in flat.items()})
        manifest["groups"][gname] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: Optional[int], like_params,
            like_opt_state=None, shardings=None):
    """Restore into the structure of `like_*` (treedefs must match).
    `shardings`: optional {"params": tree, "opt_state": tree} of
    NamedShardings — device_puts each leaf (elastic re-mesh path)."""
    if step is None:
        step = latest_step(ckpt_dir)
        assert step is not None, f"no checkpoints under {ckpt_dir}"
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    def load_group(gname, like, shard_tree):
        data = np.load(os.path.join(d, f"{gname}.npz"))
        leaves_paths = []

        def collect(path, leaf):
            leaves_paths.append(_path_key(path))
            return leaf

        jax.tree_util.tree_map_with_path(collect, like)
        flat_shards = (jax.tree.leaves(shard_tree) if shard_tree is not None
                       else [None] * len(leaves_paths))
        info = manifest["groups"][gname]
        arrays = []
        for key, sh in zip(leaves_paths, flat_shards):
            arr = _decode(data[key], info[key]["dtype"],
                          tuple(info[key]["shape"]))
            arrays.append(jax.device_put(arr, sh) if sh is not None
                          else jax.numpy.asarray(arr))
        return jax.tree.unflatten(jax.tree.structure(like), arrays)

    shardings = shardings or {}
    params = load_group("params", like_params, shardings.get("params"))
    out = {"step": manifest["step"], "params": params,
           "meta": manifest["meta"]}
    if like_opt_state is not None:
        out["opt_state"] = load_group("opt_state", like_opt_state,
                                      shardings.get("opt_state"))
    return out


def prune_old(ckpt_dir: str, keep: int = 3) -> None:
    if not os.path.isdir(ckpt_dir):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
