"""Pallas TPU kernel: fused per-token dynamic quantization.

One VMEM pass per token tile: row min/max reduction (VPU), scale/zero-point
computation, round+clip, int8 store. This is the activation-quant hot path
that runs before every quantized matmul at serve time (paper setup:
dynamic, per-token, asymmetric).

Codes are stored signed (shifted by 2^(b-1)) so the downstream int8 MXU
matmul consumes them directly; the zero-point is shifted to match
(see ref.dynamic_quant).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _dq_kernel(bits: float, symmetric: bool, x_ref, q_ref, s_ref, z_ref):
    x = x_ref[...].astype(jnp.float32)
    if symmetric:
        qmax = 2.0 ** (bits - 1) - 1
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-12) / qmax
        zp = jnp.zeros_like(scale)
        q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax)
    else:
        levels = 2.0**bits - 1
        xmin = jnp.min(x, axis=-1, keepdims=True)
        xmax = jnp.max(x, axis=-1, keepdims=True)
        scale = jnp.maximum(xmax - xmin, 1e-12) / levels
        zp = jnp.round(-xmin / scale)
        q = jnp.clip(jnp.round(x / scale + zp), 0, levels) - 2.0 ** (bits - 1)
        zp = zp - 2.0 ** (bits - 1)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[...] = scale
    z_ref[...] = zp


@functools.partial(jax.jit, static_argnames=("bits", "symmetric",
                                             "block_tokens", "interpret"))
def dynamic_quant(x: jnp.ndarray, bits: int = 8, symmetric: bool = False,
                  block_tokens: int = 256, interpret: bool = True):
    """-> (q int8 (..., d), scale f32 (..., 1), zp f32 (..., 1))."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    m = xf.shape[0]
    tm = min(block_tokens, max(m, 1))
    pad = (-m) % tm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)), constant_values=1.0)
    grid = (xf.shape[0] // tm,)
    kern = functools.partial(_dq_kernel, float(bits), symmetric)
    q, s, z = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[pl.BlockSpec((tm, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xf.shape, jnp.int8),
            jax.ShapeDtypeStruct((xf.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((xf.shape[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(xf)
    if pad:
        q, s, z = q[:m], s[:m], z[:m]
    return (q.reshape(*lead, d), s.reshape(*lead, 1), z.reshape(*lead, 1))
