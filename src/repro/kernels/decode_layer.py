"""Pallas TPU kernel: the whole decode-attention QKV prologue in ONE launch.

``decode_qkv_prologue`` extends ``fused_cat_gemv_w4``'s scratch dataflow
through everything that sits between the hidden state and the paged
attention kernel on the decode path: block-CAT/Hadamard transform ->
dynamic act quant -> packed W4A8 QKV GEMV -> RoPE(q, k) -> symmetric
int8 KV quantization -> scatter of the new K/V rows into the paged pool
via the scalar-prefetched page table. With it, a transformer layer's
decode attention block is exactly **two** Pallas launches: this prologue
and the existing online-softmax paged attention — the composed path's
XLA glue (rope, quantize, 4 scatter dispatches) disappears into the
prologue's epilogue.

Dataflow (grid (gn, gk, M) with the row axis r innermost so weight
blocks are DMA'd once per (j, kk) — Pallas skips re-fetch while the
block index is unchanged):

    (j, kk, r) == (0, 0, 0):                   # once per launch
        x (8, D) --HBM--> VMEM -> CAT -> sign ⊙ -> Hadamard
        -> per-token asym quant -> qx/sx/zx scratch
    every (j, kk) at r == 0:                   # the contraction
        qw block (TK/2, TN) --HBM--> VMEM -> unpack
        acc[:, j·TN:..] (+)= sx·sw·(qx @ qw − zx·colsum)
    last (j, kk) at r == 0:                    # the epilogue
        acc -> split q|k|v columns -> RoPE(q, k) with per-row positions
        -> q out; quantize_kv(k), quantize_kv(v) -> code/scale scratch
    last (j, kk), every r:                     # the paged scatter
        row r's (KVH, hd) codes + (KVH, 1) scales -> pool out blocks
        whose index maps target (page_ids[r], row_ids[r])

The four pool leaves ride through ``input_output_aliases`` so every page
row the grid does not target keeps its prior content; before the final
(j, kk) sweep the pool out-spec index maps park on the reserved null
page (0, 0) — inert by the pool contract, exactly like the composed
path's padded ``_write_kv_paged`` rows. Padded batch rows (M < 8) pass
``page_ids == row_ids == 0`` and land there too.

Numerics: the RoPE and KV-quant stages mirror ``models.layers.rope`` /
``quantize_kv`` op for op in f32 and the contraction is exact int32, but
XLA contracts the kernel's fused f32 chains (``x1·cos − x2·sin`` becomes
mul+FMA inside the jitted launch) so agreement with the eager
``ref.decode_qkv_prologue`` oracle is rtol ~1e-6, same caveat as
``fused_cat_matmul_w4``; the int8 KV codes — the values paged attention
actually reads — round identically and are pinned bitwise by the tests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fused_cat_matmul import _prep_operands, _transform_quant
from .quant_matmul_w4 import _GEMV_M, _unpack_block


def _rope_rows(y, pos_f32, head_dim: int, theta: float):
    """RoPE over flat (M, H*hd) rows with per-row f32 positions (M, 1) —
    mirrors ``models.layers.rope`` op for op (all f32)."""
    m, hn = y.shape
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos_f32 * freq[None, :]               # (M, half)
    cos = jnp.cos(ang)[:, None, :]              # (M, 1, half)
    sin = jnp.sin(ang)[:, None, :]
    yh = y.reshape(m, hn // head_dim, head_dim)
    x1, x2 = yh[..., :half], yh[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out


def _quantize_kv_rows(t, bits: int):
    """``models.layers.quantize_kv`` op for op: symmetric per-(row, head)
    int8 codes + f32 scales over the last axis."""
    qmax = 2.0 ** (bits - 1) - 1
    amax = jnp.max(jnp.abs(t), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    codes = jnp.clip(jnp.round(t / scale), -qmax - 1, qmax).astype(jnp.int8)
    return codes, scale


def _make_prologue_kernel(*, act_bits: int, packed: bool, has_blocks: bool,
                          tk: int, tn: int, k_pad: int, gn: int, gk: int,
                          n_q: int, n_kv: int, head_dim: int,
                          rope_theta: float, kv_bits: int):
    kvh = n_kv // head_dim

    def kernel(*refs):
        (pid_ref, rid_ref), refs = refs[:2], refs[2:]
        if has_blocks:
            (x_ref, sign_ref, ha_ref, hb_ref, blocks_ref, w_ref, sw_ref,
             pos_ref), refs = refs[:8], refs[8:]
        else:
            (x_ref, sign_ref, ha_ref, hb_ref, w_ref, sw_ref,
             pos_ref), refs = refs[:7], refs[7:]
            blocks_ref = None
        (_kin, _ksin, _vin, _vsin,                    # aliased, unread
         qo_ref, ko_ref, kso_ref, vo_ref, vso_ref,
         qx_ref, sx_ref, zx_ref, acc_ref,
         kq_ref, ks_ref, vq_ref, vs_ref) = refs
        j = pl.program_id(0)
        kk = pl.program_id(1)
        r = pl.program_id(2)
        last_jk = (j == gn - 1) & (kk == gk - 1)

        @pl.when((j == 0) & (kk == 0) & (r == 0))
        def _prep():
            _transform_quant(x_ref, sign_ref, ha_ref, hb_ref, blocks_ref,
                             qx_ref, sx_ref, zx_ref, act_bits=act_bits,
                             k_pad=k_pad)

        @pl.when(r == 0)
        def _contract():
            qx = qx_ref[:, pl.ds(kk * tk, tk)].astype(jnp.int32)
            qw = (_unpack_block(w_ref[...]) if packed
                  else w_ref[...].astype(jnp.int32))
            acc = jnp.dot(qx, qw,
                          preferred_element_type=jnp.int32).astype(jnp.float32)
            colsum = jnp.sum(qw, axis=0, keepdims=True).astype(jnp.float32)
            part = sx_ref[...] * sw_ref[...] * (acc - zx_ref[...] * colsum)

            @pl.when(kk == 0)
            def _set():
                acc_ref[:, pl.ds(j * tn, tn)] = part

            @pl.when(kk != 0)
            def _add():
                acc_ref[:, pl.ds(j * tn, tn)] += part

        @pl.when(last_jk & (r == 0))
        def _epilogue():
            y = acc_ref[...]                        # (8, N_pad) f32
            posf = pos_ref[...].astype(jnp.float32)  # (8, 1)
            qo_ref[...] = _rope_rows(y[:, :n_q], posf, head_dim,
                                     rope_theta).reshape(_GEMV_M, n_q)
            k = _rope_rows(y[:, n_q:n_q + n_kv], posf, head_dim, rope_theta)
            v = y[:, n_q + n_kv:n_q + 2 * n_kv].reshape(_GEMV_M, kvh,
                                                        head_dim)
            kq, ks = _quantize_kv_rows(k, kv_bits)
            vq, vs = _quantize_kv_rows(v, kv_bits)
            kq_ref[...] = kq
            ks_ref[...] = ks
            vq_ref[...] = vq
            vs_ref[...] = vs

        @pl.when(last_jk)
        def _scatter():
            ko_ref[...] = kq_ref[r][None, None]     # (1, 1, KVH, hd)
            kso_ref[...] = ks_ref[r][None, None]
            vo_ref[...] = vq_ref[r][None, None]
            vso_ref[...] = vs_ref[r][None, None]

    return kernel


@functools.partial(jax.jit, static_argnames=(
    "n_q", "head_dim", "rope_theta", "kv_bits", "act_bits", "packed",
    "block_n", "block_k", "interpret"))
def decode_qkv_prologue(x, blocks, ha, hb, sign, qw, sw,
                        k_pool, k_scale, v_pool, v_scale,
                        page_ids, row_ids, positions, *,
                        n_q: int, head_dim: int, rope_theta: float,
                        kv_bits: int = 8, act_bits: int = 8,
                        packed: bool = True, block_n: int = 256,
                        block_k: int = 512, interpret: bool = True):
    """Fused decode QKV prologue: one launch from hidden rows to rope'd
    q plus the paged pool with the step's K/V rows scattered in.

    x           (B, D) fp normed hidden rows, B <= 8 (decode batch)
    blocks/ha/hb/sign  CAT transform operands (``fused_transform_operands``)
    qw          (ceil(D/2), N) packed int4 — or (D, N) int8 — QKV weight,
                N = n_q + 2·n_kv columns laid out [q | k | v]
    sw          (1, N) f32 weight scales
    k/v_pool    (n_pages, page_size, KVH, hd) int8 pool leaves
    k/v_scale   (n_pages, page_size, KVH, 1) f32 pool leaves
    page_ids    (B,) int32 physical page per row (0 = null page for
                padded/invalid rows — the write is inert)
    row_ids     (B,) int32 row within the page
    positions   (B,) int32 absolute position per row (RoPE angle)
    -> (q (B, n_q) f32 rope'd, k_pool', k_scale', v_pool', v_scale')

    The pool operands are aliased into the outputs (donated); rows not
    targeted by ``page_ids``/``row_ids`` keep their prior content.
    """
    m, d = x.shape
    assert m <= _GEMV_M, f"decode prologue is for B<=8 rows, got B={m}"
    n = qw.shape[1]
    n_kv = (n - n_q) // 2
    assert n_q + 2 * n_kv == n, (n_q, n)
    assert n_q % head_dim == 0 and n_kv % head_dim == 0, (n_q, n_kv,
                                                          head_dim)
    n_pages, page_size, kvh, hd = k_pool.shape
    assert hd == head_dim and kvh == n_kv // head_dim, (k_pool.shape, n_kv)
    tk = min(block_k, d + d % 2)
    tk += tk % 2
    tn = min(block_n, n)
    x, qw, sw, dims = _prep_operands(x, blocks, ha, hb, sign, qw, sw,
                                     packed, _GEMV_M, tn, tk)
    k_pad, n_pad = dims["k_pad"], qw.shape[1]
    gn = n_pad // tn
    gk = k_pad // tk

    def _pad8(v):
        v = jnp.asarray(v, jnp.int32)
        return jnp.pad(v, (0, _GEMV_M - v.shape[0])) if v.shape[0] < _GEMV_M \
            else v

    page_ids = _pad8(page_ids)
    row_ids = _pad8(row_ids)
    pos8 = _pad8(positions)[:, None]

    has_blocks = blocks is not None
    kern = _make_prologue_kernel(
        act_bits=act_bits, packed=packed, has_blocks=has_blocks, tk=tk,
        tn=tn, k_pad=k_pad, gn=gn, gk=gk, n_q=n_q, n_kv=n_kv,
        head_dim=head_dim, rope_theta=rope_theta, kv_bits=kv_bits)

    def _pool_idx(j, kk, r, pid, rid):
        # park on the inert null page until the final (j, kk) sweep — the
        # only flushes that reach real rows carry the finished epilogue
        last = (j == gn - 1) & (kk == gk - 1)
        return (jnp.where(last, pid[r], 0), jnp.where(last, rid[r], 0),
                0, 0)

    def _null_idx(j, kk, r, pid, rid):
        return (0, 0, 0, 0)

    in_specs = [
        pl.BlockSpec((_GEMV_M, d), lambda j, kk, r, pid, rid: (0, 0)),
        pl.BlockSpec((d,), lambda j, kk, r, pid, rid: (0,)),
        pl.BlockSpec(ha.shape, lambda j, kk, r, pid, rid: (0, 0)),
        pl.BlockSpec(hb.shape, lambda j, kk, r, pid, rid: (0, 0)),
    ]
    operands = [x, sign, ha, hb]
    if has_blocks:
        in_specs.append(pl.BlockSpec(blocks.shape,
                                     lambda j, kk, r, pid, rid: (0, 0, 0)))
        operands.append(blocks)
    in_specs += [
        pl.BlockSpec((tk // 2 if packed else tk, tn),
                     lambda j, kk, r, pid, rid: (kk, j)),
        pl.BlockSpec((1, tn), lambda j, kk, r, pid, rid: (0, j)),
        pl.BlockSpec((_GEMV_M, 1), lambda j, kk, r, pid, rid: (0, 0)),
        # aliased pool leaves: blocked on the null page, never read
        pl.BlockSpec((1, 1, kvh, hd), _null_idx),
        pl.BlockSpec((1, 1, kvh, 1), _null_idx),
        pl.BlockSpec((1, 1, kvh, hd), _null_idx),
        pl.BlockSpec((1, 1, kvh, 1), _null_idx),
    ]
    operands += [qw, sw, pos8, k_pool, k_scale, v_pool, v_scale]
    # alias indices count ALL pallas_call operands, scalar prefetch first:
    # pid=0, rid=1, then `operands` — pools are the last four
    pool0 = 2 + len(operands) - 4
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,              # page_ids, row_ids
        grid=(gn, gk, _GEMV_M),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((_GEMV_M, n_q), lambda j, kk, r, pid, rid: (0, 0)),
            pl.BlockSpec((1, 1, kvh, hd), _pool_idx),
            pl.BlockSpec((1, 1, kvh, 1), _pool_idx),
            pl.BlockSpec((1, 1, kvh, hd), _pool_idx),
            pl.BlockSpec((1, 1, kvh, 1), _pool_idx),
        ],
        scratch_shapes=[
            pltpu.VMEM((_GEMV_M, k_pad), jnp.int8),      # act codes
            pltpu.VMEM((_GEMV_M, 1), jnp.float32),       # act scale
            pltpu.VMEM((_GEMV_M, 1), jnp.float32),       # act zero point
            pltpu.VMEM((_GEMV_M, n_pad), jnp.float32),   # qkv accumulator
            pltpu.VMEM((_GEMV_M, kvh, hd), jnp.int8),    # k codes
            pltpu.VMEM((_GEMV_M, kvh, 1), jnp.float32),  # k scales
            pltpu.VMEM((_GEMV_M, kvh, hd), jnp.int8),    # v codes
            pltpu.VMEM((_GEMV_M, kvh, 1), jnp.float32),  # v scales
        ],
    )
    q8, kp, ksc, vp, vsc = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((_GEMV_M, n_q), jnp.float32),
            jax.ShapeDtypeStruct(k_pool.shape, k_pool.dtype),
            jax.ShapeDtypeStruct(k_scale.shape, k_scale.dtype),
            jax.ShapeDtypeStruct(v_pool.shape, v_pool.dtype),
            jax.ShapeDtypeStruct(v_scale.shape, v_scale.dtype),
        ],
        input_output_aliases={pool0: 1, pool0 + 1: 2, pool0 + 2: 3,
                              pool0 + 3: 4},
        interpret=interpret,
    )(page_ids, row_ids, *operands)
    return q8[:m], kp, ksc, vp, vsc
