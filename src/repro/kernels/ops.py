"""jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU (the kernels execute their bodies in
Python on CPU for validation); on a real TPU backend it flips to False and
the same BlockSpecs drive Mosaic codegen.

`cat_transform_matmul` composes the full paper serving hot path:
   block-CAT -> Hadamard -> dynamic per-token quant -> int8 matmul.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .block_matmul import block_diag_matmul
from .dynamic_quant import dynamic_quant
from .hadamard import hadamard_transform
from .quant_matmul import quant_matmul
from .quant_matmul_w4 import _GEMV_M, quant_gemv_w4, quant_matmul_w4


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def hadamard(x, ha, hb, sign=None, **kw):
    kw.setdefault("interpret", default_interpret())
    return hadamard_transform(x, ha, hb, sign, **kw)


def dyn_quant(x, bits: int = 8, symmetric: bool = False, **kw):
    kw.setdefault("interpret", default_interpret())
    return dynamic_quant(x, bits=bits, symmetric=symmetric, **kw)


def qmatmul(qx, sx, zpx, qw, sw, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_matmul(qx, sx, zpx, qw, sw, **kw)


def qmatmul_w4(qx, sx, zpx, qw_packed, sw, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_matmul_w4(qx, sx, zpx, qw_packed, sw, **kw)


def qgemv_w4(qx, sx, zpx, qw_packed, sw, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_gemv_w4(qx, sx, zpx, qw_packed, sw, **kw)


def block_matmul(x, blocks, **kw):
    kw.setdefault("interpret", default_interpret())
    return block_diag_matmul(x, blocks, **kw)


def cat_transform_matmul(x, blocks, ha, hb, sign, qw, sw,
                         act_bits: int = 4, packed_int4: bool = False, **kw):
    """The paper's deployed quantized linear layer, end to end:
    y ≈ W·T⁻¹·Q(T x) with T = H·M̂_block, weights pre-fused & pre-quantized.

    x (..., d) fp; blocks (n,k,k); qw (d, d_out) int8 — or, with
    ``packed_int4``, (ceil(d/2), d_out) nibble-packed int4 codes;
    sw (1, d_out) f32.
    """
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    xf = block_matmul(xf, blocks, **kw)
    xf = hadamard(xf, ha, hb, sign, **kw)
    qx, sx, zpx = dyn_quant(xf, bits=act_bits, symmetric=False, **kw)
    if packed_int4:
        # decode shapes (few single-token rows) serve straight from the
        # packed buffer via the GEMV kernel instead of the tiled matmul
        if qx.shape[0] <= _GEMV_M:
            y = qgemv_w4(qx, sx, zpx, qw, sw, **kw)
        else:
            y = qmatmul_w4(qx, sx, zpx, qw, sw, **kw)
    else:
        y = qmatmul(qx, sx, zpx, qw, sw, **kw)
    return y.reshape(*lead, qw.shape[1]).astype(x.dtype)
