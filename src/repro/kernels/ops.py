"""jit'd public wrappers for the Pallas kernels.

`interpret` defaults to True off-TPU (the kernels execute their bodies in
Python on CPU for validation); on a real TPU backend it flips to False and
the same BlockSpecs drive Mosaic codegen.

`cat_transform_matmul` composes the full paper serving hot path:
   block-CAT -> Hadamard -> dynamic per-token quant -> int8 matmul.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp

from .block_matmul import block_diag_matmul
from .decode_layer import decode_qkv_prologue as _decode_qkv_prologue
from .dynamic_quant import dynamic_quant
from .fused_cat_matmul import fused_cat_gemv_w4, fused_cat_matmul_w4
from .hadamard import hadamard_transform
from .paged_attention import (paged_attention_decode,
                              paged_attention_fallback,
                              paged_attention_ragged,
                              paged_attention_ragged_fallback)
from .quant_matmul import quant_matmul
from .quant_matmul_w4 import _GEMV_M, quant_gemv_w4, quant_matmul_w4

_FALSY = ("", "0", "false", "no", "off")


def default_interpret() -> bool:
    """Whether pallas_call should run in interpret mode.

    ``REPRO_PALLAS_INTERPRET`` overrides in BOTH directions (``1`` forces
    interpret even on TPU — useful for oracle-exact debugging; ``0``
    forces Mosaic codegen); unset, interpret follows the backend so CPU
    CI executes every kernel body in Python instead of silently skipping
    kernel-vs-oracle coverage.
    """
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env.strip().lower() not in _FALSY
    return jax.default_backend() != "tpu"


def use_fused_decode() -> bool:
    """Whether decode layers route through the two-launch fused path
    (``decode_qkv_prologue`` + paged attention).

    ``REPRO_DECODE_FUSED`` overrides in both directions (``1`` enables it
    off-TPU — interpret mode, used by the parity tests; ``0`` pins the
    composed path); unset, it follows the backend like the other fused
    kernels, so off-TPU golden fixtures keep the composed path's exact
    numerics.
    """
    env = os.environ.get("REPRO_DECODE_FUSED")
    if env is not None:
        return env.strip().lower() not in _FALSY
    return jax.default_backend() == "tpu"


def hadamard(x, ha, hb, sign=None, **kw):
    kw.setdefault("interpret", default_interpret())
    return hadamard_transform(x, ha, hb, sign, **kw)


def dyn_quant(x, bits: int = 8, symmetric: bool = False, **kw):
    kw.setdefault("interpret", default_interpret())
    return dynamic_quant(x, bits=bits, symmetric=symmetric, **kw)


def qmatmul(qx, sx, zpx, qw, sw, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_matmul(qx, sx, zpx, qw, sw, **kw)


def qmatmul_w4(qx, sx, zpx, qw_packed, sw, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_matmul_w4(qx, sx, zpx, qw_packed, sw, **kw)


def qgemv_w4(qx, sx, zpx, qw_packed, sw, **kw):
    kw.setdefault("interpret", default_interpret())
    return quant_gemv_w4(qx, sx, zpx, qw_packed, sw, **kw)


def block_matmul(x, blocks, **kw):
    kw.setdefault("interpret", default_interpret())
    return block_diag_matmul(x, blocks, **kw)


def cat_transform_matmul(x, blocks, ha, hb, sign, qw, sw,
                         act_bits: int = 4, packed_int4: bool = False,
                         axis_name=None, **kw):
    """The paper's deployed quantized linear layer, end to end:
    y ≈ W·T⁻¹·Q(T x) with T = H·M̂_block, weights pre-fused & pre-quantized.

    x (..., d) fp; blocks (n,k,k); qw (d, d_out) int8 — or, with
    ``packed_int4``, (ceil(d/2), d_out) nibble-packed int4 codes;
    sw (1, d_out) f32.

    ``axis_name`` marks a call from INSIDE shard_map on a tensor-parallel
    mesh axis: ``x`` is replicated (the CAT/Hadamard transform and the
    per-token act-quant scales span the full d, so they run globally) and
    ``qw`` is this device's K shard — whole packed bytes per shard. The
    matching slice of the quantized activation contracts locally (decode
    shapes still route to the GEMV kernel; M is unchanged by K sharding)
    and partial outputs psum over ``axis_name`` — the zero-point
    correction is linear in K, so per-shard ``sx·sw·(acc − zp·colsum)``
    terms sum exactly."""
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    xf = block_matmul(xf, blocks, **kw)
    xf = hadamard(xf, ha, hb, sign, **kw)
    qx, sx, zpx = dyn_quant(xf, bits=act_bits, symmetric=False, **kw)
    if axis_name is not None:
        k_local = qw.shape[0] * 2 if packed_int4 else qw.shape[0]
        if packed_int4:
            assert d % 2 == 0, "sharded packed serving needs even K"
        idx = jax.lax.axis_index(axis_name)
        qx = jax.lax.dynamic_slice_in_dim(qx, idx * k_local, k_local, axis=1)
    if packed_int4:
        # decode shapes (few single-token rows) serve straight from the
        # packed buffer via the GEMV kernel instead of the tiled matmul
        if qx.shape[0] <= _GEMV_M:
            y = qgemv_w4(qx, sx, zpx, qw, sw, **kw)
        else:
            y = qmatmul_w4(qx, sx, zpx, qw, sw, **kw)
    else:
        y = qmatmul(qx, sx, zpx, qw, sw, **kw)
    if axis_name is not None:
        y = jax.lax.psum(y, axis_name)
    return y.reshape(*lead, qw.shape[1]).astype(x.dtype)


def fused_transform_operands(t):
    """Decompose a CAT transform pytree into the fused kernel's
    ``(blocks, ha, hb, sign)`` operands, or None when it doesn't fit.

    Supported shapes (exactly what ``transforms.make_cat_block`` /
    ``make_hadamard`` build): a bare ``Hadamard``, or a ``Compose`` of
    (``Scale`` | ``BlockDiag``, ``Hadamard``). A diagonal ``Scale``
    factor folds into the pre-Hadamard sign vector (both are elementwise,
    so they commute). Anything else — ``Dense``, bare block transforms
    without a Hadamard stage, nested composes — returns None and the
    caller uses the composed per-kernel path.
    """
    from repro.core import transforms as T

    if isinstance(t, T.Hadamard):
        return None, t.ha, t.hb, t.sign
    if not isinstance(t, T.Compose) or len(t.parts) != 2:
        return None
    first, had = t.parts
    if not isinstance(had, T.Hadamard):
        return None
    if isinstance(first, T.Scale):
        return None, had.ha, had.hb, had.sign * first.s
    if isinstance(first, T.BlockDiag):
        return first.blocks, had.ha, had.hb, had.sign
    if isinstance(first, T.Identity):
        return None, had.ha, had.hb, had.sign
    return None


def fused_cat_matmul(x, blocks, ha, hb, sign, qw, sw, act_bits: int = 8,
                     packed: bool = True, axis_name=None, **kw):
    """Single-launch serving linear: y ≈ W·T⁻¹·Q(T x) with the whole
    transform -> quant -> W4A8 chain fused into one Pallas kernel
    (``kernels/fused_cat_matmul.py``): the activation tile crosses HBM
    once and the (packed) weight is the only other stream.

    Operands as in ``fused_cat_matmul_w4`` (get them from a transform
    pytree via ``fused_transform_operands``); ``packed=False`` contracts
    (D, N) int8 weight codes instead of nibble-packed int4. Block sizes
    come from the per-shape autotune cache (``kernels/autotune.py``)
    unless passed explicitly.

    ``axis_name`` marks a call from inside shard_map on a K-sharded mesh
    axis. The transform and per-token quant scales span the full feature
    dim, so they cannot tile with a K shard — the tp path composes the
    stand-alone kernels (global transform + quant, local K-slice
    contraction, exact psum) just like ``cat_transform_matmul``.
    """
    from . import autotune

    kw.setdefault("interpret", default_interpret())
    lead = x.shape[:-1]
    d = x.shape[-1]
    xf = x.reshape(-1, d)
    if axis_name is not None:
        if blocks is not None:
            xf = block_matmul(xf, blocks, **kw)
            xf = hadamard(xf, ha, hb, sign, **kw)
        else:
            xf = hadamard(xf * sign.astype(xf.dtype), ha, hb, None, **kw)
        qx, sx, zpx = dyn_quant(xf, bits=act_bits, symmetric=False, **kw)
        k_local = qw.shape[0] * 2 if packed else qw.shape[0]
        if packed:
            assert d % 2 == 0, "sharded packed serving needs even K"
        idx = jax.lax.axis_index(axis_name)
        qx = jax.lax.dynamic_slice_in_dim(qx, idx * k_local, k_local, axis=1)
        if not packed:
            y = qmatmul(qx, sx, zpx, qw, sw, **kw)
        elif qx.shape[0] <= _GEMV_M:
            y = qgemv_w4(qx, sx, zpx, qw, sw, **kw)
        else:
            y = qmatmul_w4(qx, sx, zpx, qw, sw, **kw)
        y = jax.lax.psum(y, axis_name)
        return y.reshape(*lead, qw.shape[1]).astype(x.dtype)
    m, n = xf.shape[0], qw.shape[1]
    if xf.shape[0] <= _GEMV_M:
        if "block_n" not in kw or "block_k" not in kw:
            tn, tk = autotune.gemv_blocks(d, n, packed)
            kw.setdefault("block_n", tn)
            kw.setdefault("block_k", tk)
        y = fused_cat_gemv_w4(xf, blocks, ha, hb, sign, qw, sw,
                              act_bits=act_bits, packed=packed, **kw)
    else:
        if not {"block_m", "block_n", "block_k"} <= kw.keys():
            m_bucket = 1 << max(3, (m - 1).bit_length())
            key = ("fused", m_bucket, d, n, packed, kw["interpret"])

            def run(cand):
                tm, tn, tk = cand
                fused_cat_matmul_w4(
                    xf, blocks, ha, hb, sign, qw, sw, act_bits=act_bits,
                    packed=packed, block_m=tm, block_n=tn, block_k=tk,
                    interpret=kw["interpret"]).block_until_ready()

            tm, tn, tk = autotune.pick(key, m, d, n, packed, run=run)
            kw.setdefault("block_m", tm)
            kw.setdefault("block_n", tn)
            kw.setdefault("block_k", tk)
        y = fused_cat_matmul_w4(xf, blocks, ha, hb, sign, qw, sw,
                                act_bits=act_bits, packed=packed, **kw)
    return y.reshape(*lead, n).astype(x.dtype)


def decode_qkv_prologue(x, blocks, ha, hb, sign, qw, sw,
                        k_pool, k_scale, v_pool, v_scale,
                        page_ids, row_ids, positions, *,
                        n_q: int, head_dim: int, rope_theta: float,
                        kv_bits: int = 8, act_bits: int = 8,
                        packed: bool = True, **kw):
    """One-launch decode QKV prologue (``kernels/decode_layer.py``):
    CAT -> dynamic quant -> W4A8 QKV GEMV -> RoPE -> int8 KV quant ->
    paged-pool scatter. Together with the paged-attention kernel this
    makes a decode layer's attention block exactly two launches.

    Returns (q (B, n_q) f32 rope'd, k_pool', k_scale', v_pool',
    v_scale') with the pool leaves donated through
    ``input_output_aliases``. Block sizes come from
    ``autotune.prologue_blocks`` unless passed explicitly.
    """
    from . import autotune

    kw.setdefault("interpret", default_interpret())
    if "block_n" not in kw or "block_k" not in kw:
        n_kv = (qw.shape[1] - n_q) // 2
        tn, tk = autotune.prologue_blocks(x.shape[-1], qw.shape[1], n_kv,
                                          packed)
        kw.setdefault("block_n", tn)
        kw.setdefault("block_k", tk)
    return _decode_qkv_prologue(
        x, blocks, ha, hb, sign, qw, sw, k_pool, k_scale, v_pool, v_scale,
        page_ids, row_ids, positions, n_q=n_q, head_dim=head_dim,
        rope_theta=rope_theta, kv_bits=kv_bits, act_bits=act_bits,
        packed=packed, **kw)


def paged_attention(q, k_pages, k_scale, v_pages, v_scale, page_table,
                    lengths, **kw):
    """Paged decode attention from the quantized KV page pool.

    Routes int8 pools to the Pallas kernel (page table + lengths ride as
    scalar-prefetch operands driving the per-page DMA; dequant + online
    softmax in VMEM) and fp pools — which carry no scales to stream — to
    the jnp gather fallback. See ``kernels/paged_attention.py``.
    """
    if k_scale is None or v_scale is None:
        return paged_attention_fallback(q, k_pages, k_scale, v_pages,
                                        v_scale, page_table, lengths)
    kw.setdefault("interpret", default_interpret())
    return paged_attention_decode(q, k_pages, k_scale, v_pages, v_scale,
                                  page_table, lengths, **kw)


def ragged_paged_attention(q, k_pages, k_scale, v_pages, v_scale,
                           page_table, lengths, q_pos, **kw):
    """Mixed-q_len paged attention for the unified token-budget step:
    per-work-item query blocks against the page pool, with the
    per-(query, kv) causal mask applied inside the launch — prefill
    chunks and decode tokens share one kernel call. int8 pools go to the
    Pallas kernel, fp pools (no scales to stream) to the jnp fallback.
    """
    if k_scale is None or v_scale is None:
        return paged_attention_ragged_fallback(q, k_pages, k_scale,
                                               v_pages, v_scale,
                                               page_table, lengths, q_pos)
    kw.setdefault("interpret", default_interpret())
    return paged_attention_ragged(q, k_pages, k_scale, v_pages, v_scale,
                                  page_table, lengths, q_pos, **kw)


# ------------------------------------------------- tensor-parallel wrappers

def _w4_tp(kernel, qx, sx, zpx, qw_packed, sw, mesh, axis, kw):
    """Run a W4A8 kernel with the contraction sharded over ``axis``: qx
    splits on K, qw_packed on packed-K (whole bytes per shard), and the
    per-device partial — dequant and zero-point correction are both
    linear in K — psums to the exact full contraction."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map

    kw.setdefault("interpret", default_interpret())
    tp = mesh.shape[axis]
    k = qx.shape[1]
    assert k % (2 * tp) == 0, (
        f"K={k} must split into whole packed bytes across {axis}={tp}")
    assert qw_packed.shape[0] * 2 == k, (qx.shape, qw_packed.shape)

    def body(qxl, sxl, zxl, qwl, swl):
        return jax.lax.psum(kernel(qxl, sxl, zxl, qwl, swl, **kw), axis)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, axis), P(None, None), P(None, None),
                  P(axis, None), P(None, None)),
        out_specs=P(None, None), check_vma=False,
    )(qx, sx, zpx, qw_packed, sw)


def qmatmul_w4_tp(qx, sx, zpx, qw_packed, sw, *, mesh, axis: str = "model",
                  **kw):
    """K-sharded ``qmatmul_w4`` under shard_map with a psum over ``axis``."""
    return _w4_tp(quant_matmul_w4, qx, sx, zpx, qw_packed, sw, mesh, axis, kw)


def qgemv_w4_tp(qx, sx, zpx, qw_packed, sw, *, mesh, axis: str = "model",
                **kw):
    """K-sharded decode GEMV under shard_map with a psum over ``axis``."""
    return _w4_tp(quant_gemv_w4, qx, sx, zpx, qw_packed, sw, mesh, axis, kw)
