"""Pallas TPU kernel: int4-packed-weight quantized matmul (W4A8).

Weights arrive packed two nibbles per int8 byte along K (see
``repro.core.quantizers.pack_int4``: even K index -> low nibble, odd ->
high). The kernel unpacks in VMEM right before the contraction, so HBM->
VMEM weight traffic is halved vs the int8 kernel while the MXU still sees
an int8 contraction:

    y[m,n] = sx[m]·sw[n]·( Σ_k qx[m,k]·qw[k,n] − zpx[m]·Σ_k qw[k,n] )

Output accumulation across the K grid dimension reuses the revisited-output
pattern from ``quant_matmul.py`` (out block index ignores k; init at k=0);
the zero-point correction likewise uses the per-tile column sum of the
*unpacked* qw, which is linear in k.

Grid: (M/TM, N/TN, K/TK). Per step the packed weight block is (TK//2, TN)
int8 — half the bytes of the int8 kernel's (TK, TN). Nibble sign-extension
uses ((v & 0xF) ^ 8) - 8, which is portable across interpret and Mosaic.

``quant_gemv_w4`` is the decode-shaped sibling (M ∈ [1, 8] single-token
rows): no M grid — the activation sliver stays VMEM-resident across an
(N, K) grid and the packed weight is the only HBM stream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_block(pw: jnp.ndarray) -> jnp.ndarray:
    """(TK//2, TN) packed int8 -> (TK, TN) int32 codes in [-8, 7]."""
    p = pw.astype(jnp.int32)
    lo = ((p & 0xF) ^ 8) - 8
    hi = (((p >> 4) & 0xF) ^ 8) - 8
    tk2, tn = p.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * tk2, tn)


def _w4_accumulate(x_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref, k):
    """Shared K-step body: unpack the packed weight block in VMEM, int8
    MXU contraction, dequant + zero-point epilogue into the revisited
    output block. ``k`` is this grid's K program id."""
    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qx = x_ref[...].astype(jnp.int32)
    qw = _unpack_block(w_ref[...])
    acc = jnp.dot(qx, qw, preferred_element_type=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(qw, axis=0, keepdims=True).astype(jnp.float32)
    sx = sx_ref[...]
    zx = zx_ref[...]
    sw = sw_ref[...]
    o_ref[...] += (sx * sw * (acc - zx * colsum)).astype(o_ref.dtype)


def _qmm_w4_kernel(x_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref):
    _w4_accumulate(x_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref,
                   pl.program_id(2))


def _gemv_w4_kernel(x_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref):
    _w4_accumulate(x_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref,
                   pl.program_id(1))


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def quant_matmul_w4(qx: jnp.ndarray, sx: jnp.ndarray, zpx: jnp.ndarray,
                    qw_packed: jnp.ndarray, sw: jnp.ndarray,
                    block_m: int = 256, block_n: int = 256,
                    block_k: int = 512,
                    out_dtype=jnp.float32, interpret: bool = True
                    ) -> jnp.ndarray:
    """qx (M,K) int8 activation codes, sx/zpx (M,1) f32, qw_packed
    (ceil(K/2), N) int8 nibble-packed weight codes, sw (1,N) f32 -> (M,N).

    Odd K is allowed: the packed weight's final byte carries a zero high
    nibble and qx's K axis is zero-padded to match — both inert.
    """
    m, k = qx.shape
    k2, n = qw_packed.shape
    assert k2 == (k + 1) // 2, (qx.shape, qw_packed.shape)
    if k % 2:  # align qx's K with the padded nibble
        qx = jnp.pad(qx, ((0, 0), (0, 1)))
        k += 1
    # block_k counts UNPACKED K rows and must stay even so each packed
    # byte lands wholly inside one grid step.
    tm, tn = min(block_m, m), min(block_n, n)
    tk = min(block_k, k)
    tk += tk % 2
    pm, pn, pk = (-m) % tm, (-n) % tn, (-k) % tk
    if pm or pk:
        qx = jnp.pad(qx, ((0, pm), (0, pk)))
        sx = jnp.pad(sx, ((0, pm), (0, 0)), constant_values=1.0)
        zpx = jnp.pad(zpx, ((0, pm), (0, 0)))
    if pk or pn:
        qw_packed = jnp.pad(qw_packed, ((0, pk // 2), (0, pn)))
        sw = jnp.pad(sw, ((0, 0), (0, pn)), constant_values=1.0)
    gm, gn, gk = qx.shape[0] // tm, qw_packed.shape[1] // tn, qx.shape[1] // tk
    out = pl.pallas_call(
        _qmm_w4_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((tk // 2, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qx.shape[0], qw_packed.shape[1]),
                                       out_dtype),
        interpret=interpret,
    )(qx, sx, zpx, qw_packed, sw)
    return out[:m, :n]


_GEMV_M = 8  # decode micro-batch rows kept VMEM-resident (f32 sublane tile)


@functools.partial(jax.jit, static_argnames=("block_n", "block_k",
                                             "out_dtype", "interpret"))
def quant_gemv_w4(qx: jnp.ndarray, sx: jnp.ndarray, zpx: jnp.ndarray,
                  qw_packed: jnp.ndarray, sw: jnp.ndarray,
                  block_n: int = 256, block_k: int = 512,
                  out_dtype=jnp.float32, interpret: bool = True
                  ) -> jnp.ndarray:
    """Decode-shaped W4A8 GEMV: same contraction as ``quant_matmul_w4``
    but for M ∈ [1, 8] rows (single-token decode over a few slots).

    The M axis is padded to 8 and kept whole — one VMEM-resident activation
    sliver revisited across the whole (N, K) grid, so the packed weight is
    the only HBM stream (the memory-bound regime where int4 packing pays:
    half the bytes of the int8 kernel per decoded token). Odd K follows
    the matmul kernel's contract (inert zero high nibble + zero-padded qx).
    """
    m, k = qx.shape
    k2, n = qw_packed.shape
    assert m <= _GEMV_M, f"GEMV path is for M<=8 decode shapes, got M={m}"
    assert k2 == (k + 1) // 2, (qx.shape, qw_packed.shape)
    if k % 2:
        qx = jnp.pad(qx, ((0, 0), (0, 1)))
        k += 1
    tn = min(block_n, n)
    tk = min(block_k, k)
    tk += tk % 2  # whole packed bytes per grid step
    pm, pn, pk = _GEMV_M - m, (-n) % tn, (-k) % tk
    if pm or pk:
        qx = jnp.pad(qx, ((0, pm), (0, pk)))
        sx = jnp.pad(sx, ((0, pm), (0, 0)), constant_values=1.0)
        zpx = jnp.pad(zpx, ((0, pm), (0, 0)))
    if pk or pn:
        qw_packed = jnp.pad(qw_packed, ((0, pk // 2), (0, pn)))
        sw = jnp.pad(sw, ((0, 0), (0, pn)), constant_values=1.0)
    gn, gk = qw_packed.shape[1] // tn, qx.shape[1] // tk
    out = pl.pallas_call(
        _gemv_w4_kernel,
        grid=(gn, gk),
        in_specs=[
            pl.BlockSpec((_GEMV_M, tk), lambda j, kk: (0, kk)),
            pl.BlockSpec((_GEMV_M, 1), lambda j, kk: (0, 0)),
            pl.BlockSpec((_GEMV_M, 1), lambda j, kk: (0, 0)),
            pl.BlockSpec((tk // 2, tn), lambda j, kk: (kk, j)),
            pl.BlockSpec((1, tn), lambda j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((_GEMV_M, tn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((_GEMV_M, qw_packed.shape[1]),
                                       out_dtype),
        interpret=interpret,
    )(qx, sx, zpx, qw_packed, sw)
    return out[:m, :n]
