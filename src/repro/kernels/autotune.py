"""Per-shape block-size selection for the Pallas serving kernels.

The serving hot loop calls the same handful of (M, K, N) shapes thousands
of times, so block sizes are worth picking once per shape and memoizing.
Two modes:

* default — an analytic VMEM-budget heuristic (`heuristic_blocks`):
  largest power-of-two M tile whose working set (x tile + int8 code
  scratch + weight block + f32 output block + scales) fits the budget,
  with N/K blocks clamped to the operand.
* ``REPRO_AUTOTUNE=measure`` — time each heuristic candidate once via a
  caller-supplied runner and keep the fastest (`pick`). Useful on real
  TPUs where the heuristic's VMEM model is approximate; never on by
  default because it compiles every candidate.

The cache is process-local and keyed on the caller's shape tuple; entries
are never evicted (a serving process sees a few dozen shapes at most).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Sequence

# Conservative slice of the ~16 MiB/core VMEM: leaves headroom for
# Mosaic's own double-buffering of the streamed weight blocks.
VMEM_BUDGET = 8 * 2**20

_CACHE: dict = {}


def cache_info() -> dict:
    """Snapshot of the memoized choices (for tests / debugging)."""
    return dict(_CACHE)


def cache_clear() -> None:
    _CACHE.clear()


def _fused_working_set(tm: int, tn: int, tk: int, d: int, packed: bool) -> int:
    k_pad = -(-d // tk) * tk
    x_tile = tm * d * 4                       # f32 activation tile
    scratch = tm * k_pad + tm * 2 * 4         # int8 codes + scale/zp
    w_blk = (tk // 2 if packed else tk) * tn  # int8/packed weight block
    out = tm * tn * 4
    return x_tile + scratch + w_blk + out


def heuristic_blocks(m: int, d: int, n: int, packed: bool,
                     budget: int = VMEM_BUDGET) -> tuple[int, int, int]:
    """-> (block_m, block_n, block_k) for the fused CAT matmul shape."""
    tk = min(512, d + d % 2)
    tk += tk % 2
    tn = min(256, n)
    for tm in (256, 128, 64, 32, 16, 8):
        if _fused_working_set(tm, tn, tk, d, packed) <= budget:
            return tm, tn, tk
    return 8, tn, tk


def _candidates(m: int, d: int, n: int, packed: bool):
    tm0, tn0, tk0 = heuristic_blocks(m, d, n, packed)
    seen, out = set(), []
    for tm in (tm0, max(8, tm0 // 2), min(256, tm0 * 2)):
        for tn in (tn0, max(128, tn0 // 2)):
            c = (tm, min(tn, max(8, n)), tk0)
            if c not in seen:
                seen.add(c)
                out.append(c)
    return out


def pick(key: tuple, m: int, d: int, n: int, packed: bool,
         run: Callable[[tuple[int, int, int]], None] | None = None,
         ) -> tuple[int, int, int]:
    """Memoized block-size choice for ``key`` (caller's shape tuple).

    With ``REPRO_AUTOTUNE=measure`` and a ``run`` callback, times each
    candidate (one warmup + one timed call) and caches the fastest;
    otherwise caches the heuristic.
    """
    hit = _CACHE.get(key)
    if hit is not None:
        return hit
    choice = heuristic_blocks(m, d, n, packed)
    if run is not None and os.environ.get("REPRO_AUTOTUNE") == "measure":
        best_t = None
        for cand in _candidates(m, d, n, packed):
            try:
                run(cand)           # compile + warm
                t0 = time.perf_counter()
                run(cand)
                dt = time.perf_counter() - t0
            except Exception:       # candidate invalid on this backend
                continue
            if best_t is None or dt < best_t:
                best_t, choice = dt, cand
    _CACHE[key] = choice
    return choice


def gemv_blocks(d: int, n: int, packed: bool,
                budget: int = VMEM_BUDGET) -> tuple[int, int]:
    """-> (block_n, block_k) for the fused GEMV (M fixed at 8)."""
    _, tn, tk = heuristic_blocks(8, d, n, packed, budget)
    return tn, tk


def prologue_blocks(d: int, n: int, n_kv: int, packed: bool,
                    budget: int = VMEM_BUDGET) -> tuple[int, int]:
    """-> (block_n, block_k) for the fused decode QKV prologue.

    Same shape family as the GEMV (M fixed at 8), but the kernel keeps
    extra VMEM resident for the whole launch: the full-N f32 QKV
    accumulator (the RoPE/KV epilogue reads all columns at once) and the
    K/V code+scale epilogue scratches — carve those out of the budget
    before sizing the streamed weight block.
    """
    acc = 8 * n * 4                       # (8, N_pad) f32 accumulator
    kv = 2 * (8 * n_kv + 8 * n_kv * 4)    # int8 codes + f32 scale bound
    return gemv_blocks(d, n, packed, budget=max(budget - acc - kv,
                                                budget // 8))


__all__: Sequence[str] = ("pick", "heuristic_blocks", "gemv_blocks",
                          "prologue_blocks", "cache_info", "cache_clear",
                          "VMEM_BUDGET")
