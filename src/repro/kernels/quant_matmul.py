"""Pallas TPU kernel: int8×int8 quantized matmul with fused dequant epilogue.

y[m,n] = sx[m]·sw[n]·( Σ_k qx[m,k]·qw[k,n] − zpx[m]·Σ_k qw[k,n] )

The int8 contraction hits the MXU natively on v5e; the asymmetric
zero-point correction uses the per-k-tile column sum of qw (linear in k,
so each grid step adds its exact share — no cross-step scratch needed).
Output accumulation across the K grid dimension uses the standard
revisited-output pattern (out block index ignores k; initialized at k=0).

Grid: (M/TM, N/TN, K/TK). VMEM per step ≈ TM·TK + TK·TN int8 + TM·TN f32.
Defaults (256, 256, 512) ⇒ ~0.5 MB, leaving headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _qmm_kernel(x_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qx = x_ref[...].astype(jnp.int32)
    qw = w_ref[...].astype(jnp.int32)
    acc = jnp.dot(qx, qw, preferred_element_type=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(qw, axis=0, keepdims=True).astype(jnp.float32)
    sx = sx_ref[...]
    zx = zx_ref[...]
    sw = sw_ref[...]
    o_ref[...] += (sx * sw * (acc - zx * colsum)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def quant_matmul(qx: jnp.ndarray, sx: jnp.ndarray, zpx: jnp.ndarray,
                 qw: jnp.ndarray, sw: jnp.ndarray,
                 block_m: int = 256, block_n: int = 256, block_k: int = 512,
                 out_dtype=jnp.float32, interpret: bool = True) -> jnp.ndarray:
    """qx (M,K) int8, sx/zpx (M,1) f32, qw (K,N) int8, sw (1,N) f32 -> (M,N)."""
    m, k = qx.shape
    k2, n = qw.shape
    assert k == k2, (qx.shape, qw.shape)
    tm, tn, tk = min(block_m, m), min(block_n, n), min(block_k, k)
    pm, pn, pk = (-m) % tm, (-n) % tn, (-k) % tk
    if pm or pk:
        qx = jnp.pad(qx, ((0, pm), (0, pk)))
        sx = jnp.pad(sx, ((0, pm), (0, 0)), constant_values=1.0)
        zpx = jnp.pad(zpx, ((0, pm), (0, 0)))
    if pk or pn:
        qw = jnp.pad(qw, ((0, pk), (0, pn)))
        sw = jnp.pad(sw, ((0, 0), (0, pn)), constant_values=1.0)
    gm, gn, gk = qx.shape[0] // tm, qw.shape[1] // tn, qx.shape[1] // tk
    out = pl.pallas_call(
        _qmm_kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((tm, 1), lambda i, j, kk: (i, 0)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qx.shape[0], qw.shape[1]), out_dtype),
        interpret=interpret,
    )(qx, sx, zpx, qw, sw)
    return out[:m, :n]
