"""Pallas TPU kernels for the quantized-serving hot paths, with pure-jnp
oracles in ref.py. Validated in interpret mode on CPU; BlockSpecs target
the v5e memory hierarchy (see DESIGN.md §3)."""
from . import ops, ref  # noqa: F401
