"""Pallas TPU kernel: paged decode attention over a quantized KV pool.

The serve engine's paged KV cache stores int8 codes + per-(token, head)
f32 scales in fixed-size *pages* of a global pool (``repro.launch.paged``);
a request's logical sequence is the concatenation of the pages its page
table names. Decode attention is then a gather problem: for slot ``b``,
stream pages ``page_table[b, i]`` from HBM, dequantize in VMEM, and fold
each page into an online-softmax accumulator — the bf16 logical cache is
never materialized and the int8 pages are the only HBM stream (half the
bytes of an fp16 cache per decoded token, the memory-bound regime where
KV quantization pays).

The page table and per-slot lengths ride in as **scalar-prefetch**
operands (``pltpu.PrefetchScalarGridSpec``): they are resident before the
kernel body runs, so the k/v BlockSpec index maps can address the
*physical* page ``pt[b, i]`` while the grid walks *logical* page slots
``(b, i)`` — the indirection is free, folded into the DMA descriptor.

Grid: ``(B, n_ptab)`` with the page axis innermost; VMEM scratch carries
the flash-attention running (m, l, acc) across a slot's pages (init at
``i == 0``, final ``acc / l`` write-out at the last page). Ragged last
pages and dummy table entries (null page 0) are handled by the
``kv_pos < length[b]`` mask — garbage rows get ``exp(-1e30 - m) == 0``
weight exactly.

``repro.kernels.ref.paged_attention_decode`` is the jnp oracle;
``paged_attention_fallback`` is a gather-based jnp path for fp pools and
backends without Pallas.

``paged_attention_ragged`` generalizes the q_len=1 decode kernel to a
*block of queries per sequence* with a per-(query, kv) causal mask — the
shape of a unified token-budget step, where one launch covers every
prefill chunk and decode token packed into the step and each sequence's
pages stream exactly once (see ``repro.launch.scheduler``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _paged_attn_kernel(len_ref, pt_ref, q_ref, k_ref, ks_ref, v_ref,
                       vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                       page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (KVH, g, hd), pre-scaled
    k = k_ref[0].astype(jnp.float32) * ks_ref[0]  # (G, KVH, hd) dequant
    v = v_ref[0].astype(jnp.float32) * vs_ref[0]

    # scores for this page: (KVH, g, G)
    s = jnp.einsum("kgd,Gkd->kgG", q, k,
                   preferred_element_type=jnp.float32)
    kv_pos = i * page_size + jax.lax.iota(jnp.int32, page_size)
    mask = kv_pos < len_ref[b]
    s = jnp.where(mask[None, None, :], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("kgG,Gkd->kgd", p, v,
                                 preferred_element_type=jnp.float32))

    @pl.when(i == pl.num_programs(1) - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = out.astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_decode(q: jnp.ndarray, k_pages: jnp.ndarray,
                           k_scale: jnp.ndarray, v_pages: jnp.ndarray,
                           v_scale: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray,
                           interpret: bool = True) -> jnp.ndarray:
    """Single-token paged decode attention from a quantized page pool.

    q           (B, KVH, g, hd)  query heads grouped GQA-style (g = H/KVH)
    k/v_pages   (n_pages, G, KVH, hd) int8 codes
    k/v_scale   (n_pages, G, KVH, 1) f32 per-(token, head) scales
    page_table  (B, n_ptab) int32 physical page ids (0 = null page for
                slots/entries beyond the sequence — masked by ``lengths``)
    lengths     (B,) int32 valid kv rows per slot (the decode token's row
                included: pass ``pos + 1``)
    -> (B, KVH, g, hd) in q's dtype.
    """
    b, kvh, g, hd = q.shape
    n_pages, page_size, kvh_p, _ = k_pages.shape
    n_ptab = page_table.shape[1]
    assert kvh_p == kvh, (q.shape, k_pages.shape)
    assert page_table.shape[0] == b and lengths.shape == (b,)

    qs = (q.astype(jnp.float32) * hd ** -0.5).astype(q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # lengths, page_table
        grid=(b, n_ptab),
        in_specs=[
            pl.BlockSpec((1, kvh, g, hd), lambda bb, i, ln, pt: (bb, 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, hd),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, 1),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, hd),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, 1),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, kvh, g, hd),
                               lambda bb, i, ln, pt: (bb, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((kvh, g), jnp.float32),       # running max
            pltpu.VMEM((kvh, g), jnp.float32),       # running denom
            pltpu.VMEM((kvh, g, hd), jnp.float32),   # running numerator
        ],
    )
    return pl.pallas_call(
        functools.partial(_paged_attn_kernel, page_size=page_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table, qs, k_pages, k_scale, v_pages, v_scale)


# ------------------------------------------------- ragged (mixed q_len)

def _ragged_attn_kernel(len_ref, pt_ref, q_ref, qpos_ref, k_ref, ks_ref,
                        v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        page_size: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)              # (Q, KVH, g, hd)
    qpos = qpos_ref[0]                            # (Q,) absolute positions
    k = k_ref[0].astype(jnp.float32) * ks_ref[0]  # (G, KVH, hd) dequant
    v = v_ref[0].astype(jnp.float32) * vs_ref[0]

    # scores for this page: (Q, KVH, g, G)
    s = jnp.einsum("qkgd,Gkd->qkgG", q, k,
                   preferred_element_type=jnp.float32)
    kv_pos = i * page_size + jax.lax.iota(jnp.int32, page_size)
    # per-(query, kv) causal mask inside the chunk: a prefill row at
    # position p sees exactly kv_pos <= p (its same-step chunk-mates
    # beyond p were already written but stay masked); padded query rows
    # (qpos < 0) mask everything and their garbage output is discarded
    mask = ((kv_pos[None, :] <= qpos[:, None])
            & (kv_pos[None, :] < len_ref[b])
            & (qpos[:, None] >= 0))
    s = jnp.where(mask[:, None, None, :], s, _NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    m_ref[...] = m_new
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    acc_ref[...] = (acc_ref[...] * corr[..., None]
                    + jnp.einsum("qkgG,Gkd->qkgd", p, v,
                                 preferred_element_type=jnp.float32))

    @pl.when(i == pl.num_programs(1) - 1)
    def _finish():
        out = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)[..., None]
        o_ref[...] = out.astype(o_ref.dtype)[None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_ragged(q: jnp.ndarray, k_pages: jnp.ndarray,
                           k_scale: jnp.ndarray, v_pages: jnp.ndarray,
                           v_scale: jnp.ndarray, page_table: jnp.ndarray,
                           lengths: jnp.ndarray, q_pos: jnp.ndarray,
                           interpret: bool = True) -> jnp.ndarray:
    """Mixed-q_len paged attention: the q_len=1 decode kernel generalized
    to a *block of queries per sequence*, so one launch serves a unified
    token-budget step — each grid row is one work item (a prefill chunk
    OR a decode token) and its pages stream from HBM exactly once for
    all of its queries.

    q           (B, Q, KVH, g, hd)  per-item query blocks (right-padded)
    k/v_pages   (n_pages, G, KVH, hd) int8 codes
    k/v_scale   (n_pages, G, KVH, 1) f32 per-(token, head) scales
    page_table  (B, n_ptab) int32 physical page ids (0 = null page)
    lengths     (B,) int32 valid kv rows per item (last query's pos + 1)
    q_pos       (B, Q) int32 absolute position per query row; -1 marks
                padding rows (fully masked, output garbage — discard)
    -> (B, Q, KVH, g, hd) in q's dtype. ``q_len=1`` with
    ``q_pos = lengths - 1`` reproduces ``paged_attention_decode``.
    """
    b, nq, kvh, g, hd = q.shape
    n_pages, page_size, kvh_p, _ = k_pages.shape
    n_ptab = page_table.shape[1]
    assert kvh_p == kvh, (q.shape, k_pages.shape)
    assert page_table.shape[0] == b and lengths.shape == (b,)
    assert q_pos.shape == (b, nq), (q_pos.shape, q.shape)

    qs = (q.astype(jnp.float32) * hd ** -0.5).astype(q.dtype)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,      # lengths, page_table
        grid=(b, n_ptab),
        in_specs=[
            pl.BlockSpec((1, nq, kvh, g, hd),
                         lambda bb, i, ln, pt: (bb, 0, 0, 0, 0)),
            pl.BlockSpec((1, nq), lambda bb, i, ln, pt: (bb, 0)),
            pl.BlockSpec((1, page_size, kvh, hd),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, 1),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, hd),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
            pl.BlockSpec((1, page_size, kvh, 1),
                         lambda bb, i, ln, pt: (pt[bb, i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, nq, kvh, g, hd),
                               lambda bb, i, ln, pt: (bb, 0, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((nq, kvh, g), jnp.float32),       # running max
            pltpu.VMEM((nq, kvh, g), jnp.float32),       # running denom
            pltpu.VMEM((nq, kvh, g, hd), jnp.float32),   # running numerator
        ],
    )
    return pl.pallas_call(
        functools.partial(_ragged_attn_kernel, page_size=page_size),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, nq, kvh, g, hd), q.dtype),
        interpret=interpret,
    )(lengths, page_table, qs, q_pos, k_pages, k_scale, v_pages, v_scale)


def paged_attention_ragged_fallback(q: jnp.ndarray, k_pages, k_scale,
                                    v_pages, v_scale,
                                    page_table: jnp.ndarray,
                                    lengths: jnp.ndarray,
                                    q_pos: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp ragged paged attention (same contract as the kernel).

    Gathers each item's logical view and runs a per-(query, kv) causally
    masked softmax in f32. Also serves fp pools: pass ``k_scale``/
    ``v_scale`` as ``None`` and fp ``*_pages``.
    """
    b, nq, kvh, g, hd = q.shape
    page_size = k_pages.shape[1]

    def logical(pages, scale):
        view = pages[page_table].reshape(b, -1, kvh, hd)  # (B, S, KVH, hd)
        if scale is None:
            return view.astype(jnp.float32)
        sc = scale[page_table].reshape(b, -1, kvh, 1)
        return view.astype(jnp.float32) * sc

    k = logical(k_pages, k_scale)
    v = logical(v_pages, v_scale)
    skv = page_table.shape[1] * page_size
    qf = q.astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bqkgd,bskd->bqkgs", qf, k)
    kv = jnp.arange(skv, dtype=jnp.int32)
    mask = ((kv[None, None, :] <= q_pos[:, :, None])
            & (kv[None, None, :] < lengths[:, None, None])
            & (q_pos[:, :, None] >= 0))
    s = jnp.where(mask[:, :, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p, v)
    return out.astype(q.dtype)


def paged_attention_fallback(q: jnp.ndarray, k_pages, k_scale, v_pages,
                             v_scale, page_table: jnp.ndarray,
                             lengths: jnp.ndarray) -> jnp.ndarray:
    """Pure-jnp paged decode attention (same contract as the kernel).

    Gathers the logical view and runs a masked softmax in f32. Also serves
    fp pools: pass ``k_scale``/``v_scale`` as ``None`` and fp ``*_pages``.
    """
    b, kvh, g, hd = q.shape
    page_size = k_pages.shape[1]

    def logical(pages, scale):
        view = pages[page_table].reshape(b, -1, kvh, hd)  # (B, S, KVH, hd)
        if scale is None:
            return view.astype(jnp.float32)
        sc = scale[page_table].reshape(b, -1, kvh, 1)
        return view.astype(jnp.float32) * sc

    k = logical(k_pages, k_scale)
    v = logical(v_pages, v_scale)
    skv = page_table.shape[1] * page_size
    qf = q.astype(jnp.float32) * hd ** -0.5
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)
    mask = jnp.arange(skv, dtype=jnp.int32)[None, :] < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p, v)
    return out.astype(q.dtype)
