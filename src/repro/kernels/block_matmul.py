"""Pallas TPU kernel: block-diagonal matmul — the online CAT transform.

y[..., i·k:(i+1)·k] = x[..., i·k:(i+1)·k] @ B_iᵀ  for blocks (n, k, k).

With the paper's k=128 each block is exactly one MXU tile; the grid walks
(token-tile × block) so a block matrix is loaded once per token tile and
the working set stays tiny (TM·k in + k² weights + TM·k out).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bdm_kernel(x_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)           # (TM, k)
    b = b_ref[0].astype(jnp.float32)             # (k, k)
    o_ref[...] = jnp.dot(x, b.T, preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_tokens", "interpret"))
def block_diag_matmul(x: jnp.ndarray, blocks: jnp.ndarray,
                      block_tokens: int = 512, interpret: bool = True):
    """x (..., n·k), blocks (n, k, k) -> y = x @ blockdiag(B)ᵀ."""
    n, k, _ = blocks.shape
    d = n * k
    assert x.shape[-1] == d, (x.shape, blocks.shape)
    lead = x.shape[:-1]
    xf = x.reshape(-1, d)
    m = xf.shape[0]
    tm = min(block_tokens, max(m, 1))
    pad = (-m) % tm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // tm, n)
    out = pl.pallas_call(
        _bdm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, j)),
            pl.BlockSpec((1, k, k), lambda i, j: (j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, k), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, blocks)
    if pad:
        out = out[:m]
    return out.reshape(*lead, d)
