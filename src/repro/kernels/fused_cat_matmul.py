"""Pallas TPU kernel: the whole CAT serving hot path in ONE launch.

``fused_cat_matmul_w4`` runs block-CAT -> (sign ⊙) Hadamard -> dynamic
per-token asymmetric quantization -> W4A8 (or W8A8) matmul with the
dequant + zero-point epilogue as a single kernel. The unfused composition
(``ops.cat_transform_matmul``) round-trips three fp intermediates through
HBM per linear — transformed activations twice (block-CAT out, Hadamard
out) plus the int8 codes; here the activation tile is read from HBM
once, transformed and quantized in VMEM scratch, and the packed weight
is the only other HBM stream.

Dataflow per M-tile (grid (gm, gn, gk); K fastest, TPU iteration order):

    (j == 0 and kk == 0):                      # once per M-tile
        x (TM, D) --HBM--> VMEM
        block-CAT (static per-block dots) -> ⊙ combined-sign
        -> Hadamard (two Kronecker-factor dots)
        -> per-token min/max -> scale/zp -> int8 codes
        -> qx scratch (TM, K_pad) int8, sx/zx scratch (TM, 1) f32
    every (j, kk):                             # the contraction
        qw block (TK/2, TN) packed --HBM--> VMEM -> unpack
        o[i,j] += sx·sw·(qx[:, kk·TK:..] @ qw − zx·colsum(qw))

The transform spans the FULL feature dim (CAT blocks / Hadamard factors
mix all of D), so the x block is always (TM, D) and the quantized codes
live in a (TM, K_pad) VMEM scratch revisited across the (N, K) grid —
Pallas only re-fetches x when the M index changes, so activations cross
HBM once per tile. Scratch columns past D are zeroed; the matching
padded weight rows are zero too, so the padding is doubly inert. Padded
M rows quantize an all-zero row to codes == zp and the epilogue cancels
them to exactly 0.

``fused_cat_gemv_w4`` is the decode-shaped sibling (M <= 8 rows kept
whole and VMEM-resident across an (N, K) grid), mirroring
``quant_matmul_w4.quant_gemv_w4``.

Numerics match composing the stand-alone kernels (all-f32 transform,
``ref.dynamic_quant`` signed-shifted codes, int32 accumulation) — the
oracle is ``ref.fused_cat_matmul_w4``; agreement is rtol-level (~1e-6)
because the in-kernel dots may associate differently from the composed
kernels' dots.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .quant_matmul_w4 import _GEMV_M, _unpack_block


def _transform_quant(x_ref, sign_ref, ha_ref, hb_ref, blocks_ref,
                     qx_ref, sx_ref, zx_ref, *, act_bits: int, k_pad: int):
    """Shared once-per-M-tile body: CAT transform + dynamic quant into the
    VMEM scratch refs. All-f32; codes are signed-shifted exactly like
    ``ref.dynamic_quant`` so the contraction epilogue matches the
    stand-alone W4A8 kernels."""
    x = x_ref[...].astype(jnp.float32)
    tm, d = x.shape
    if blocks_ref is not None:
        # block-diag CAT: y[:, i·k:(i+1)·k] = x_i @ B_iᵀ, statically
        # unrolled per block (blocks stay VMEM-resident across the grid)
        nblk, bk, _ = blocks_ref.shape
        parts = []
        for bi in range(nblk):
            xi = x[:, bi * bk:(bi + 1) * bk]
            parts.append(jnp.dot(xi, blocks_ref[bi].T,
                                 preferred_element_type=jnp.float32))
        x = jnp.concatenate(parts, axis=1)
    # combined elementwise vector: Hadamard randomization sign, with any
    # diagonal (Scale) CAT factor folded in by the dispatcher
    x = x * sign_ref[...].astype(jnp.float32)
    a = ha_ref.shape[0]
    b = hb_ref.shape[0]
    ha = ha_ref[...].astype(jnp.float32)
    hb = hb_ref[...].astype(jnp.float32)
    y = jnp.dot(x.reshape(tm * a, b), hb.T,
                preferred_element_type=jnp.float32)
    y = y.reshape(tm, a, b).swapaxes(1, 2).reshape(tm * b, a)
    y = jnp.dot(y, ha.T, preferred_element_type=jnp.float32)
    y = y.reshape(tm, b, a).swapaxes(1, 2).reshape(tm, d)
    # dynamic per-token asymmetric quant (ref.dynamic_quant semantics)
    levels = 2.0 ** act_bits - 1
    ymin = jnp.min(y, axis=-1, keepdims=True)
    ymax = jnp.max(y, axis=-1, keepdims=True)
    scale = jnp.maximum(ymax - ymin, 1e-12) / levels
    zp = jnp.round(-ymin / scale)
    q = jnp.clip(jnp.round(y / scale + zp), 0, levels) - 2.0 ** (act_bits - 1)
    zp = zp - 2.0 ** (act_bits - 1)
    if k_pad > d:   # zero the scratch tail (padded qw rows are zero too)
        q = jnp.concatenate(
            [q, jnp.zeros((tm, k_pad - d), jnp.float32)], axis=1)
    qx_ref[...] = q.astype(jnp.int8)
    sx_ref[...] = scale
    zx_ref[...] = zp


def _contract(qx_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref, *, kk, tk,
              packed: bool):
    """Per-(j, kk) contraction step against the quantized scratch codes
    (the ``quant_matmul_w4`` K-step body, reading qx from scratch)."""
    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    qx = qx_ref[:, pl.ds(kk * tk, tk)].astype(jnp.int32)
    qw = _unpack_block(w_ref[...]) if packed else w_ref[...].astype(jnp.int32)
    acc = jnp.dot(qx, qw, preferred_element_type=jnp.int32).astype(jnp.float32)
    colsum = jnp.sum(qw, axis=0, keepdims=True).astype(jnp.float32)
    o_ref[...] += (sx_ref[...] * sw_ref[...]
                   * (acc - zx_ref[...] * colsum)).astype(o_ref.dtype)


def _make_kernel(act_bits: int, packed: bool, has_blocks: bool, tk: int,
                 k_pad: int, gemv: bool):
    def kernel(*refs):
        if has_blocks:
            (x_ref, sign_ref, ha_ref, hb_ref, blocks_ref, w_ref, sw_ref,
             o_ref, qx_ref, sx_ref, zx_ref) = refs
        else:
            (x_ref, sign_ref, ha_ref, hb_ref, w_ref, sw_ref,
             o_ref, qx_ref, sx_ref, zx_ref) = refs
            blocks_ref = None
        j = pl.program_id(0) if gemv else pl.program_id(1)
        kk = pl.program_id(1) if gemv else pl.program_id(2)

        # transform + quantize ONCE per M-tile: the scratch persists
        # across the (N, K) sweep (grid iterates K fastest, then N, so
        # (j, kk) == (0, 0) is the first visit of each M-tile)
        @pl.when((j == 0) & (kk == 0))
        def _prep():
            _transform_quant(x_ref, sign_ref, ha_ref, hb_ref, blocks_ref,
                             qx_ref, sx_ref, zx_ref, act_bits=act_bits,
                             k_pad=k_pad)

        _contract(qx_ref, sx_ref, zx_ref, w_ref, sw_ref, o_ref, kk=kk,
                  tk=tk, packed=packed)

    return kernel


def _prep_operands(x, blocks, ha, hb, sign, qw, sw, packed, tm, tn, tk):
    """Shared padding/validation -> (padded operands, dims dict)."""
    m, d = x.shape
    if packed:
        k2, n = qw.shape
        assert k2 == (d + 1) // 2, (x.shape, qw.shape)
        k0 = 2 * k2
    else:
        k0, n = qw.shape
        assert k0 == d, (x.shape, qw.shape)
    assert ha.shape[0] * hb.shape[0] == d, (ha.shape, hb.shape, d)
    if blocks is not None:
        nblk, bk, _ = blocks.shape
        assert nblk * bk == d, (blocks.shape, d)
    pk = (-k0) % tk
    pn = (-n) % tn
    pm = (-m) % tm
    if pm:
        x = jnp.pad(x, ((0, pm), (0, 0)))
    if pk or pn:
        pk_rows = pk // 2 if packed else pk
        qw = jnp.pad(qw, ((0, pk_rows), (0, pn)))
        sw = jnp.pad(sw, ((0, 0), (0, pn)), constant_values=1.0)
    return x, qw, sw, dict(m=m, d=d, n=n, k_pad=k0 + pk)


@functools.partial(jax.jit, static_argnames=("act_bits", "packed",
                                             "block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def fused_cat_matmul_w4(x, blocks, ha, hb, sign, qw, sw, *,
                        act_bits: int = 8, packed: bool = True,
                        block_m: int = 128, block_n: int = 256,
                        block_k: int = 512, out_dtype=jnp.float32,
                        interpret: bool = True) -> jnp.ndarray:
    """x (M, D) fp activations; blocks (n, k, k) CAT block factors (None
    for a diagonal/absent CAT stage — fold a ``Scale`` into ``sign``);
    ha/hb Kronecker Hadamard factors; sign (D,) elementwise pre-Hadamard
    vector; qw (ceil(D/2), N) nibble-packed int4 codes — or, with
    ``packed=False``, (D, N) int8 codes; sw (1, N) f32 -> (M, N).

    One pallas_call for the full transform->quant->matmul chain; see the
    module docstring for the dataflow. Odd D follows the packed-weight
    contract (inert zero high nibble; the scratch's matching column is
    explicitly zeroed)."""
    m, d = x.shape
    tm = min(block_m, max(8, m))
    tk = min(block_k, d + d % 2)
    tk += tk % 2
    tn = min(block_n, qw.shape[1])
    x, qw, sw, dims = _prep_operands(x, blocks, ha, hb, sign, qw, sw,
                                     packed, tm, tn, tk)
    k_pad, n = dims["k_pad"], dims["n"]
    gm = x.shape[0] // tm
    gn = qw.shape[1] // tn
    gk = k_pad // tk
    has_blocks = blocks is not None
    kern = _make_kernel(act_bits, packed, has_blocks, tk, k_pad, gemv=False)
    in_specs = [
        pl.BlockSpec((tm, d), lambda i, j, kk: (i, 0)),
        pl.BlockSpec((d,), lambda i, j, kk: (0,)),
        pl.BlockSpec(ha.shape, lambda i, j, kk: (0, 0)),
        pl.BlockSpec(hb.shape, lambda i, j, kk: (0, 0)),
    ]
    operands = [x, sign, ha, hb]
    if has_blocks:
        in_specs.append(pl.BlockSpec(blocks.shape, lambda i, j, kk: (0, 0, 0)))
        operands.append(blocks)
    in_specs += [
        pl.BlockSpec((tk // 2 if packed else tk, tn),
                     lambda i, j, kk: (kk, j)),
        pl.BlockSpec((1, tn), lambda i, j, kk: (0, j)),
    ]
    operands += [qw, sw]
    out = pl.pallas_call(
        kern,
        grid=(gm, gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], qw.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((tm, k_pad), jnp.int8),
                        pltpu.VMEM((tm, 1), jnp.float32),
                        pltpu.VMEM((tm, 1), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:m, :n]


@functools.partial(jax.jit, static_argnames=("act_bits", "packed",
                                             "block_n", "block_k",
                                             "out_dtype", "interpret"))
def fused_cat_gemv_w4(x, blocks, ha, hb, sign, qw, sw, *,
                      act_bits: int = 8, packed: bool = True,
                      block_n: int = 256, block_k: int = 512,
                      out_dtype=jnp.float32,
                      interpret: bool = True) -> jnp.ndarray:
    """Decode-shaped fused chain for M <= 8 single-token rows: the
    activation sliver (padded to 8 rows) is transformed + quantized into
    VMEM once and revisited across the whole (N, K) grid — the packed
    weight is the only HBM stream, as in ``quant_gemv_w4``."""
    m, d = x.shape
    assert m <= _GEMV_M, f"GEMV path is for M<=8 decode shapes, got M={m}"
    tk = min(block_k, d + d % 2)
    tk += tk % 2
    tn = min(block_n, qw.shape[1])
    x, qw, sw, dims = _prep_operands(x, blocks, ha, hb, sign, qw, sw,
                                     packed, _GEMV_M, tn, tk)
    k_pad, n = dims["k_pad"], dims["n"]
    gn = qw.shape[1] // tn
    gk = k_pad // tk
    has_blocks = blocks is not None
    kern = _make_kernel(act_bits, packed, has_blocks, tk, k_pad, gemv=True)
    in_specs = [
        pl.BlockSpec((_GEMV_M, d), lambda j, kk: (0, 0)),
        pl.BlockSpec((d,), lambda j, kk: (0,)),
        pl.BlockSpec(ha.shape, lambda j, kk: (0, 0)),
        pl.BlockSpec(hb.shape, lambda j, kk: (0, 0)),
    ]
    operands = [x, sign, ha, hb]
    if has_blocks:
        in_specs.append(pl.BlockSpec(blocks.shape, lambda j, kk: (0, 0, 0)))
        operands.append(blocks)
    in_specs += [
        pl.BlockSpec((tk // 2 if packed else tk, tn), lambda j, kk: (kk, j)),
        pl.BlockSpec((1, tn), lambda j, kk: (0, j)),
    ]
    operands += [qw, sw]
    out = pl.pallas_call(
        kern,
        grid=(gn, gk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((_GEMV_M, tn), lambda j, kk: (0, j)),
        out_shape=jax.ShapeDtypeStruct((_GEMV_M, qw.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((_GEMV_M, k_pad), jnp.int8),
                        pltpu.VMEM((_GEMV_M, 1), jnp.float32),
                        pltpu.VMEM((_GEMV_M, 1), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:m, :n]
