"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function mirrors the semantics of the corresponding kernel in this
package exactly, including quantization rounding and accumulation dtypes.
"""
from __future__ import annotations

import jax.numpy as jnp


def hadamard_transform(x: jnp.ndarray, ha: jnp.ndarray, hb: jnp.ndarray,
                       sign: jnp.ndarray | None = None) -> jnp.ndarray:
    """y = (x ⊙ sign) @ Hᵀ with H = ha ⊗ hb (orthonormal factors)."""
    a, b = ha.shape[0], hb.shape[0]
    if sign is not None:
        x = x * sign.astype(x.dtype)
    shape = x.shape
    xr = x.astype(jnp.float32).reshape(*shape[:-1], a, b)
    y = jnp.einsum("ij,...jk,lk->...il", ha.astype(jnp.float32), xr,
                   hb.astype(jnp.float32))
    return y.reshape(shape).astype(x.dtype)


def dynamic_quant(x: jnp.ndarray, bits: int = 8, symmetric: bool = False):
    """Per-token (last-axis) dynamic quantization.

    Returns (q int8, scale f32 (..., 1), zp f32 (..., 1)).
    Asymmetric: q in [0, 2^b - 1] stored offset-by-qmax... (int8-safe via
    shifting to signed range: q_signed = q - 2^(b-1)).
    """
    xf = x.astype(jnp.float32)
    if symmetric:
        qmax = 2.0 ** (bits - 1) - 1
        amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
        scale = jnp.maximum(amax, 1e-12) / qmax
        zp = jnp.zeros_like(scale)
        q = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax)
    else:
        levels = 2.0**bits - 1
        xmin = jnp.min(xf, axis=-1, keepdims=True)
        xmax = jnp.max(xf, axis=-1, keepdims=True)
        scale = jnp.maximum(xmax - xmin, 1e-12) / levels
        zp = jnp.round(-xmin / scale)
        q = jnp.clip(jnp.round(xf / scale + zp), 0, levels)
        q = q - 2.0 ** (bits - 1)  # shift to signed storage
        zp = zp - 2.0 ** (bits - 1)
    return q.astype(jnp.int8), scale, zp


def quant_matmul(qx: jnp.ndarray, sx: jnp.ndarray, zpx: jnp.ndarray,
                 qw: jnp.ndarray, sw: jnp.ndarray,
                 out_dtype=jnp.float32) -> jnp.ndarray:
    """y[m,n] = sx[m]·sw[n]·( Σ_k qx[m,k]·qw[k,n] − zpx[m]·Σ_k qw[k,n] ).

    qx: (M, K) int8 (signed-shifted codes), sx/zpx: (M, 1) f32,
    qw: (K, N) int8, sw: (1, N) f32.
    """
    acc = jnp.dot(qx.astype(jnp.int32), qw.astype(jnp.int32),
                  preferred_element_type=jnp.int32)
    colsum = jnp.sum(qw.astype(jnp.int32), axis=0, keepdims=True)
    y = sx * sw * (acc.astype(jnp.float32) - zpx * colsum.astype(jnp.float32))
    return y.astype(out_dtype)


def unpack_int4(packed: jnp.ndarray, k: int | None = None) -> jnp.ndarray:
    """(K//2, N) nibble-packed int8 -> (K, N) int8 codes in [-8, 7].

    Delegates to the canonical layout in repro.core.quantizers so the
    storage contract lives in exactly one place (the kernel's in-VMEM
    _unpack_block is validated against this oracle by the tests).
    """
    from repro.core.quantizers import unpack_int4 as _unpack
    return _unpack(packed, k, axis=0)


def quant_matmul_w4(qx: jnp.ndarray, sx: jnp.ndarray, zpx: jnp.ndarray,
                    qw_packed: jnp.ndarray, sw: jnp.ndarray,
                    out_dtype=jnp.float32) -> jnp.ndarray:
    """W4A8 oracle: unpack the int4 weight codes, then int8 quant_matmul.

    qx: (M, K) int8, sx/zpx: (M, 1) f32, qw_packed: (ceil(K/2), N) int8,
    sw: (1, N) f32.
    """
    qw = unpack_int4(qw_packed, qx.shape[1])
    return quant_matmul(qx, sx, zpx, qw, sw, out_dtype=out_dtype)


def quant_gemv_w4(qx: jnp.ndarray, sx: jnp.ndarray, zpx: jnp.ndarray,
                  qw_packed: jnp.ndarray, sw: jnp.ndarray,
                  out_dtype=jnp.float32) -> jnp.ndarray:
    """Decode-shaped W4A8 GEMV oracle (M ∈ [1, 8] rows).

    The math is exactly ``quant_matmul_w4`` — the kernel differs only in
    blocking (M resident in VMEM, no M grid) — so the oracle delegates;
    a separate name keeps the kernel↔oracle pairing one-to-one."""
    from repro.kernels.quant_matmul_w4 import _GEMV_M
    assert qx.shape[0] <= _GEMV_M, qx.shape
    return quant_matmul_w4(qx, sx, zpx, qw_packed, sw, out_dtype=out_dtype)


def paged_attention_decode(q: jnp.ndarray, k_pages: jnp.ndarray,
                           k_scale, v_pages: jnp.ndarray, v_scale,
                           page_table: jnp.ndarray,
                           lengths: jnp.ndarray) -> jnp.ndarray:
    """Paged decode-attention oracle (mirrors kernels.paged_attention).

    q (B, KVH, g, hd); k/v_pages (n_pages, G, KVH, hd) int8 codes (or fp
    when the matching scale is None); k/v_scale (n_pages, G, KVH, 1) f32;
    page_table (B, n_ptab) int32; lengths (B,) valid kv rows per slot.
    Gathers each slot's logical sequence, dequantizes, and runs a masked
    f32 softmax — positions >= lengths[b] (ragged last pages, null-page
    entries) get exactly zero weight.

    Delegates to the canonical jnp gather path so the semantics live in
    exactly one place (same pattern as ``unpack_int4`` above); the Pallas
    kernel's online-softmax reformulation is what gets validated against
    this."""
    from repro.kernels.paged_attention import paged_attention_fallback
    return paged_attention_fallback(q, k_pages, k_scale, v_pages, v_scale,
                                    page_table, lengths)


def paged_attention_ragged(q: jnp.ndarray, k_pages, k_scale,
                           v_pages: jnp.ndarray, v_scale,
                           page_table: jnp.ndarray, lengths: jnp.ndarray,
                           q_pos: jnp.ndarray) -> jnp.ndarray:
    """Ragged (mixed q_len) paged-attention oracle.

    q (B, Q, KVH, g, hd) per-work-item query blocks; q_pos (B, Q)
    absolute positions (-1 = padding row, fully masked); other operands
    as in ``paged_attention_decode``. Each query row attends exactly the
    kv rows at positions <= its own — the per-(query, kv) causal test
    that makes prefill chunks and decode tokens composable in one batch.

    Delegates to the canonical jnp gather path (same pattern as
    ``paged_attention_decode``); the Pallas kernel's per-page
    online-softmax reformulation is what gets validated against this."""
    from repro.kernels.paged_attention import paged_attention_ragged_fallback
    return paged_attention_ragged_fallback(q, k_pages, k_scale, v_pages,
                                           v_scale, page_table, lengths,
                                           q_pos)


def block_diag_matmul(x: jnp.ndarray, blocks: jnp.ndarray) -> jnp.ndarray:
    """y = x @ Tᵀ for block-diagonal T = Diag(B_1..B_n); blocks (n, k, k).
    y[..., i, a] = Σ_b blocks[i, a, b] · x[..., i, b]."""
    n, k, _ = blocks.shape
    shape = x.shape
    xb = x.astype(jnp.float32).reshape(*shape[:-1], n, k)
    yb = jnp.einsum("...nk,nak->...na", xb, blocks.astype(jnp.float32))
    return yb.reshape(shape).astype(x.dtype)


def fused_hadamard_quant(x, ha, hb, sign, bits: int = 8):
    """Online-transform hot path: Hadamard then per-token dynamic quant."""
    y = hadamard_transform(x, ha, hb, sign)
    return dynamic_quant(y, bits=bits, symmetric=False)


def kernel_transform_quant(x, blocks, ha, hb, sign, *, act_bits: int = 8):
    """CAT transform + dynamic quant in the KERNEL's exact op order.

    Mirrors ``fused_cat_matmul._transform_quant`` operation for operation
    (per-block dots, two Kronecker-factor dots with the same
    reshape/transpose walk, then ``dynamic_quant`` rounding) instead of
    ``hadamard_transform``'s single einsum — f32 dot association is the
    only difference, and matching it makes oracles built on this helper
    **bitwise** against the fused kernels rather than rtol-close.
    Returns (q int8 (M, D), scale f32 (M, 1), zp f32 (M, 1)).
    """
    xf = x.astype(jnp.float32)
    m, d = xf.shape
    if blocks is not None:
        nblk, bk, _ = blocks.shape
        parts = [jnp.dot(xf[:, bi * bk:(bi + 1) * bk],
                         blocks[bi].astype(jnp.float32).T,
                         preferred_element_type=jnp.float32)
                 for bi in range(nblk)]
        xf = jnp.concatenate(parts, axis=1)
    xf = xf * sign.astype(jnp.float32)
    a, b = ha.shape[0], hb.shape[0]
    y = jnp.dot(xf.reshape(m * a, b), hb.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)
    y = y.reshape(m, a, b).swapaxes(1, 2).reshape(m * b, a)
    y = jnp.dot(y, ha.astype(jnp.float32).T,
                preferred_element_type=jnp.float32)
    y = y.reshape(m, b, a).swapaxes(1, 2).reshape(m, d)
    return dynamic_quant(y, bits=act_bits, symmetric=False)


def decode_qkv_prologue(x, blocks, ha, hb, sign, qw, sw,
                        k_pool, k_scale, v_pool, v_scale,
                        page_ids, row_ids, positions, *,
                        n_q: int, head_dim: int, rope_theta: float,
                        kv_bits: int = 8, act_bits: int = 8,
                        packed: bool = True):
    """Oracle for ``kernels.decode_layer.decode_qkv_prologue`` — the
    one-launch decode QKV prologue (CAT -> quant -> W4A8 QKV GEMV ->
    RoPE -> KV int8 quant -> paged scatter).

    Composes ``kernel_transform_quant`` (kernel op order) + the exact
    int32 ``quant_matmul`` + ``models.layers.rope`` + ``quantize_kv`` +
    the ``_write_kv_paged`` scatter. Agreement with the kernel is rtol
    ~1e-6 on the f32 outputs (XLA FMA-contracts the fused mul/sub chains
    inside the jitted launch; this eager composition keeps them
    separate) while the scattered int8 KV codes round identically and
    match bitwise. The kernel additionally parks padded batch rows and
    intermediate flushes on the null page — page 0 is outside the
    contract and excluded from comparison.
    """
    from repro.models.layers import quantize_kv, rope

    m, _ = x.shape
    n = qw.shape[1]
    n_kv = (n - n_q) // 2
    kvh = n_kv // head_dim
    q8, sx, zx = kernel_transform_quant(x, blocks, ha, hb, sign,
                                        act_bits=act_bits)
    w = unpack_int4(qw, x.shape[1]) if packed else qw
    y = quant_matmul(q8, sx, zx, w, sw)
    pos = positions.astype(jnp.int32)[:, None]                  # (M, 1)
    q = rope(y[:, :n_q].reshape(m, 1, n_q // head_dim, head_dim),
             pos, theta=rope_theta).reshape(m, n_q)
    k = rope(y[:, n_q:n_q + n_kv].reshape(m, 1, kvh, head_dim),
             pos, theta=rope_theta).reshape(m, kvh, head_dim)
    v = y[:, n_q + n_kv:].reshape(m, kvh, head_dim)
    kq, ks = quantize_kv(k, bits=kv_bits)
    vq, vs = quantize_kv(v, bits=kv_bits)
    pids = page_ids.astype(jnp.int32)
    rows = row_ids.astype(jnp.int32)
    k_pool = k_pool.at[pids, rows].set(kq, mode="drop")
    k_scale = k_scale.at[pids, rows].set(ks, mode="drop")
    v_pool = v_pool.at[pids, rows].set(vq, mode="drop")
    v_scale = v_scale.at[pids, rows].set(vs, mode="drop")
    return q, k_pool, k_scale, v_pool, v_scale


def fused_cat_matmul_w4(x, blocks, ha, hb, sign, qw, sw, *,
                        act_bits: int = 8, packed: bool = True,
                        out_dtype=jnp.float32) -> jnp.ndarray:
    """Oracle for the single-launch fused serving chain
    (``kernels.fused_cat_matmul``): block-CAT -> (sign ⊙) Hadamard ->
    dynamic per-token asymmetric quant -> W4A8 (or W8A8 with
    ``packed=False``) matmul with the zero-point epilogue.

    x (M, D) fp; blocks (n, k, k) or None; sign (D,) combined elementwise
    vector (Hadamard sign with any Scale CAT factor folded in); qw
    (ceil(D/2), N) packed int4 codes or (D, N) int8 codes; sw (1, N) f32.
    Composes the stand-alone oracles above, so agreement with the fused
    kernel is rtol-level (dot association differs), not bitwise.
    """
    xf = x.astype(jnp.float32)
    if blocks is not None:
        xf = block_diag_matmul(xf, blocks)
    q, s, zp = fused_hadamard_quant(xf, ha, hb, sign, bits=act_bits)
    if packed:
        return quant_matmul_w4(q, s, zp, qw, sw, out_dtype=out_dtype)
    return quant_matmul(q, s, zp, qw, sw, out_dtype=out_dtype)
