"""Pallas TPU kernel: blocked Walsh–Hadamard transform.

TPU-native formulation (DESIGN.md §3): H_d = H_a ⊗ H_b, so the transform
of a token tile X (TM, d) is two dense matmuls on the reshaped (TM·a, b)
and (TM·b, a) views — both map onto the 128×128 MXU. The factor matrices
(≤ 192×192 for every assigned dim) stay resident in VMEM across the grid.

Grid: one program per TM-token tile. VMEM per step ≈ TM·d·4 B ·2 (in+out)
+ a² + b² floats; TM=256, d=4096 ⇒ ~8.4 MB < 16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hadamard_kernel(x_ref, sign_ref, ha_ref, hb_ref, o_ref):
    x = x_ref[...].astype(jnp.float32) * sign_ref[...].astype(jnp.float32)
    tm, d = x.shape
    a = ha_ref.shape[0]
    b = hb_ref.shape[0]
    ha = ha_ref[...].astype(jnp.float32)
    hb = hb_ref[...].astype(jnp.float32)
    # right factor: (TM·a, b) @ hbᵀ
    y = jnp.dot(x.reshape(tm * a, b), hb.T, preferred_element_type=jnp.float32)
    # left factor: contract the a axis with haᵀ
    y = y.reshape(tm, a, b).swapaxes(1, 2).reshape(tm * b, a)
    y = jnp.dot(y, ha.T, preferred_element_type=jnp.float32)
    y = y.reshape(tm, b, a).swapaxes(1, 2).reshape(tm, d)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_tokens", "interpret"))
def hadamard_transform(x: jnp.ndarray, ha: jnp.ndarray, hb: jnp.ndarray,
                       sign: jnp.ndarray | None = None,
                       block_tokens: int = 256,
                       interpret: bool = True) -> jnp.ndarray:
    """y = (x ⊙ sign) @ (ha ⊗ hb)ᵀ for x of shape (..., d). Tokens are
    padded up to a multiple of block_tokens (cheap; removed after)."""
    a, b = ha.shape[0], hb.shape[0]
    d = a * b
    assert x.shape[-1] == d, (x.shape, a, b)
    if sign is None:
        sign = jnp.ones((d,), jnp.float32)
    lead = x.shape[:-1]
    xf = x.reshape(-1, d)
    m = xf.shape[0]
    tm = min(block_tokens, max(m, 1))
    pad = (-m) % tm
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    grid = (xf.shape[0] // tm,)
    out = pl.pallas_call(
        _hadamard_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((a, a), lambda i: (0, 0)),
            pl.BlockSpec((b, b), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, x.dtype),
        interpret=interpret,
    )(xf, sign, ha, hb)
    if pad:
        out = out[:m]
    return out.reshape(*lead, d)
