"""Unified model API: ``build(cfg) -> Model`` bundle of pure functions.

Families: dense (gemma2/3, mistral-nemo, granite, paligemma backbone,
catlm), moe (dense skeleton + expert MLP), ssm (rwkv6), hybrid (zamba2),
encdec (whisper), vlm (dense + prefix patch embeddings).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import dense, rwkv, whisper, zamba


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    init: Callable            # (rng) -> params
    forward: Callable         # (params, tokens, **kw) -> (hidden, aux, cache)
    logits: Callable          # (params, hidden) -> logits
    loss: Callable            # (params, batch) -> (loss, metrics)
    init_cache: Callable      # (batch, max_len) -> cache
    prefill: Callable         # (params, tokens, cache, **kw) -> (logits, cache)
    decode: Callable          # (params, token, cache) -> (logits, cache)
    # (n_pages, page_size) -> paged KV pool; None for families without a
    # paged decode path (ssm/hybrid/encdec keep recurrent or dense state)
    init_paged_cache: Optional[Callable] = None
    # (cache, src (C,), dst (C,)) -> cache with pages src copied to dst
    # on every pool leaf — the prefix cache's COW split (None for
    # families without a paged pool)
    copy_paged_pages: Optional[Callable] = None
    # (params, tokens (T,1), cache, logit_rows) -> (logits (R,1,V), cache):
    # the unified token-budget step over a flat ragged batch of mixed
    # prefill-chunk + decode rows (None for families without one).
    # ``greedy=True`` returns (tokens (R,) int32, cache) instead — the
    # argmax folds into the jitted step (device-resident sampling for
    # the pipelined serve loop; see launch/README.md)
    ragged_step: Optional[Callable] = None
    # (params) -> fused-serving params (QKV/gate-up concat + colsum /
    # pre-unpacked codes; see models.dense.make_serving_params); None for
    # families without a fused hot path. The serve engine applies it at
    # build time on the single-device path.
    make_serving_params: Optional[Callable] = None


_FAMILIES = {
    "dense": dense, "moe": dense, "vlm": dense,
    "ssm": rwkv, "hybrid": zamba, "encdec": whisper,
}


def build(cfg) -> Model:
    mod = _FAMILIES[cfg.family]
    return Model(
        cfg=cfg,
        init=lambda rng: mod.init(cfg, rng),
        forward=lambda params, tokens, **kw: mod.forward(cfg, params,
                                                         tokens, **kw),
        logits=lambda params, hidden: mod.logits_fn(cfg, params, hidden),
        loss=lambda params, batch: mod.loss(cfg, params, batch),
        init_cache=lambda batch, max_len: mod.init_cache(cfg, batch, max_len),
        prefill=lambda params, tokens, cache, **kw: mod.prefill(
            cfg, params, tokens, cache, **kw),
        decode=lambda params, token, cache, **kw: mod.decode(cfg, params,
                                                             token, cache,
                                                             **kw),
        init_paged_cache=(
            (lambda n_pages, page_size: mod.init_paged_cache(
                cfg, n_pages, page_size))
            if hasattr(mod, "init_paged_cache") else None),
        copy_paged_pages=(
            (lambda cache, src, dst: mod.copy_paged_pages(
                cfg, cache, src, dst))
            if hasattr(mod, "copy_paged_pages") else None),
        ragged_step=(
            (lambda params, tokens, cache, logit_rows, **kw:
             mod.ragged_step(cfg, params, tokens, cache, logit_rows, **kw))
            if hasattr(mod, "ragged_step") else None),
        make_serving_params=(
            (lambda params, **kw: mod.make_serving_params(cfg, params, **kw))
            if hasattr(mod, "make_serving_params") else None),
    )


def param_count(params) -> int:
    return sum(p.size for p in jax.tree.leaves(params)
               if isinstance(p, jnp.ndarray))


def active_param_count(cfg, params) -> int:
    """MoE: routed experts count only top_k/E of expert params."""
    total = param_count(params)
    if not cfg.n_experts:
        return total
    expert = 0
    layers = params.get("layers", {})
    for name in ("we_g", "we_u", "we_d"):
        if name in layers:
            expert += layers[name].size
    return total - expert + int(expert * cfg.top_k / cfg.n_experts)


def train_step_fn(model: Model, optimizer):
    """Returns a pure (params, opt_state, batch) -> (params, opt_state,
    metrics) training step (the unit the launcher jits/lowers)."""

    def step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(model.loss, has_aux=True)(
            params, batch)
        params, opt_state = optimizer.update(params, grads, opt_state)
        metrics = dict(metrics, loss=l)
        return params, opt_state, metrics

    return step
