"""Zamba2 hybrid: Mamba-2 (SSD) backbone with a *shared* attention+MLP
block invoked every `attn_every` layers (one set of attention weights,
reused at every invocation site — the Zamba trick).

Mamba-2 blocks use the shared GLA core with per-head scalar decay
(SSD ≡ linear attention with scalar gate): decay from softplus(dt)·exp(A),
B/C projections play k/r, a depthwise causal conv precedes the SSM, and a
gated (silu z) output path follows it.

Decode state: per-layer (conv tail (B, convw-1, Cin), SSD state
(B, H, state, hd)) + KV caches for each shared-attn invocation site —
O(1) in context for the mamba part, so this arch runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.models import gla
from repro.models.layers import (chunked_attention, cache_update, glu_mlp,
                                 rms_norm, rope, softcap)

CONV_W = 4


def _d_inner(cfg):
    return 2 * cfg.d_model


def _hd(cfg):
    return _d_inner(cfg) // cfg.ssm_heads


def _conv_ch(cfg):
    return _d_inner(cfg) + 2 * cfg.ssm_state


def n_attn_sites(cfg) -> int:
    return -(-cfg.n_layers // cfg.attn_every)


def init(cfg, rng):
    keys = iter(jax.random.split(rng, 32))
    L, D = cfg.n_layers, cfg.d_model
    di, st, H = _d_inner(cfg), cfg.ssm_state, cfg.ssm_heads

    def lins(n, d_in, d_out):
        ks = jax.random.split(next(keys), n)
        return jax.vmap(lambda k: jax.random.normal(k, (d_in, d_out)) /
                        jnp.sqrt(d_in))(ks)

    # separate projections (not one fused in_proj) => every weight's output
    # dim is cleanly TP-shardable (standard Mamba TP split; DESIGN.md §4)
    mamba = {
        "ln": jnp.zeros((L, D)),
        "in_x": lins(L, D, di),
        "in_z": lins(L, D, di),
        "in_b": lins(L, D, st),
        "in_c": lins(L, D, st),
        "in_dt": lins(L, D, H),
        "conv_w": jax.random.normal(next(keys), (L, CONV_W, _conv_ch(cfg)))
                  * 0.2,
        "a_log": jnp.zeros((L, H)),
        "dt_bias": jnp.zeros((L, H)),
        "d_skip": jnp.ones((L, H)),
        "ln_out": jnp.zeros((L, di)),
        "out_proj": lins(L, di, D),
    }
    Hq, Hkv = cfg.q_dim, cfg.kv_dim

    def lin1(d_in, d_out):
        return (jax.random.normal(next(keys), (d_in, d_out)) /
                jnp.sqrt(d_in))

    shared = {  # ONE block, reused at every site
        "ln1": jnp.zeros((D,)), "ln2": jnp.zeros((D,)),
        "wq": lin1(D, Hq), "wk": lin1(D, Hkv), "wv": lin1(D, Hkv),
        "wo": lin1(Hq, D),
        "wg": lin1(D, cfg.d_ff), "wu": lin1(D, cfg.d_ff),
        "wd": lin1(cfg.d_ff, D),
    }
    return {
        "embed": jax.random.normal(next(keys), (cfg.vocab, D)) * 0.02,
        "final_norm": jnp.zeros((D,)),
        "mamba": mamba,
        "shared_attn": shared,
    }


def _causal_conv(x, w, tail):
    """Depthwise causal conv: x (B, S, C), w (CONV_W, C), tail (B, CONV_W-1, C).
    Returns (y (B, S, C), new_tail)."""
    xx = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(xx[:, i:i + x.shape[1]] * w[i][None, None] for i in range(CONV_W))
    new_tail = xx[:, -(CONV_W - 1):] if CONV_W > 1 else tail
    return jax.nn.silu(y), new_tail


def _mamba_layer(cfg, x, lp, state, taps=None, layer_idx=None):
    b, s, d = x.shape
    di, stt, H = _d_inner(cfg), cfg.ssm_state, cfg.ssm_heads
    hd = _hd(cfg)
    h = rms_norm(x, lp["ln"])
    if taps is not None:
        taps.record(f"layers.{layer_idx}.mamba_in", h)
    xs_ = qlinear.dense(lp["in_x"], h)
    z = qlinear.dense(lp["in_z"], h)
    bmat = qlinear.dense(lp["in_b"], h)
    cmat = qlinear.dense(lp["in_c"], h)
    dt = qlinear.dense(lp["in_dt"], h)
    conv_in = jnp.concatenate([xs_, bmat, cmat], axis=-1)
    conv_out, new_tail = _causal_conv(conv_in, lp["conv_w"], state["conv"])
    xs_, bmat, cmat = jnp.split(conv_out, [di, di + stt], axis=-1)

    # SSD: scalar per-head decay; B/C shared across heads — the factored
    # chunked form (§Perf B1) never materializes (B,S,H,state) broadcasts
    dtp = jax.nn.softplus(dt.astype(jnp.float32)
                          + lp["dt_bias"].astype(jnp.float32))  # (B,S,H)
    log_w = gla.clamp_log_decay(-dtp * jnp.exp(lp["a_log"].astype(jnp.float32)))
    v = (xs_.reshape(b, s, H, hd)
         * dtp.astype(xs_.dtype)[..., None])             # dt-scaled input
    if s == 1:
        o, S = gla.ssd_decode_step(cmat[:, 0], bmat[:, 0], v[:, 0],
                                   log_w[:, 0], state["ssd"])
        o = o[:, None]
    else:
        o, S = gla.ssd_chunked(cmat, bmat, v, log_w, state=state["ssd"])
    o = o + lp["d_skip"].astype(jnp.float32)[None, None, :, None] \
        * xs_.reshape(b, s, H, hd).astype(jnp.float32)
    o = o.reshape(b, s, di).astype(x.dtype) * jax.nn.silu(z)
    o = rms_norm(o, lp["ln_out"])
    if taps is not None:
        taps.record(f"layers.{layer_idx}.mamba_out_in", o)
    x = x + qlinear.dense(lp["out_proj"], o)
    return x, {"conv": new_tail, "ssd": S}


def _shared_attn_block(cfg, x, sp, kv, pos, positions, taps=None, site=None):
    b, s, d = x.shape
    h = rms_norm(x, sp["ln1"])
    if taps is not None:
        taps.record(f"shared.{site}.attn_in", h)
    q = qlinear.dense(sp["wq"], h).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = qlinear.dense(sp["wk"], h).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = qlinear.dense(sp["wv"], h).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if kv is not None:
        ck, cv = cache_update(kv[0], kv[1], k, v, pos)
        k_att, v_att = ck, cv
        kv = (ck, cv)
    else:
        k_att, v_att = k, v
    o = chunked_attention(q, k_att.astype(x.dtype), v_att.astype(x.dtype),
                          q_positions=positions, causal=True)
    o = o.reshape(b, s, cfg.q_dim)
    if taps is not None:
        taps.record(f"shared.{site}.o_in", o)
    x = x + qlinear.dense(sp["wo"], o)
    h2 = rms_norm(x, sp["ln2"])
    if taps is not None:
        taps.record(f"shared.{site}.mlp_in", h2)
    from repro.models.layers import activation
    hmid = activation(cfg.act)(qlinear.dense(sp["wg"], h2)) \
        * qlinear.dense(sp["wu"], h2)
    if taps is not None:
        taps.record(f"shared.{site}.down_in", hmid)
    x = x + qlinear.dense(sp["wd"], hmid)
    return x, kv


def forward(cfg, params, tokens, *, cache=None, taps=None,
            unroll: bool = False, extra_embed=None):
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"][tokens].astype(cd)
    b, s, _ = x.shape
    state = cache if cache is not None else init_cache(cfg, b, 0)
    pos = state["pos"]
    positions = pos + jnp.arange(s, dtype=jnp.int32)
    E = cfg.attn_every
    sites = n_attn_sites(cfg)
    new_kv = []
    new_m = []
    if unroll or taps is not None:
        for g in range(sites):
            kv_g = None
            if state["attn_k"] is not None:
                kv_g = (state["attn_k"][g], state["attn_v"][g])
            x, kv_g = _shared_attn_block(cfg, x, params["shared_attn"],
                                         kv_g, pos, positions, taps, g)
            if kv_g is not None:
                new_kv.append(kv_g)
            for i in range(g * E, min((g + 1) * E, cfg.n_layers)):
                lp = jax.tree.map(lambda a: a[i], params["mamba"])
                st = jax.tree.map(lambda a: a[i], state["mamba"])
                x, st = _mamba_layer(cfg, x, lp, st, taps=taps, layer_idx=i)
                new_m.append(st)
    else:
        # §Perf B2/B3: the whole backbone is ONE scan over homogeneous
        # (shared-attn + E mamba layers) groups — unrolled Python-loop
        # segments were assigned DISTINCT backward buffers (9+ GiB/site,
        # 14 sites live simultaneously). Nested remat: checkpointed layer
        # body inside a checkpointed group body — peak residency becomes
        # one layer's internals + 29 MB SP-sharded carries.
        from repro.models.flags import scan as _scan

        def layer_body(x, xs):
            lp, st = xs
            x, st = _mamba_layer(cfg, x, lp, st)
            if cfg.act_shard == "seq":
                from repro.distributed.act_sharding import constrain_seq
                x = constrain_seq(x)
            return x, st

        inner = jax.checkpoint(layer_body) if cfg.remat else layer_body

        def group_body(x, xs):
            gp, gs, kv_g = xs
            x, kv_g = _shared_attn_block(cfg, x, params["shared_attn"],
                                         kv_g, pos, positions, None, None)
            x, st_g = _scan(inner, x, (gp, gs))
            return x, (kv_g, st_g)

        outer = jax.checkpoint(group_body) if cfg.remat else group_body

        n_full = cfg.n_layers // E
        rem = cfg.n_layers - n_full * E
        regroup = lambda a: a[:n_full * E].reshape(n_full, E, *a.shape[1:])
        gm = jax.tree.map(regroup, params["mamba"])
        gst = jax.tree.map(regroup, state["mamba"])
        if state["attn_k"] is not None:
            kv_xs = (state["attn_k"][:n_full], state["attn_v"][:n_full])
        else:
            kv_xs = (None, None)
        x, (kv_ys, st_ys) = _scan(
            lambda c, xs: outer(c, (xs[0], xs[1],
                                    (xs[2], xs[3]) if xs[2] is not None
                                    else None)),
            x, (gm, gst, kv_xs[0], kv_xs[1]))
        ungroup = lambda a: a.reshape(n_full * E, *a.shape[2:])
        new_m.append(jax.tree.map(ungroup, st_ys))
        if kv_ys is not None:
            new_kv.append(kv_ys)

        if rem:  # trailing site: attn + remaining layers
            kv_g = None
            if state["attn_k"] is not None:
                kv_g = (state["attn_k"][n_full], state["attn_v"][n_full])
            x, kv_g = _shared_attn_block(cfg, x, params["shared_attn"],
                                         kv_g, pos, positions, None, None)
            sl = lambda a: a[n_full * E:]
            x, st_t = _scan(inner, x, (jax.tree.map(sl, params["mamba"]),
                                       jax.tree.map(sl, state["mamba"])))
            new_m.append(st_t)
            if kv_g is not None:
                new_kv.append(jax.tree.map(lambda a: a[None], kv_g))
    x = rms_norm(x, params["final_norm"])
    if unroll or taps is not None:
        new_mamba = jax.tree.map(lambda *xs: jnp.stack(xs), *new_m)
        attn_k = jnp.stack([kv[0] for kv in new_kv]) if new_kv else None
        attn_v = jnp.stack([kv[1] for kv in new_kv]) if new_kv else None
    else:
        new_mamba = (new_m[0] if len(new_m) == 1 else
                     jax.tree.map(lambda *xs: jnp.concatenate(xs), *new_m))
        attn_k = jnp.concatenate([kv[0] for kv in new_kv]) if new_kv \
            else None
        attn_v = jnp.concatenate([kv[1] for kv in new_kv]) if new_kv \
            else None
    new_cache = {
        "mamba": new_mamba,
        "attn_k": attn_k,
        "attn_v": attn_v,
        "pos": pos + s,
    }
    return x, jnp.zeros((), jnp.float32), new_cache


def logits_fn(cfg, params, hidden):
    return softcap(hidden @ params["embed"].T.astype(hidden.dtype),
                   cfg.logit_softcap)


def init_cache(cfg, batch_size: int, max_len: int = 0) -> dict:
    L, H, stt = cfg.n_layers, cfg.ssm_heads, cfg.ssm_state
    hd = _hd(cfg)
    sites = n_attn_sites(cfg)
    cache = {
        "mamba": {
            "conv": jnp.zeros((L, batch_size, CONV_W - 1, _conv_ch(cfg)),
                              jnp.bfloat16),
            "ssd": jnp.zeros((L, batch_size, H, stt, hd), jnp.float32),
        },
        "attn_k": None,
        "attn_v": None,
        "pos": jnp.int32(0),
    }
    if max_len > 0:
        shape = (sites, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
        cache["attn_k"] = jnp.zeros(shape, jnp.bfloat16)
        cache["attn_v"] = jnp.zeros(shape, jnp.bfloat16)
    return cache


def loss(cfg, params, batch, **kw):
    from repro.models.losses import chunked_ce
    hidden, aux, _ = forward(cfg, params, batch["tokens"])
    return chunked_ce(lambda h: logits_fn(cfg, params, h), hidden,
                      batch["labels"], aux)


def prefill(cfg, params, tokens, cache, extra_embed=None):
    hidden, _, cache = forward(cfg, params, tokens, cache=cache)
    return logits_fn(cfg, params, hidden[:, -1:]), cache


def decode(cfg, params, token, cache):
    hidden, _, cache = forward(cfg, params, token, cache=cache)
    return logits_fn(cfg, params, hidden), cache
