from repro.models.model import Model, build, param_count  # noqa: F401
