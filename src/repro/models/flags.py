"""Tracing-time flags for cost-exact lowering.

XLA's cost_analysis counts a lax.scan body ONCE (trip count is not
multiplied in). The roofline harness therefore lowers small (L=p, L=2p)
model variants in `exact_cost_mode()`, which makes every scan in the model
zoo fully unroll — per-layer/per-chunk ops then appear in the HLO the
correct number of times and the L-extrapolation is exact. Normal runs
keep rolled scans (small HLO, fast compiles).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

_UNROLL = contextvars.ContextVar("repro_unroll_scans", default=False)


@contextlib.contextmanager
def exact_cost_mode():
    tok = _UNROLL.set(True)
    try:
        yield
    finally:
        _UNROLL.reset(tok)


def exact_cost() -> bool:
    return _UNROLL.get()


def scan(body, carry, xs, **kw):
    """jax.lax.scan that fully unrolls under exact_cost_mode().

    Used for the LAYER scans (small trip counts at the L=p/2p cost cells).
    Inner chunk scans instead switch to a single chunk in exact mode
    (attention/loss: nc=1 has identical FLOPs to the chunked algorithm and
    keeps the graph small; GLA keeps its real chunk size — its recurrence
    FLOPs are <2% of the projections, undercount documented)."""
    if _UNROLL.get():
        kw = dict(kw, unroll=True)
    return jax.lax.scan(body, carry, xs, **kw)
