"""Chunked gated linear attention — the shared recurrence core for RWKV-6
(per-channel data-dependent decay) and Mamba-2 SSD (per-head scalar decay).

Recurrence (per head, state S ∈ R^{dk×dv}):
    S_t = Diag(w_t) S_{t-1} + k_t v_tᵀ
    o_t = S_{t-1}ᵀ r_t                (+ caller-specific bonus terms)

TPU-native chunked form (DESIGN.md §3): within a chunk of C steps all
cross-terms become two MXU matmuls using cumulative log-decay c_t:
    A[t,i] = (r_t ⊙ e^{c_{t-1}-c_C}) · (k_i ⊙ e^{c_C-c_i}),  i < t
    inter  = (r_t ⊙ e^{c_{t-1}}) S
    S'     = Diag(e^{c_C}) S + Σ_i (k_i ⊙ e^{c_C-c_i}) v_iᵀ

Stability: log-decay is clamped to [LOG_W_MIN, 0] per step so the
intra-chunk exponential span is bounded by |LOG_W_MIN|·C (< f32 range).
A production TPU kernel would instead renormalize per 16-step sub-chunk
(FLA-style); the clamp keeps the pure-JAX reference exact w.r.t. itself
and is recorded as a hardware-adaptation note in DESIGN.md.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

LOG_W_MIN = -1.0
CHUNK = 32


def clamp_log_decay(log_w: jnp.ndarray) -> jnp.ndarray:
    return jnp.clip(log_w, LOG_W_MIN, -1e-6)


def gla_chunked(r, k, v, log_w, state=None, chunk: int = CHUNK):
    """r, k: (B, S, H, dk); v: (B, S, H, dv); log_w: (B, S, H, dk) in
    [LOG_W_MIN, 0). state: (B, H, dk, dv) initial (zeros if None).
    Returns (o (B, S, H, dv), final_state).
    """
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    f32 = jnp.float32
    r, k, v, log_w = (t.astype(f32) for t in (r, k, v, log_w))
    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)

    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        z = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)),
                        constant_values=-1e-6)
    nc = r.shape[1] // c

    def resh(t):
        return t.reshape(b, nc, c, h, -1).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(log_w)
    mask = jnp.tril(jnp.ones((c, c), f32), -1)  # strictly causal (i < t)

    def step(S, xs):
        rj, kj, vj, wj = xs                      # (B, C, H, dk|dv)
        cum = jnp.cumsum(wj, axis=1)             # c_t
        c_prev = cum - wj                        # c_{t-1}
        c_tot = cum[:, -1:]                      # c_C
        q_in = rj * jnp.exp(c_prev - c_tot)      # bounded by e^{|min|·C}
        k_in = kj * jnp.exp(c_tot - cum)         # ≤ 1
        scores = jnp.einsum("bthd,bshd->bhts", q_in, k_in) * mask
        o_intra = jnp.einsum("bhts,bshv->bthv", scores, vj)
        o_inter = jnp.einsum("bthd,bhdv->bthv", rj * jnp.exp(c_prev), S)
        S_new = (jnp.exp(c_tot)[:, 0, :, :, None] * S
                 + jnp.einsum("bshd,bshv->bhdv", k_in, vj))
        return S_new, o_intra + o_inter

    # plain scan: GLA recurrence FLOPs are <2% of the surrounding
    # projections; exact-cost mode leaves this rolled (see flags.scan)
    state, oc = jax.lax.scan(step, state, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, dv)
    if pad:
        o = o[:, :s]
    return o, state


def ssd_chunked(r, k, v, log_w, state=None, chunk: int = CHUNK):
    """Mamba-2 SSD chunked form — decay is SCALAR per head, and r/k (the
    C/B projections) are SHARED across heads, so the intra-chunk inner
    product is computed ONCE (head-independent) and per-head decay enters
    as a chunk-local (C×C) elementwise factor. Versus broadcasting r/k to
    (B,S,H,dk) and reusing gla_chunked, this removes the H× blowup in
    both FLOPs (scores) and transient memory (§Perf B1).

    r, k: (B, S, dk); v: (B, S, H, dv); log_w: (B, S, H) in [LOG_W_MIN, 0).
    state: (B, H, dk, dv). Returns (o (B, S, H, dv), final state).

    §Perf B4: r/k/v stay in their compute dtype (bf16 — halves the
    dominant (B,S,H,dv) transients); decay math and the carried state are
    f32 (the recurrence is the numerically sensitive part).
    """
    b, s, dk = r.shape
    _, _, h, dv = v.shape
    f32 = jnp.float32
    log_w = log_w.astype(f32)
    if state is None:
        state = jnp.zeros((b, h, dk, dv), f32)
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0)),
                        constant_values=-1e-6)
    nc = r.shape[1] // c
    rc = r.reshape(b, nc, c, dk).transpose(1, 0, 2, 3)
    kc = k.reshape(b, nc, c, dk).transpose(1, 0, 2, 3)
    vc = v.reshape(b, nc, c, h, dv).transpose(1, 0, 2, 3, 4)
    wc = log_w.reshape(b, nc, c, h).transpose(1, 0, 2, 3)
    mask = jnp.tril(jnp.ones((c, c), f32), -1)

    def step(S, xs):
        rj, kj, vj, wj = xs
        cum = jnp.cumsum(wj, axis=1)              # (B, C, H)
        c_prev = cum - wj
        c_tot = cum[:, -1]                        # (B, H)
        inner = jnp.einsum("btd,bsd->bts", rj, kj,
                           preferred_element_type=f32)      # head-free
        decay = jnp.exp(c_prev[:, :, None, :] - cum[:, None, :, :])
        decay = decay * mask[None, :, :, None]              # (B,C,C,H)
        o_intra = jnp.einsum("bts,btsh,bshv->bthv", inner, decay,
                             vj.astype(f32))
        o_inter = jnp.einsum("btd,bth,bhdv->bthv", rj.astype(f32),
                             jnp.exp(c_prev), S)
        k_dec = jnp.exp(c_tot[:, None, :] - cum)            # (B,C,H) ≤ 1
        S = (jnp.exp(c_tot)[:, :, None, None] * S
             + jnp.einsum("bsd,bsh,bshv->bhdv", kj.astype(f32), k_dec,
                          vj.astype(f32)))
        return S, o_intra + o_inter

    state, oc = jax.lax.scan(step, state, (rc, kc, vc, wc))
    o = oc.transpose(1, 0, 2, 3, 4).reshape(b, nc * c, h, dv)
    if pad:
        o = o[:, :s]
    return o, state


def ssd_decode_step(r, k, v, log_w, state):
    """One-token SSD update. r/k: (B, dk); v: (B, H, dv); log_w: (B, H);
    state: (B, H, dk, dv)."""
    f32 = jnp.float32
    r, k, v, log_w = (t.astype(f32) for t in (r, k, v, log_w))
    o = jnp.einsum("bd,bhdv->bhv", r, state)
    state = jnp.exp(log_w)[..., None, None] * state \
        + k[:, None, :, None] * v[:, :, None, :]
    return o, state


def gla_decode_step(r, k, v, log_w, state):
    """Single-token recurrent update. r/k: (B, H, dk); v: (B, H, dv);
    log_w: (B, H, dk); state: (B, H, dk, dv). Returns (o (B,H,dv), state)."""
    f32 = jnp.float32
    r, k, v, log_w = (t.astype(f32) for t in (r, k, v, log_w))
    o = jnp.einsum("bhd,bhdv->bhv", r, state)
    state = jnp.exp(log_w)[..., None] * state + k[..., None] * v[..., None, :]
    return o, state


def gla_reference(r, k, v, log_w, state=None):
    """O(S) sequential oracle for tests."""
    b, s, h, dk = r.shape
    dv = v.shape[-1]
    if state is None:
        state = jnp.zeros((b, h, dk, dv), jnp.float32)
    outs = []
    for t in range(s):
        o, state = gla_decode_step(r[:, t], k[:, t], v[:, t], log_w[:, t],
                                   state)
        outs.append(o)
    return jnp.stack(outs, axis=1), state
