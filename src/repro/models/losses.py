"""Shared loss utilities: sequence-chunked cross-entropy.

The (B, S, V) logits tensor is never materialized — a scan over sequence
chunks computes per-chunk logits + LSE and accumulates scalars. Under TP
the vocab axis is model-sharded, so per-chunk peak bytes are
B·chunk·V/TP·4, which keeps 256k-vocab × 1M-token train cells in HBM.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def chunked_ce(logits_fn, hidden: jnp.ndarray, labels: jnp.ndarray,
               aux: jnp.ndarray | float = 0.0, aux_coef: float = 0.01,
               loss_chunk: int = 512):
    """logits_fn: hidden_chunk (B, c, D) -> logits (B, c, V).
    labels < 0 are masked. Returns (total_loss, metrics)."""
    from repro.models.flags import exact_cost
    b, s, d = hidden.shape
    c = s if exact_cost() else min(loss_chunk, s)
    pad = (-s) % c
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)), constant_values=-1)
    nc = hidden.shape[1] // c
    hc = hidden.reshape(b, nc, c, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, nc, c).transpose(1, 0, 2)

    def step(carry, xs):
        tot, cnt = carry
        h, lab = xs
        logits = logits_fn(h).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, jnp.maximum(lab, 0)[..., None],
                                   axis=-1)[..., 0]
        mask = (lab >= 0).astype(jnp.float32)
        return (tot + jnp.sum((lse - gold) * mask),
                cnt + jnp.sum(mask)), None

    from repro.models.flags import scan as _scan
    (tot, cnt), _ = _scan(step, (jnp.float32(0), jnp.float32(0)), (hc, lc))
    ce = tot / jnp.maximum(cnt, 1.0)
    aux = jnp.asarray(aux, jnp.float32)
    return ce + aux_coef * aux, {"ce": ce, "aux": aux}
