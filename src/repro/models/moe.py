"""Mixture-of-Experts MLP with grouped, capacity-based einsum dispatch.

GSPMD-native MoE: tokens are first reshaped into groups (the dispatch
tensors then carry a leading group dim, so their size is T·E·C_g instead
of T·E·C — the difference between MBs and TBs at train scale), experts
are sharded on the "model" axis (EP: the ecd einsums lower to all-to-all),
and compute scales with capacity not E.

Aux loss is the standard switch load-balancing term.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.models.layers import activation

GROUP_SIZE = 4096  # tokens per dispatch group (≈ one data shard's worth)


def init_layers(cfg, rng) -> dict:
    L, D, F, E = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(rng, 4)

    def lins(k, *shape):
        fan_in = shape[-2]
        keys = jax.random.split(k, L)
        return jax.vmap(lambda kk: jax.random.normal(kk, shape) /
                        jnp.sqrt(fan_in))(keys)

    return {
        "router": lins(ks[0], D, E),
        "we_g": lins(ks[1], E, D, F),
        "we_u": lins(ks[2], E, D, F),
        "we_d": lins(ks[3], E, F, D),
    }


def group_capacity(cfg, group_size: int) -> int:
    c = int(cfg.capacity_factor * group_size * cfg.top_k / cfg.n_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def moe_mlp(cfg, lp, x, taps=None, layer_idx=None):
    """x (B, S, D) -> (out (B, S, D), aux_loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    gs = min(GROUP_SIZE, t)
    pad = (-t) % gs
    xf = x.reshape(t, d)
    if pad:
        xf = jnp.pad(xf, ((0, pad), (0, 0)))
    g = xf.shape[0] // gs
    xg = xf.reshape(g, gs, d)
    cap = group_capacity(cfg, gs)

    # §Perf A3: bf16 router input on the wire, f32 MXU accumulation
    gate_logits = jnp.einsum(
        "gtd,de->gte", xg,
        qlinear.dense_params(lp["router"]).astype(xg.dtype),
        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(gate_logits, axis=-1)            # (G, Tg, E)
    gate_w, sel = jax.lax.top_k(probs, k)                   # (G, Tg, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    sel_oh = jax.nn.one_hot(sel, e, dtype=jnp.float32)      # (G, Tg, k, E)
    # position of each (token, slot) within its expert's per-group queue
    pos = jnp.cumsum(sel_oh.reshape(g, gs * k, e), axis=1
                     ).reshape(g, gs, k, e) - 1.0
    pos = jnp.sum(pos * sel_oh, axis=-1)                    # (G, Tg, k)
    keep = pos < cap
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap,
                            dtype=jnp.float32) * keep[..., None]
    cd = x.dtype
    # §Perf A1: dispatch/combine are the largest MoE tensors (G·Tg·E·C);
    # bf16 wire format + explicit EP sharding (E on "model") halves the
    # cross-model traffic GSPMD would otherwise all-reduce in f32.
    # §Perf A2: the one-hot routing masks are piecewise-constant (zero
    # gradient a.e.) — stop_gradient them and carry the differentiable
    # gate as a small (G,Tg,E) factor, so backward never materializes /
    # all-gathers a (G,Tg,E,C) gradient.
    mask = jax.lax.stop_gradient(
        jnp.einsum("gtke,gtkc->gtec", sel_oh, pos_oh).astype(cd))
    gate_te = jnp.einsum("gtke,gtk->gte",
                         jax.lax.stop_gradient(sel_oh), gate_w).astype(cd)
    dispatch = _constrain_ep(mask)
    combine = _constrain_ep(mask * gate_te[..., None])
    xe = jnp.einsum("gtec,gtd->gecd", dispatch, xg.astype(cd))
    xe = _constrain_ep(xe)
    if taps is not None and layer_idx is not None:
        taps.record(f"layers.{layer_idx}.expert_in", xe.reshape(-1, d))
    act = activation(cfg.act)
    he = act(_expert_dense(lp["we_g"], xe)) * _expert_dense(lp["we_u"], xe)
    he = _constrain_ep(he)
    if taps is not None and layer_idx is not None:
        taps.record(f"layers.{layer_idx}.down_in", he.reshape(-1, cfg.d_ff))
    ye = _expert_dense(lp["we_d"], he)                      # (G, E, C, D)
    ye = _constrain_ep(ye)
    out = jnp.einsum("gtec,gecd->gtd", combine, ye)
    out = out.reshape(g * gs, d)
    if pad:
        out = out[:t]

    # switch load-balance aux: E * Σ_e f_e · p_e (averaged over groups)
    frac = jnp.mean(jnp.sum(dispatch, axis=-1), axis=(0, 1))  # (E,)
    pmean = jnp.mean(probs, axis=(0, 1))
    aux = e * jnp.sum(frac / jnp.maximum(jnp.float32(k), 1.0) * pmean)
    return out.reshape(b, s, d), aux


def _constrain_ep(t):
    """Shard the expert dim over 'model' (EP) and the group dim over dp.
    t: (G, Tg|E, E|C, ...) — the E axis is dim 2 for (G,T,E,C) dispatch
    tensors and dim 1 for (G,E,C,D) expert-major tensors; detect by name-
    free heuristic: the dim whose size == leaves' n_experts is set by the
    caller's layout, so we accept both via explicit dim search."""
    from repro.distributed.act_sharding import get_mesh
    import jax as _jax
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = get_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return t
    ms = mesh.shape["model"]
    from repro.distributed.sharding import dp_axes
    dp = dp_axes(mesh)
    spec = [None] * t.ndim
    # expert axis: dim 2 for (G,Tg,E,C), dim 1 for (G,E,C,D)
    e_dim = 2 if t.ndim == 4 and t.shape[1] > t.shape[2] else 1
    if t.shape[e_dim] % ms == 0:
        spec[e_dim] = "model"
    if dp:
        import numpy as _np
        if t.shape[0] % int(_np.prod([mesh.shape[a] for a in dp])) == 0:
            spec[0] = dp
    return _jax.lax.with_sharding_constraint(
        t, NamedSharding(mesh, P(*spec)))


def _expert_dense(p, xe):
    """Per-expert matmul: p (E, d_in, d_out) or QLinear with stacked
    leaves; xe (G, E, C, d_in)."""
    if isinstance(p, qlinear.QLinear):
        from repro.core import transforms as T
        x = T.apply(p.transform, xe)
        if p.act_bits:
            from repro.core.quantizers import act_spec, fake_quant
            x = fake_quant(x, act_spec(p.act_bits))
        w = qlinear.unpacked_qweight(p).astype(xe.dtype) * p.scale.astype(xe.dtype)
        return jnp.einsum("gecd,edf->gecf", x.astype(xe.dtype), w)
    return jnp.einsum("gecd,edf->gecf", xe, p.astype(xe.dtype))
