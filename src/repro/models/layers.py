"""Shared model layers: norms, RoPE, chunked (flash-style) attention, MLP.

Attention uses an online-softmax scan over KV chunks so the score matrix
is never materialized (O(S·chunk) working set instead of O(S²)) — required
for the 32k prefill cells to fit HBM, and the natural TPU formulation
(each chunk is an MXU matmul; the running max/sum rescale is VPU work).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import qlinear

_NEG_INF = -1e30


# ------------------------------------------------------------------- norms

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dt)


def group_rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6):
    """Per-head RMS norm: x (..., H, hd), scale (H*hd,) reshaped."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    out = out * (1.0 + scale.astype(jnp.float32).reshape(x.shape[-2], x.shape[-1]))
    return out.astype(dt)


def softcap(x: jnp.ndarray, cap: float):
    if not cap:
        return x
    return cap * jnp.tanh(x / cap)


# -------------------------------------------------------------------- RoPE

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float):
    """x: (B, S, H, hd), positions: (B, S) or (S,) absolute positions."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions.astype(jnp.float32)[:, :, None] * freq[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]  # (B, S, 1, half)
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------- chunked flash-style attention

def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                      q_positions: jnp.ndarray,
                      causal: bool = True,
                      window: Optional[int] = None,
                      attn_softcap: float = 0.0,
                      kv_chunk: int = 1024) -> jnp.ndarray:
    """Online-softmax attention.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with KV | H (GQA) — or
    (codes int8, scale) tuples for int8 KV caches (dequantized per chunk
    inside the scan, so the bf16 cache is never materialized).
    q_positions: (B, Sq) absolute positions (decode passes the cache pos);
    KV positions are arange(Skv). Causal mask: q_pos >= kv_pos — this also
    masks unwritten cache slots (their positions exceed every query).
    """
    k_q = isinstance(k, tuple)
    v_q = isinstance(v, tuple)
    k_arr = k[0] if k_q else k
    v_arr = v[0] if v_q else v
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k_arr.shape
    g = h // kvh
    scale = hd ** -0.5
    # §Perf A3: keep q/k/v in compute dtype on the wire (SP/TP gathers at
    # bf16 bytes); score/PV einsums accumulate in f32 on the MXU via
    # preferred_element_type — flash-attention-standard numerics.
    cd = q.dtype
    qf = (q * jnp.asarray(scale, cd)).reshape(b, sq, kvh, g, hd)

    from repro.models.flags import exact_cost
    c = skv if exact_cost() else min(kv_chunk, skv)
    pad = (-skv) % c

    def prep(t):
        if pad:
            t = jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        nc = t.shape[1] // c
        return t.reshape(b, nc, c, t.shape[2], t.shape[3]
                         ).transpose(1, 0, 2, 3, 4)

    kc = jax.tree.map(prep, k)
    vc = jax.tree.map(prep, v)
    nc = (skv + pad) // c
    kv_pos = jnp.arange(nc * c, dtype=jnp.int32).reshape(nc, c)
    qp = q_positions if q_positions.ndim == 2 else q_positions[None, :]

    def _deq(t, quantized):
        if quantized:
            codes, sc = t
            return codes.astype(cd) * sc.astype(cd)
        return t.astype(cd)

    def step(carry, xs):
        m, l, acc = carry
        kj, vj, pj = xs
        kj = _deq(kj, k_q)
        vj = _deq(vj, v_q)
        s = jnp.einsum("bqkgd,bckd->bkgqc", qf, kj,
                       preferred_element_type=jnp.float32)
        s = softcap(s, attn_softcap)
        mask = jnp.ones((b, sq, c), dtype=bool)
        if causal:
            mask &= qp[:, :, None] >= pj[None, None, :]
        if window is not None:
            mask &= (qp[:, :, None] - pj[None, None, :]) < window
        if pad:
            mask &= (pj < skv)[None, None, :]
        s = jnp.where(mask[:, None, None, :, :], s, _NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(cd), vj,
                        preferred_element_type=jnp.float32)
        acc = acc * corr[..., None] + pv
        return (m_new, l, acc), None

    m0 = jnp.full((b, kvh, g, sq), _NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kvh, g, sq), jnp.float32)
    a0 = jnp.zeros((b, kvh, g, sq, hd), jnp.float32)
    from repro.models.flags import scan as _scan
    (m, l, acc), _ = _scan(step, (m0, l0, a0), (kc, vc, kv_pos))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hd)
    return out.astype(q.dtype)


# --------------------------------------------------------------------- MLP

def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def glu_mlp(p, x, act: str):
    """Gated MLP: down( act(gate(x)) * up(x) ). p: dict wg/wu/wd."""
    h = activation(act)(qlinear.dense(p["wg"], x)) * qlinear.dense(p["wu"], x)
    return qlinear.dense(p["wd"], h)


# ---------------------------------------------------------------- KV cache

def _write_kv(cache, x, pos):
    """Write x (B, S, ...) into cache (B, Smax, ...) at rows [pos, pos+S).

    ``pos`` is either a scalar (whole batch at the same offset — static
    batching) or an (B,) int32 vector of per-slot offsets (continuous
    batching: every slot sits at its own sequence position)."""
    x = x.astype(cache.dtype)
    if getattr(pos, "ndim", 0) == 0:
        start = (0, pos) + (0,) * (cache.ndim - 2)
        return jax.lax.dynamic_update_slice(cache, x, start)

    def one(c, u, p):
        return jax.lax.dynamic_update_slice(c, u, (p,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache, x, pos)


def cache_update(cache_k, cache_v, k, v, pos):
    """Write k, v (B, S, KV, hd) into caches at [pos, pos+S); ``pos``
    scalar or (B,) per-slot offsets (see ``_write_kv``)."""
    return _write_kv(cache_k, k, pos), _write_kv(cache_v, v, pos)


def quantize_kv(x: jnp.ndarray, bits: int):
    """Symmetric per-(token, head) int8-storage quantization of K/V.
    x (B, S, KV, hd) -> (codes int8, scale f32 (B, S, KV, 1))."""
    qmax = 2.0 ** (bits - 1) - 1
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-12) / qmax
    codes = jnp.clip(jnp.round(xf / scale), -qmax - 1, qmax).astype(jnp.int8)
    return codes, scale


def cache_update_quantized(ck, cks, cv, cvs, k, v, pos, bits: int):
    """int8 KV-cache write: codes + per-token scales at [pos, pos+S);
    ``pos`` scalar or (B,) per-slot offsets (see ``_write_kv``)."""
    kq, ks = quantize_kv(k, bits)
    vq, vs = quantize_kv(v, bits)
    return (_write_kv(ck, kq, pos), _write_kv(cks, ks, pos),
            _write_kv(cv, vq, pos), _write_kv(cvs, vs, pos))


# ----------------------------------------------------------- paged KV cache

# Sequence-axis granularity of the per-token KV quant scales. The serve
# CLI validates page_size % KV_QUANT_GROUP == 0 so a page never splits a
# scale group (today scales are per-token, so the group is 1; a grouped-
# scale quantizer must bump this in lockstep).
KV_QUANT_GROUP = 1


def _paged_indices(page_table, pos, b, s, page_size):
    """Physical (page, row) targets for writing (B, S) tokens starting at
    ``pos`` (scalar or (B,)) into a paged pool.

    Logical position p lives at row ``p % page_size`` of physical page
    ``page_table[b, p // page_size]``. Positions past the table (padded
    prefill chunks / bucket rows) and table entries that are 0 both land
    on the reserved null page 0 — never owned by a request, so the write
    is inert (and the garbage rows are causally masked on read anyway).
    Returns flat ((B*S,) page ids, (B*S,) rows)."""
    if getattr(pos, "ndim", 0) == 0:
        pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    logical = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # (B,S)
    n_ptab = page_table.shape[1]
    pidx = logical // page_size
    valid = pidx < n_ptab
    pids = jnp.take_along_axis(page_table, jnp.minimum(pidx, n_ptab - 1),
                               axis=1)
    pids = jnp.where(valid, pids, 0)
    rows = logical % page_size
    return pids.reshape(-1), rows.reshape(-1)


def _write_kv_paged(pool, x, page_table, pos):
    """Scatter x (B, S, KV, d) into pool (n_pages, G, KV, d) at the pages
    ``page_table`` (B, n_ptab) names for logical rows [pos, pos+S).

    Distinct slots own distinct pages, so real writes never collide; the
    only duplicate targets are inert null-page rows (see _paged_indices).
    """
    b, s = x.shape[:2]
    pids, rows = _paged_indices(page_table, pos, b, s, pool.shape[1])
    vals = x.reshape((b * s,) + x.shape[2:]).astype(pool.dtype)
    return pool.at[pids, rows].set(vals, mode="drop")


def paged_cache_update(ck, cv, k, v, page_table, pos):
    """fp paged write: k, v (B, S, KV, hd) into (n_pages, G, KV, hd)
    pools at the rows the page table maps [pos, pos+S) to."""
    return (_write_kv_paged(ck, k, page_table, pos),
            _write_kv_paged(cv, v, page_table, pos))


def paged_cache_update_quantized(ck, cks, cv, cvs, k, v, page_table, pos,
                                 bits: int):
    """int8 paged write: same quantizer as the contiguous cache
    (``quantize_kv``), codes + per-token scales scattered page-wise —
    the stored values are bitwise identical to the slot cache's."""
    kq, ks = quantize_kv(k, bits)
    vq, vs = quantize_kv(v, bits)
    return (_write_kv_paged(ck, kq, page_table, pos),
            _write_kv_paged(cks, ks, page_table, pos),
            _write_kv_paged(cv, vq, page_table, pos),
            _write_kv_paged(cvs, vs, page_table, pos))


def copy_pool_pages(pool, src, dst):
    """Copy whole pages ``src`` -> ``dst`` along a pool leaf's page axis
    (axis 1: leaves are (L, n_pages, page_size, ...)). The prefix cache's
    copy-on-write split: duplicate a shared page's rows into a private
    replacement before the new owner writes its divergent rows. ``src``/
    ``dst`` are (C,) int32; padding pairs are (0, 0) — a null-page
    self-copy is a no-op write — so the copy keeps one compile shape."""
    return pool.at[:, dst].set(pool[:, src])


def gather_pages(pool, page_table):
    """(n_pages, G, KV, d) pool + (B, n_ptab) table -> the logical
    (B, n_ptab*G, KV, d) view — identical (content and shape) to the
    contiguous slot cache over written rows, so downstream attention is
    bitwise the same; unwritten/null rows are finite garbage masked by
    the causal test."""
    b, n_ptab = page_table.shape
    g = pool.shape[1]
    return pool[page_table].reshape((b, n_ptab * g) + pool.shape[2:])
