"""Dense decoder-only transformer family.

Covers: gemma2-2b (local/global alternation, softcaps, sandwich norms),
gemma3-12b (5:1 local:global, qk-norm), mistral-nemo-12b, granite-34b
(MQA), paligemma-3b backbone (prefix patch embeddings), catlm-60m, and the
MoE variants (granite-moe, moonshot) via repro.models.moe.

Layers are stacked on a leading axis and driven by lax.scan (small HLO,
fast multi-pod compiles). ``unroll=True`` runs a Python loop instead so
calibration taps can observe per-layer activations.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.models import moe as moe_lib
from repro.models.layers import (chunked_attention, cache_update, glu_mlp,
                                 rms_norm, rope, softcap)


def _compute_dtype(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


def _init_linear(rng, d_in, d_out, dtype=jnp.float32):
    return (jax.random.normal(rng, (d_in, d_out), dtype=jnp.float32)
            / jnp.sqrt(d_in)).astype(dtype)


def is_global_flags(cfg) -> jnp.ndarray:
    """(L,) bool: which layers use global (full) attention."""
    if not cfg.window or cfg.local_ratio == 0:
        return jnp.ones((cfg.n_layers,), bool)
    idx = jnp.arange(cfg.n_layers)
    return (idx % (cfg.local_ratio + 1)) == cfg.local_ratio


def init(cfg, rng) -> dict:
    keys = iter(jax.random.split(rng, 64))
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    Hq, Hkv = cfg.q_dim, cfg.kv_dim

    def lin(d_in, d_out, extra=()):
        k = next(keys)
        ks = jax.random.split(k, L)
        return jnp.stack([_init_linear(ks[i], d_in, d_out) for i in range(L)]
                         ) if not extra else None

    # vectorized per-layer init (vmap over layer axis keeps it fast)
    def lins(d_in, d_out):
        k = jax.random.split(next(keys), L)
        return jax.vmap(lambda kk: _init_linear(kk, d_in, d_out))(k)

    layers = {
        "ln1": jnp.zeros((L, D)),
        "ln2": jnp.zeros((L, D)),
        "wq": lins(D, Hq),
        "wk": lins(D, Hkv),
        "wv": lins(D, Hkv),
        "wo": lins(Hq, D),
    }
    if cfg.post_norms:
        layers["ln1_post"] = jnp.zeros((L, D))
        layers["ln2_post"] = jnp.zeros((L, D))
    if cfg.qk_norm:
        layers["q_norm"] = jnp.zeros((L, cfg.head_dim))
        layers["k_norm"] = jnp.zeros((L, cfg.head_dim))
    if cfg.n_experts:
        layers.update(moe_lib.init_layers(cfg, next(keys)))
    else:
        if cfg.gated_mlp:
            layers["wg"] = lins(D, F)
        layers["wu"] = lins(D, F)
        layers["wd"] = lins(F, D)

    params = {
        "embed": jax.random.normal(next(keys), (cfg.vocab, D)) * 0.02,
        "final_norm": jnp.zeros((D,)),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init_linear(next(keys), D, cfg.vocab)
    return params


# ----------------------------------------------------------------- forward

def _fused_decode_operands(cfg, lp, cache_sl, s, b, tp_axis, ragged_desc,
                           page_table, paged_kernel):
    """Two-launch decode gate: returns (wqkv QLinear, (blocks, ha, hb,
    sign)) when this layer's attention block can run the one-launch QKV
    prologue (``kernels/decode_layer.py``) + paged attention, else None.

    The prologue covers exactly the composed quantized decode shape:
    single-token rows (s == 1, B <= 8), quantized paged pools, serving
    params with a concatenated QKV QLinear whose transform the fused
    kernels can decompose, and none of the attention features the paged
    kernel already excludes (windows, softcap, qk-norm). Mixed-q_len
    (ragged) and tensor-parallel steps keep the current path. Routing is
    decided by ``ops.use_fused_decode()`` (backend/env), so off-TPU
    golden fixtures keep the composed path's exact numerics by default.
    """
    if not (paged_kernel and s == 1 and ragged_desc is None
            and tp_axis is None and page_table is not None
            and cache_sl is not None and "k_scale" in cache_sl
            and bool(cfg.kv_quant_bits) and b <= 8
            and not cfg.window and not cfg.attn_softcap
            and not cfg.qk_norm):
        return None
    p = lp.get("wqkv")
    if not isinstance(p, qlinear.QLinear) or not p.act_bits:
        return None
    from repro.kernels import ops
    if not ops.use_fused_decode():
        return None
    dec = ops.fused_transform_operands(p.transform)
    if dec is None:
        return None
    return p, dec


def _fused_decode_attn(cfg, fd, h, cache_sl, page_table, pos, b):
    """The two-launch decode attention block: ONE prologue launch (CAT ->
    quant -> W4A8 QKV GEMV -> RoPE -> int8 KV quant -> paged scatter)
    feeding ONE paged-attention launch. Returns (o (B, 1, Hq·hd),
    new_cache_sl)."""
    from repro.kernels import ops
    from repro.models.layers import _paged_indices

    p, (blocks, ha, hb, sign) = fd
    cd = h.dtype
    page_size = cache_sl["k"].shape[1]
    pos_vec = (pos if getattr(pos, "ndim", 0)
               else jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,)))
    pids, rows = _paged_indices(page_table, pos_vec, b, 1, page_size)
    q, ck, cks, cv, cvs = ops.decode_qkv_prologue(
        h.reshape(b, -1), blocks, ha, hb, sign, p.qweight, p.scale,
        cache_sl["k"], cache_sl["k_scale"], cache_sl["v"],
        cache_sl["v_scale"], pids, rows, pos_vec,
        n_q=cfg.q_dim, head_dim=cfg.head_dim,
        rope_theta=float(cfg.rope_theta), kv_bits=cfg.kv_quant_bits,
        act_bits=p.act_bits, packed=p.packed)
    new_cache_sl = {"k": ck, "k_scale": cks, "v": cv, "v_scale": cvs}
    kvh = ck.shape[2]
    g = cfg.q_dim // cfg.head_dim // kvh
    qk = q.astype(cd).reshape(b, kvh, g, cfg.head_dim)
    lengths = pos_vec + 1
    o = ops.paged_attention(qk, ck, cks, cv, cvs, page_table,
                            lengths.astype(jnp.int32))
    return o.reshape(b, 1, -1).astype(cd), new_cache_sl


def _layer_body(cfg, x, lp, cache_sl, is_global, pos, positions,
                taps=None, layer_idx=None, tp_axis=None,
                tp_mode: str = "gather", tp_kernels=False,
                page_table=None, paged_kernel: bool = False,
                ragged_desc=None):
    """cache_sl: per-layer cache slices dict ({"k","v"[,"k_scale","v_scale"]})
    or None. Returns (x, new_cache_sl, aux).

    With ``page_table`` (B, n_ptab) the cache slices are *page pools*
    ((n_pages, page_size, KV, hd) per layer, plus congruent per-token
    scale pools when quantized): k/v writes scatter to the physical rows
    the table maps [pos, pos+S) to, and attention reads the gathered
    logical view — identical content and shape to the contiguous slot
    cache, so decoded tokens stay bitwise the same. ``paged_kernel``
    additionally routes single-token (decode) attention on quantized
    pools through the Pallas paged-attention kernel (streams int8 pages,
    dequantizes in VMEM — rtol-level, not bitwise).

    With ``tp_axis`` the body runs INSIDE shard_map on a tensor-parallel
    mesh axis: wq/wk/wv/wg/wu arrive column-sharded (whole local heads /
    FFN columns — head counts are derived from the projection shapes, not
    cfg) and the KV cache slices are head-sharded congruently. The
    row-position layers (wo/wd) follow ``tp_mode``: ``"gather"``
    all-gathers the head-/FFN-sharded activation and contracts against a
    replicated weight (bitwise-identical to single device — column slices
    of a matmul are exact); ``"psum"`` keeps the weight K-sharded and
    psums partial contractions via ``qlinear.dense_tp`` (rtol-level;
    ``tp_kernels=True`` additionally routes the local contraction through
    the packed W4A8 Pallas kernels)."""
    b, s, d = x.shape
    cd = x.dtype

    def row_dense(p, h):
        if tp_axis is None:
            return qlinear.dense(p, h)
        if tp_mode == "psum":
            return qlinear.dense_tp(p, h, tp_axis, use_kernel=tp_kernels)
        h = jax.lax.all_gather(h, tp_axis, axis=h.ndim - 1, tiled=True)
        return qlinear.dense(p, h)

    h = rms_norm(x, lp["ln1"])
    _tap(taps, layer_idx, "attn_in", h)
    fd = _fused_decode_operands(cfg, lp, cache_sl, s, b, tp_axis,
                                ragged_desc, page_table, paged_kernel)
    if fd is not None:
        # two-launch decode: the QKV prologue kernel replaces the dense
        # projection + rope + KV-quant + scatter chain below. Numerics
        # follow the integer-accumulation route (``qlinear.dense_fused``
        # route 3 == the TPU kernel route), NOT the portable bf16
        # ``w_eff`` route the composed path takes off-TPU — the same
        # documented route-2/route-3 gap; gating defaults off outside
        # TPU (REPRO_DECODE_FUSED overrides) so stock CPU runs keep the
        # composed path bitwise.
        o, new_cache_sl = _fused_decode_attn(cfg, fd, h, cache_sl,
                                             page_table, pos, b)
    else:
        if "wqkv" in lp:
            # fused serving params (make_serving_params): one concatenated
            # QKV projection — one transform+quant+matmul chain instead of
            # three. Column slices of a matmul are exact, so splitting the
            # output reproduces the separate projections bitwise.
            qkv = qlinear.dense(lp["wqkv"], h)
            hq, hkv = cfg.q_dim, cfg.kv_dim
            q = qkv[..., :hq]
            k = qkv[..., hq:hq + hkv]
            v = qkv[..., hq + hkv:]
            q = q.reshape(b, s, -1, cfg.head_dim)
            k = k.reshape(b, s, -1, cfg.head_dim)
            v = v.reshape(b, s, -1, cfg.head_dim)
        else:
            q = qlinear.dense(lp["wq"], h).reshape(b, s, -1, cfg.head_dim)
            k = qlinear.dense(lp["wk"], h).reshape(b, s, -1, cfg.head_dim)
            v = qlinear.dense(lp["wv"], h).reshape(b, s, -1, cfg.head_dim)
        if cfg.qk_norm:
            q = rms_norm(q, lp["q_norm"])
            k = rms_norm(k, lp["k_norm"])
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)

        window = None
        if cfg.window:
            window = jnp.where(is_global, jnp.int32(2**30),
                               jnp.int32(cfg.window))

        quant_cache = bool(cfg.kv_quant_bits) and cache_sl is not None \
            and "k_scale" in cache_sl
        if cfg.kv_quant_bits and not quant_cache:
            # no cache (training fwd): simulate KV quantization numerics
            from repro.core.quantizers import QuantSpec, fake_quant
            kv_spec = QuantSpec(bits=cfg.kv_quant_bits, symmetric=False,
                                per="token", dynamic=True)
            k = fake_quant(k, kv_spec)
            v = fake_quant(v, kv_spec)

        new_cache_sl = None
        o = None
        if cache_sl is not None and page_table is not None:
            from repro.models.layers import (gather_pages,
                                             paged_cache_update,
                                             paged_cache_update_quantized)
            if quant_cache:
                ck, cks, cv, cvs = paged_cache_update_quantized(
                    cache_sl["k"], cache_sl["k_scale"], cache_sl["v"],
                    cache_sl["v_scale"], k, v, page_table, pos,
                    cfg.kv_quant_bits)
                new_cache_sl = {"k": ck, "k_scale": cks, "v": cv,
                                "v_scale": cvs}
                use_kernel = (paged_kernel and s == 1 and window is None
                              and not cfg.attn_softcap)
                if use_kernel and ragged_desc is not None:
                    # unified ragged step: regroup the flat packed rows
                    # into per-work-item query blocks so every sequence's
                    # pages stream ONCE for all its prefill-chunk +
                    # decode queries (one launch for the mixed batch)
                    from repro.kernels import ops
                    kvh = ck.shape[2]
                    qf = q.reshape(b, kvh, q.shape[2] // kvh, cfg.head_dim)
                    qb = qf[ragged_desc["qidx"]]     # (R, Q, KVH, g, hd)
                    ob = ops.ragged_paged_attention(
                        qb, ck, cks, cv, cvs, ragged_desc["table"],
                        ragged_desc["lengths"].astype(jnp.int32),
                        ragged_desc["qpos"].astype(jnp.int32))
                    o = ob[ragged_desc["inv_seq"], ragged_desc["inv_qi"]]
                    o = o.reshape(b, 1, -1)
                elif use_kernel:
                    # decode fast path: stream int8 pages, dequant in
                    # VMEM (rtol-level vs the gathered view, not bitwise)
                    from repro.kernels import ops
                    kvh = ck.shape[2]
                    qk = q.reshape(b, kvh, q.shape[2] // kvh, cfg.head_dim)
                    lengths = (pos if getattr(pos, "ndim", 0)
                               else jnp.broadcast_to(pos, (b,))) + 1
                    o = ops.paged_attention(qk, ck, cks, cv, cvs,
                                            page_table,
                                            lengths.astype(jnp.int32))
                    o = o.reshape(b, 1, -1)
                else:
                    k_att = (gather_pages(ck, page_table),
                             gather_pages(cks, page_table))
                    v_att = (gather_pages(cv, page_table),
                             gather_pages(cvs, page_table))
            else:
                ck, cv = paged_cache_update(cache_sl["k"], cache_sl["v"],
                                            k, v, page_table, pos)
                new_cache_sl = {"k": ck, "v": cv}
                k_att = gather_pages(ck, page_table).astype(cd)
                v_att = gather_pages(cv, page_table).astype(cd)
        elif cache_sl is not None and quant_cache:
            from repro.models.layers import cache_update_quantized
            ck, cks, cv, cvs = cache_update_quantized(
                cache_sl["k"], cache_sl["k_scale"], cache_sl["v"],
                cache_sl["v_scale"], k, v, pos, cfg.kv_quant_bits)
            new_cache_sl = {"k": ck, "k_scale": cks, "v": cv,
                            "v_scale": cvs}
            k_att, v_att = (ck, cks), (cv, cvs)
        elif cache_sl is not None:
            ck, cv = cache_update(cache_sl["k"], cache_sl["v"], k, v, pos)
            new_cache_sl = {"k": ck, "v": cv}
            k_att, v_att = ck.astype(cd), cv.astype(cd)
        else:
            k_att, v_att = k, v

    if o is None:
        o = chunked_attention(q, k_att, v_att, q_positions=positions,
                              causal=True, window=window,
                              attn_softcap=cfg.attn_softcap)
        o = o.reshape(b, s, -1)
    _tap(taps, layer_idx, "o_in", o)
    attn_out = row_dense(lp["wo"], o)
    if cfg.post_norms:
        attn_out = rms_norm(attn_out, lp["ln1_post"])
    x = x + attn_out

    h2 = rms_norm(x, lp["ln2"])
    _tap(taps, layer_idx, "mlp_in", h2)
    if cfg.n_experts:
        mlp_out, aux = moe_lib.moe_mlp(cfg, lp, h2, taps=taps,
                                       layer_idx=layer_idx)
    else:
        from repro.models.layers import activation
        act = activation(cfg.act)
        if "wgu" in lp:
            # fused serving params: concatenated gate|up projection
            gu = qlinear.dense(lp["wgu"], h2)
            f = gu.shape[-1] // 2
            hmid = act(gu[..., :f]) * gu[..., f:]
        elif cfg.gated_mlp:
            hmid = act(qlinear.dense(lp["wg"], h2)) * qlinear.dense(lp["wu"], h2)
        else:
            hmid = act(qlinear.dense(lp["wu"], h2))
        _tap(taps, layer_idx, "down_in", hmid)
        mlp_out = row_dense(lp["wd"], hmid)
        aux = jnp.zeros((), jnp.float32)
    if cfg.post_norms:
        mlp_out = rms_norm(mlp_out, lp["ln2_post"])
    x = x + mlp_out
    if cfg.act_shard == "seq":
        from repro.distributed.act_sharding import constrain_seq
        x = constrain_seq(x)
    return x, new_cache_sl, aux


def _tap(taps, layer_idx, name, x):
    if taps is not None and layer_idx is not None:
        taps.record(f"layers.{layer_idx}.{name}", x)


def forward(cfg, params, tokens, *, extra_embed=None, cache=None,
            taps=None, unroll: bool = False, tp_axis=None,
            tp_mode: str = "gather", tp_kernels: bool = False,
            paged_kernel: bool = False, ragged_desc=None):
    """-> (hidden (B, S, D), aux_loss, new_cache). ``tokens`` (B, S) int32;
    ``extra_embed`` (B, P, D) is prepended (vlm prefix); with ``cache`` the
    attention runs against the cache and writes k/v at cache['pos'].

    A cache carrying a ``page_table`` leaf is *paged*: its k/v leaves are
    page pools (L, n_pages, page_size, KV, hd) shared across slots, and
    the table ((B, n_ptab) int32) maps each row's logical positions to
    physical pages (see ``init_paged_cache`` / ``models.layers``).
    ``paged_kernel`` opts decode steps into the Pallas paged-attention
    kernel (quantized pools only; rtol-level numerics). A *ragged*
    (unified-step) batch — flat packed rows, per-token (B,) ``pos`` and
    (B, n_ptab) table rows, see ``ragged_step`` — may also pass
    ``ragged_desc`` (per-work-item query-block descriptors) so the
    kernel streams each sequence's pages once for all its queries.

    ``tp_axis`` names a mesh axis when the forward runs inside shard_map
    with params sharded per ``distributed.sharding.tp_param_specs`` (same
    ``tp_mode``); the embedding, residual stream, norms, and logits stay
    replicated, so the output is bitwise identical to the single-device
    forward in ``tp_mode="gather"`` and rtol-level in ``"psum"`` (see
    ``_layer_body``)."""
    if tp_axis is not None and cfg.n_experts:
        raise NotImplementedError("tensor-parallel forward covers the "
                                  "dense (non-MoE) family only")
    cd = _compute_dtype(cfg)
    x = params["embed"][tokens].astype(cd) * jnp.sqrt(float(cfg.d_model)
                                                      ).astype(cd)
    if extra_embed is not None:
        x = jnp.concatenate([extra_embed.astype(cd), x], axis=1)
    b, s, _ = x.shape
    pos = cache["pos"] if cache is not None else jnp.int32(0)
    steps = jnp.arange(s, dtype=jnp.int32)
    # pos is a scalar (static batching: whole batch at one offset) or a
    # (B,) vector of per-slot offsets (the serve engine's continuous
    # batching) — positions then (S,) or (B, S); rope/attention take both.
    positions = pos[:, None] + steps if getattr(pos, "ndim", 0) else pos + steps
    flags = is_global_flags(cfg)

    cache_layers = None
    page_table = None
    if cache is not None:
        page_table = cache.get("page_table")
        cache_layers = {k: v for k, v in cache.items()
                        if k not in ("pos", "page_table")}

    aux0 = jnp.zeros((), jnp.float32)
    if unroll:
        new_sl = []
        aux = aux0
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            csl = (jax.tree.map(lambda a: a[i], cache_layers)
                   if cache_layers is not None else None)
            x, csl, a = _layer_body(cfg, x, lp, csl, flags[i], pos,
                                    positions, taps=taps, layer_idx=i,
                                    tp_axis=tp_axis, tp_mode=tp_mode,
                                    tp_kernels=tp_kernels,
                                    page_table=page_table,
                                    paged_kernel=paged_kernel,
                                    ragged_desc=ragged_desc)
            aux = aux + a
            if csl is not None:
                new_sl.append(csl)
        new_cache = None
        if cache is not None:
            stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *new_sl)
            new_cache = dict(stacked, pos=pos + s)
    else:
        def body(carry, xs):
            x, aux = carry
            if cache_layers is not None:
                lp, csl, fl = xs
            else:
                (lp, fl), csl = xs, None
            x, csl, a = _layer_body(cfg, x, lp, csl, fl, pos, positions,
                                    tp_axis=tp_axis, tp_mode=tp_mode,
                                    tp_kernels=tp_kernels,
                                    page_table=page_table,
                                    paged_kernel=paged_kernel,
                                    ragged_desc=ragged_desc)
            return (x, aux + a), csl

        if cfg.remat:
            body = jax.checkpoint(body)
        if cache_layers is not None:
            xs = (params["layers"], cache_layers, flags)
        else:
            xs = (params["layers"], flags)
        from repro.models.flags import scan as _scan
        (x, aux), ys = _scan(body, (x, aux0), xs)
        new_cache = None
        if cache is not None:
            new_cache = dict(ys, pos=pos + s)
    if new_cache is not None and page_table is not None:
        new_cache["page_table"] = page_table

    x = rms_norm(x, params["final_norm"])
    return x, aux, new_cache


def logits_fn(cfg, params, hidden):
    unembed = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = hidden @ unembed.astype(hidden.dtype)
    return softcap(logits, cfg.logit_softcap)


def loss(cfg, params, batch, *, loss_chunk: int = 512):
    """Chunked CE over the sequence (never materializes (B, S, V) logits)."""
    from repro.models.losses import chunked_ce
    extra = batch.get("patch_embed") if cfg.n_patches else None
    hidden, aux, _ = forward(cfg, params, batch["tokens"], extra_embed=extra)
    if extra is not None:
        hidden = hidden[:, extra.shape[1]:]
    return chunked_ce(lambda h: logits_fn(cfg, params, h), hidden,
                      batch["labels"], aux, loss_chunk=loss_chunk)


# ----------------------------------------------------------------- serving

def make_serving_params(cfg, params, keep_packed=None) -> dict:
    """The fused-serving variant of a params pytree (single-device engine
    hot path; ``ServeEngine(fused=True)`` applies it at build time):

    * wq|wk|wv -> one ``wqkv`` and wg|wu -> one ``wgu`` column-concat
      (exact: the pipeline quantizes group members against ONE shared
      input transform, so the concat collapses three transform + quant +
      matmul chains — which XLA cannot CSE across distinct stacked
      params — into one; fp params concat too, so the comparison stays
      like-for-like).
    * every QLinear gains the precomputed ``colsum`` for the
      integer-accumulation epilogue (``qlinear.dense_fused``) and, off
      TPU, the dequantized compute-dtype weight ``w_eff`` so the per-step
      unpack + dequant chain moves to build time
      (see ``qlinear.make_serving``).

    Tensor-parallel serving keeps the original per-member params — the
    concatenated output dim would split unevenly across head shards.
    Decoded tokens are bitwise identical to the unfused params (golden
    fixtures run both)."""
    from repro.core.qlinear import QLinear, concat_out, make_serving

    cd = _compute_dtype(cfg)
    layers = dict(params["layers"])

    def try_concat(names, out_name):
        if not all(n in layers for n in names):
            return
        cat = concat_out([layers[n] for n in names], keep_packed, cd)
        if cat is None:
            return
        for n in names:
            del layers[n]
        layers[out_name] = cat

    try_concat(("wq", "wk", "wv"), "wqkv")
    if cfg.gated_mlp and not cfg.n_experts:
        try_concat(("wg", "wu"), "wgu")

    def prep(leaf):
        if isinstance(leaf, QLinear) and leaf.colsum is None:
            return make_serving(leaf, keep_packed, cd)
        return leaf

    layers = jax.tree.map(prep, layers,
                          is_leaf=lambda x: isinstance(x, QLinear))
    return dict(params, layers=layers)


# ------------------------------------------------------------------ caches

def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    cd = _compute_dtype(cfg)
    if cfg.kv_quant_bits:
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.zeros(sshape, jnp.float32),
                "pos": jnp.int32(0)}
    return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd),
            "pos": jnp.int32(0)}


def init_paged_cache(cfg, n_pages: int, page_size: int) -> dict:
    """Global paged KV pool: (L, n_pages, page_size, KV, hd) codes (+
    congruent per-token scale pools when quantized). No ``pos`` — page
    tables and per-slot lengths are the caller's (engine's) bookkeeping;
    page 0 is conventionally the never-owned null page (see
    ``repro.launch.paged.PagePool``)."""
    shape = (cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim)
    cd = _compute_dtype(cfg)
    if cfg.kv_quant_bits:
        sshape = shape[:-1] + (1,)
        return {"k": jnp.zeros(shape, jnp.int8),
                "k_scale": jnp.zeros(sshape, jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_scale": jnp.zeros(sshape, jnp.float32)}
    return {"k": jnp.zeros(shape, cd), "v": jnp.zeros(shape, cd)}


def copy_paged_pages(cfg, cache, src, dst) -> dict:
    """Copy-on-write device copy for the serve stack: duplicate pool
    pages ``src`` into ``dst`` on every paged-cache leaf (codes AND
    scales — a cached quantized page is only bitwise-reusable with its
    per-token scales moved in lockstep). ``src``/``dst`` (C,) int32,
    padded with (0, 0) null-page self-copies (inert)."""
    from repro.models.layers import copy_pool_pages
    return {k: copy_pool_pages(v, src, dst) for k, v in cache.items()}


def prefill(cfg, params, tokens, cache, extra_embed=None, logits_at=None,
            **fwd_kw):
    """Prefill logits come from the last row by default; ``logits_at``
    (traced scalar) instead slices the row at that index — the hook that
    lets chunked/bucketed prefill pad tokens on the right and still read
    logits at the true last prompt token. A (R,) *vector* ``logits_at``
    gathers R rows instead (logits (B, R, V)) — the multi-row read
    speculative verification needs when checking k+1 positions of one
    forward at once (``launch.scheduler``/``ragged_step`` use the packed
    equivalent)."""
    hidden, _, cache = forward(cfg, params, tokens, extra_embed=extra_embed,
                               cache=cache, **fwd_kw)
    if logits_at is None:
        hidden = hidden[:, -1:]
    elif getattr(logits_at, "ndim", 0):
        hidden = jnp.take(hidden, logits_at, axis=1)
    else:
        hidden = jax.lax.dynamic_slice_in_dim(hidden, logits_at, 1, axis=1)
    return logits_fn(cfg, params, hidden), cache


def decode(cfg, params, token, cache, **fwd_kw):
    """token (B, 1) -> (logits (B, 1, V), cache)."""
    hidden, _, cache = forward(cfg, params, token, cache=cache, **fwd_kw)
    return logits_fn(cfg, params, hidden), cache


def ragged_step(cfg, params, tokens, cache, logit_rows, greedy=False,
                **fwd_kw):
    """Unified token-budget step: ONE forward over a flat ragged batch of
    mixed prefill-chunk and decode rows (``repro.launch.scheduler``).

    ``tokens`` (T, 1) packed rows — each row is one token of some
    sequence; ``cache`` holds the paged pools plus per-token ``pos``
    (T,) absolute positions and ``page_table`` (T, n_ptab) — every row
    carries its own slot's table row, so the existing paged scatter
    writes each token's k/v to its sequence's pages and the gathered
    logical view gives each query row exactly its own sequence's KV
    (padding rows ride the null table row -> inert writes, discarded
    reads). Intra-chunk causality needs no special casing: all packed
    rows write k/v before attention, and the causal ``q_pos >= kv_pos``
    test masks same-chunk future tokens — per-row numerics are bitwise
    identical to the legacy prefill/decode dispatches.

    ``logit_rows`` (R,) generalizes prefill's ``logits_at`` to the
    ragged batch: logits are computed only at those packed rows (the
    scheduler marks each decode row and each prompt-completing chunk's
    last row; padding entries are discarded by the caller) — the unembed
    cost scales with sequences, not packed tokens.

    ``greedy=True`` is device-resident sampling for the pipelined serve
    loop: instead of (R, 1, V) logits, return the greedy next token at
    each logit row as (R,) int32 — only R token ids ever cross D2H, and
    the argmax (lowest index on ties, matching ``np.argmax``) runs
    inside the same jitted program as the forward.
    -> (logits (R, 1, V), cache), or (tokens (R,), cache) when greedy."""
    hidden, _, cache = forward(cfg, params, tokens, cache=cache, **fwd_kw)
    sel = jnp.take(hidden[:, 0], logit_rows, axis=0)[:, None]
    logits = logits_fn(cfg, params, sel)
    if greedy:
        return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache
    return logits, cache
