"""Whisper-style encoder-decoder (audio backbone only; the conv/mel
frontend is a stub — batches carry precomputed frame embeddings
(B, enc_seq, d_model), per the assignment).

Encoder: bidirectional self-attn + MLP. Decoder: causal self-attn +
cross-attn over encoder states + MLP. Cross K/V are computed once at
prefill and live in the cache; decode only grows the self-attn cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.models.layers import (chunked_attention, cache_update, glu_mlp,
                                 rms_norm, softcap)

MAX_DEC_POS = 32_768 + 8  # learned decoder positions (covers decode_32k)


def _lins(rng, n, d_in, d_out):
    ks = jax.random.split(rng, n)
    return jax.vmap(lambda k: jax.random.normal(k, (d_in, d_out)) /
                    jnp.sqrt(d_in))(ks)


def init(cfg, rng):
    keys = iter(jax.random.split(rng, 32))
    D, F = cfg.d_model, cfg.d_ff
    Hq, Hkv = cfg.q_dim, cfg.kv_dim
    Le, Ld = cfg.n_enc_layers, cfg.n_layers

    def block(L, cross=False):
        p = {
            "ln1": jnp.zeros((L, D)), "ln2": jnp.zeros((L, D)),
            "wq": _lins(next(keys), L, D, Hq),
            "wk": _lins(next(keys), L, D, Hkv),
            "wv": _lins(next(keys), L, D, Hkv),
            "wo": _lins(next(keys), L, Hq, D),
            "wg": _lins(next(keys), L, D, F),
            "wu": _lins(next(keys), L, D, F),
            "wd": _lins(next(keys), L, F, D),
        }
        if cross:
            p.update({
                "ln_x": jnp.zeros((L, D)),
                "xq": _lins(next(keys), L, D, Hq),
                "xk": _lins(next(keys), L, D, Hkv),
                "xv": _lins(next(keys), L, D, Hkv),
                "xo": _lins(next(keys), L, Hq, D),
            })
        return p

    return {
        "embed": jax.random.normal(next(keys), (cfg.vocab, D)) * 0.02,
        "enc_pos": jax.random.normal(next(keys), (cfg.enc_seq, D)) * 0.01,
        "dec_pos": jax.random.normal(next(keys), (MAX_DEC_POS, D)) * 0.01,
        "enc_norm": jnp.zeros((D,)),
        "final_norm": jnp.zeros((D,)),
        "enc_layers": block(Le),
        "layers": block(Ld, cross=True),
    }


def _attn(cfg, h, wq, wk, wv, wo, positions, causal, kv=None, pos=None,
          kv_const=None):
    b, s, _ = h.shape
    q = qlinear.dense(wq, h).reshape(b, s, cfg.n_heads, cfg.head_dim)
    if kv_const is not None:
        k_att, v_att = kv_const
        new_kv = None
    else:
        k = qlinear.dense(wk, h).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        v = qlinear.dense(wv, h).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
        if kv is not None:
            ck, cv = cache_update(kv[0], kv[1], k, v, pos)
            k_att, v_att, new_kv = ck, cv, (ck, cv)
        else:
            k_att, v_att, new_kv = k, v, None
    o = chunked_attention(q, k_att.astype(h.dtype), v_att.astype(h.dtype),
                          q_positions=positions, causal=causal)
    return qlinear.dense(wo, o.reshape(b, s, cfg.q_dim)), new_kv


def encode(cfg, params, enc_embed, taps=None, unroll=False):
    """enc_embed (B, enc_seq, D) (stub frontend output) -> encoder states."""
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = enc_embed.astype(cd) + params["enc_pos"][None].astype(cd)
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)

    def body(x, lp):
        h = rms_norm(x, lp["ln1"])
        a, _ = _attn(cfg, h, lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                     positions, causal=False)
        x = x + a
        x = x + glu_mlp(lp, rms_norm(x, lp["ln2"]), cfg.act)
        return x, None

    if unroll or taps is not None:
        from repro.models.layers import activation
        for i in range(cfg.n_enc_layers):
            lp = jax.tree.map(lambda a: a[i], params["enc_layers"])
            h = rms_norm(x, lp["ln1"])
            if taps is not None:
                taps.record(f"enc.{i}.attn_in", h)
            a, _ = _attn(cfg, h, lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                         positions, causal=False)
            x = x + a
            h2 = rms_norm(x, lp["ln2"])
            if taps is not None:
                taps.record(f"enc.{i}.mlp_in", h2)
            hmid = activation(cfg.act)(qlinear.dense(lp["wg"], h2)) \
                * qlinear.dense(lp["wu"], h2)
            if taps is not None:
                taps.record(f"enc.{i}.down_in", hmid)
            x = x + qlinear.dense(lp["wd"], hmid)
    else:
        from repro.models.flags import scan as _scan
        x, _ = _scan(body, x, params["enc_layers"])
    return rms_norm(x, params["enc_norm"])


def forward(cfg, params, tokens, *, enc_embed=None, enc_states=None,
            cache=None, taps=None, unroll=False, extra_embed=None):
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if enc_states is None:
        if enc_embed is None and cache is not None:
            enc_states = cache["enc_states"]
        else:
            enc_states = encode(cfg, params, enc_embed, taps=taps,
                                unroll=unroll)
    b, s = tokens.shape
    pos = cache["pos"] if cache is not None else jnp.int32(0)
    positions = pos + jnp.arange(s, dtype=jnp.int32)
    x = params["embed"][tokens].astype(cd) \
        + params["dec_pos"][positions].astype(cd)[None]
    enc_positions = jnp.arange(enc_states.shape[1], dtype=jnp.int32)

    def layer(x, lp, kv, idx=None):
        def tap(name, val):
            if taps is not None and idx is not None:
                taps.record(f"layers.{idx}.{name}", val)
        h = rms_norm(x, lp["ln1"])
        tap("attn_in", h)
        a, new_kv = _attn(cfg, h, lp["wq"], lp["wk"], lp["wv"], lp["wo"],
                          positions, causal=True, kv=kv, pos=pos)
        x = x + a
        hx = rms_norm(x, lp["ln_x"])
        tap("cross_in", hx)
        # cross-attention: keys/values from encoder states (full, non-causal)
        bq, sq, _ = hx.shape
        q = qlinear.dense(lp["xq"], hx).reshape(bq, sq, cfg.n_heads,
                                                cfg.head_dim)
        kx = qlinear.dense(lp["xk"], enc_states).reshape(
            bq, -1, cfg.n_kv_heads, cfg.head_dim)
        vx = qlinear.dense(lp["xv"], enc_states).reshape(
            bq, -1, cfg.n_kv_heads, cfg.head_dim)
        ox = chunked_attention(q, kx.astype(x.dtype), vx.astype(x.dtype),
                               q_positions=positions, causal=False)
        x = x + qlinear.dense(lp["xo"], ox.reshape(bq, sq, cfg.q_dim))
        h2 = rms_norm(x, lp["ln2"])
        tap("mlp_in", h2)
        from repro.models.layers import activation
        hmid = activation(cfg.act)(qlinear.dense(lp["wg"], h2)) \
            * qlinear.dense(lp["wu"], h2)
        tap("down_in", hmid)
        x = x + qlinear.dense(lp["wd"], hmid)
        return x, new_kv

    if unroll or taps is not None:
        new_k, new_v = [], []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            kv = ((cache["k"][i], cache["v"][i])
                  if cache is not None else None)
            x, new_kv = layer(x, lp, kv, idx=i)
            if new_kv is not None:
                new_k.append(new_kv[0])
                new_v.append(new_kv[1])
        ys = (jnp.stack(new_k), jnp.stack(new_v)) if new_k else None
    else:
        def body(x, xs):
            if cache is not None:
                lp, ck, cv = xs
                x, new_kv = layer(x, lp, (ck, cv))
                return x, new_kv
            x, _ = layer(x, xs, None)
            return x, None  # noqa: E501 — scan body shared with cache path

        if cfg.remat:
            body = jax.checkpoint(body)
        xs = ((params["layers"], cache["k"], cache["v"])
              if cache is not None else params["layers"])
        from repro.models.flags import scan as _scan
        x, ys = _scan(body, x, xs)

    x = rms_norm(x, params["final_norm"])
    new_cache = None
    if cache is not None:
        new_cache = {"k": ys[0], "v": ys[1], "pos": pos + s,
                     "enc_states": enc_states}
    return x, jnp.zeros((), jnp.float32), new_cache


def logits_fn(cfg, params, hidden):
    return softcap(hidden @ params["embed"].T.astype(hidden.dtype),
                   cfg.logit_softcap)


def loss(cfg, params, batch, **kw):
    from repro.models.losses import chunked_ce
    hidden, aux, _ = forward(cfg, params, batch["tokens"],
                             enc_embed=batch["enc_embed"])
    return chunked_ce(lambda h: logits_fn(cfg, params, h), hidden,
                      batch["labels"], aux)


def init_cache(cfg, batch_size: int, max_len: int) -> dict:
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, jnp.bfloat16),
            "v": jnp.zeros(shape, jnp.bfloat16),
            "pos": jnp.int32(0),
            "enc_states": jnp.zeros((batch_size, cfg.enc_seq, cfg.d_model),
                                    jnp.bfloat16)}


def prefill(cfg, params, tokens, cache, enc_embed=None, extra_embed=None):
    enc_states = encode(cfg, params, enc_embed) if enc_embed is not None \
        else cache["enc_states"]
    cache = dict(cache, enc_states=enc_states)
    hidden, _, cache = forward(cfg, params, tokens, enc_states=enc_states,
                               cache=cache)
    return logits_fn(cfg, params, hidden[:, -1:]), cache


def decode(cfg, params, token, cache):
    hidden, _, cache = forward(cfg, params, token, cache=cache)
    return logits_fn(cfg, params, hidden), cache
