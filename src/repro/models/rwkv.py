"""RWKV-6 (Finch): attention-free LM with token shift, data-dependent
per-channel decay linear attention (time-mix) and squared-ReLU channel-mix.

State per layer: (tmix prev token, cmix prev token, per-head S matrix) —
decode is O(1) in context length, so this arch runs the long_500k cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import qlinear
from repro.models import gla
from repro.models.layers import rms_norm, softcap


def _lins(rng, n, d_in, d_out):
    ks = jax.random.split(rng, n)
    return jax.vmap(lambda k: jax.random.normal(k, (d_in, d_out)) /
                    jnp.sqrt(d_in))(ks)


def init(cfg, rng):
    keys = iter(jax.random.split(rng, 32))
    L, D, F = cfg.n_layers, cfg.d_model, cfg.d_ff
    H, hd = cfg.n_heads, cfg.head_dim
    layers = {
        "ln1": jnp.zeros((L, D)),
        "ln2": jnp.zeros((L, D)),
        # time-mix projections
        "wr": _lins(next(keys), L, D, D),
        "wk": _lins(next(keys), L, D, D),
        "wv": _lins(next(keys), L, D, D),
        "wg": _lins(next(keys), L, D, D),
        "wo": _lins(next(keys), L, D, D),
        # data-dependent decay lora: D -> 64 -> D
        "w_lora_a": _lins(next(keys), L, D, 64),
        "w_lora_b": _lins(next(keys), L, 64, D),
        "w0": jnp.full((L, D), -1.0),           # decay bias
        "u": jax.random.normal(next(keys), (L, H, hd)) * 0.1,  # bonus
        # token-shift mixing coefficients per stream
        "mu_tmix": jax.random.uniform(next(keys), (L, 5, D)),
        "mu_cmix": jax.random.uniform(next(keys), (L, 1, D)),
        "ln_x": jnp.zeros((L, D)),              # per-head output norm
        # channel mix
        "ck": _lins(next(keys), L, D, F),
        "cv": _lins(next(keys), L, F, D),
    }
    return {
        "embed": jax.random.normal(next(keys), (cfg.vocab, D)) * 0.02,
        "final_norm": jnp.zeros((D,)),
        "layers": layers,
    }


def _shift(x, prev):
    """(B, S, D) -> previous-token stream; prev (B, D) fills t=0."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1]], axis=1)


def _layer(cfg, x, lp, state, taps=None, layer_idx=None):
    b, s, d = x.shape
    H, hd = cfg.n_heads, cfg.head_dim
    prev_t, prev_c, S = state["tmix_x"], state["cmix_x"], state["wkv"]

    # ---- time mix
    h = rms_norm(x, lp["ln1"])
    sh = _shift(h, prev_t)
    mu = lp["mu_tmix"].astype(h.dtype)          # (5, D)
    xr, xk, xv, xw, xg = (h + mu[i][None, None] * (sh - h) for i in range(5))
    if taps is not None:
        taps.record(f"layers.{layer_idx}.attn_in", xr)
    r = qlinear.dense(lp["wr"], xr).reshape(b, s, H, hd)
    k = qlinear.dense(lp["wk"], xk).reshape(b, s, H, hd)
    v = qlinear.dense(lp["wv"], xv).reshape(b, s, H, hd)
    g = jax.nn.silu(qlinear.dense(lp["wg"], xg))
    lora = jnp.tanh(xw.astype(jnp.float32) @ lp["w_lora_a"]) @ lp["w_lora_b"]
    log_w = gla.clamp_log_decay(-jnp.exp(lp["w0"].astype(jnp.float32)
                                         [None, None] + lora))
    log_w = log_w.reshape(b, s, H, hd)

    o, S = gla.gla_chunked(r, k, v, log_w, state=S)
    # bonus: o_t += (r_t · (u ⊙ k_t)) v_t
    bonus = jnp.einsum("bshd,hd,bshd->bsh", r.astype(jnp.float32),
                       lp["u"].astype(jnp.float32), k.astype(jnp.float32))
    o = o + bonus[..., None] * v.astype(jnp.float32)
    o = rms_norm(o.reshape(b, s, d).astype(x.dtype), lp["ln_x"]) * g
    if taps is not None:
        taps.record(f"layers.{layer_idx}.o_in", o)
    x = x + qlinear.dense(lp["wo"], o)
    new_prev_t = h[:, -1]

    # ---- channel mix
    h2 = rms_norm(x, lp["ln2"])
    sh2 = _shift(h2, prev_c)
    xc = h2 + lp["mu_cmix"][0][None, None].astype(h2.dtype) * (sh2 - h2)
    if taps is not None:
        taps.record(f"layers.{layer_idx}.mlp_in", xc)
    kk = jnp.square(jax.nn.relu(qlinear.dense(lp["ck"], xc)))
    if taps is not None:
        taps.record(f"layers.{layer_idx}.down_in", kk)
    x = x + qlinear.dense(lp["cv"], kk)
    new_state = {"tmix_x": new_prev_t, "cmix_x": h2[:, -1], "wkv": S}
    return x, new_state


def forward(cfg, params, tokens, *, cache=None, taps=None,
            unroll: bool = False, extra_embed=None):
    cd = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    x = params["embed"][tokens].astype(cd)
    b, s, _ = x.shape
    state = cache if cache is not None else init_cache(cfg, b, 0)
    if unroll or taps is not None:
        new_states = []
        for i in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[i], params["layers"])
            st = jax.tree.map(lambda a: a[i], state["layers"])
            x, st = _layer(cfg, x, lp, st, taps=taps, layer_idx=i)
            new_states.append(st)
        new_layers = jax.tree.map(lambda *xs: jnp.stack(xs), *new_states)
    else:
        def body(x, xs):
            lp, st = xs
            x, st = _layer(cfg, x, lp, st)
            if cfg.act_shard == "seq":
                from repro.distributed.act_sharding import constrain_seq
                x = constrain_seq(x)
            return x, st
        if cfg.remat:
            body = jax.checkpoint(body)
        from repro.models.flags import scan as _scan
        x, new_layers = _scan(body, x, (params["layers"], state["layers"]))
    x = rms_norm(x, params["final_norm"])
    new_cache = {"layers": new_layers, "pos": state["pos"] + s}
    return x, jnp.zeros((), jnp.float32), new_cache


def logits_fn(cfg, params, hidden):
    return softcap(hidden @ params["embed"].T.astype(hidden.dtype),
                   cfg.logit_softcap)


def init_cache(cfg, batch_size: int, max_len: int = 0) -> dict:
    D, H, hd = cfg.d_model, cfg.n_heads, cfg.head_dim
    L = cfg.n_layers
    return {
        "layers": {
            "tmix_x": jnp.zeros((L, batch_size, D), jnp.bfloat16),
            "cmix_x": jnp.zeros((L, batch_size, D), jnp.bfloat16),
            "wkv": jnp.zeros((L, batch_size, H, hd, hd), jnp.float32),
        },
        "pos": jnp.int32(0),
    }


def loss(cfg, params, batch, **kw):
    from repro.models.losses import chunked_ce
    hidden, aux, _ = forward(cfg, params, batch["tokens"])
    return chunked_ce(lambda h: logits_fn(cfg, params, h), hidden,
                      batch["labels"], aux)


def prefill(cfg, params, tokens, cache, extra_embed=None):
    hidden, _, cache = forward(cfg, params, tokens, cache=cache)
    return logits_fn(cfg, params, hidden[:, -1:]), cache


def decode(cfg, params, token, cache):
    hidden, _, cache = forward(cfg, params, token, cache=cache)
    return logits_fn(cfg, params, hidden), cache
