"""Gemma 2 2B [arXiv:2408.00118]: local+global alternating attention (1:1,
window 4096), logit softcapping, sandwich norms, head_dim 256."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b", family="dense",
    n_layers=26, d_model=2304, n_heads=8, n_kv_heads=4, head_dim=256,
    d_ff=9216, vocab=256_000,
    window=4096, local_ratio=1,          # alternating local:global
    attn_softcap=50.0, logit_softcap=30.0, post_norms=True,
    act="gelu", tie_embeddings=True,
)
