"""Architecture config schema + registry.

One module per assigned architecture lives next to this file; each exports
``CONFIG`` (the exact published shape) and the registry maps ``--arch`` ids
to them. ``ArchConfig.scaled()`` derives reduced smoke-test variants.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    # attention pattern
    window: Optional[int] = None    # sliding-window size for local layers
    local_ratio: int = 0            # N local layers per 1 global (0 = all global)
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    qk_norm: bool = False
    post_norms: bool = False        # gemma2/3 sandwich norms
    rope_theta: float = 10_000.0
    act: str = "silu"               # silu | gelu

    gated_mlp: bool = True          # False: plain up/act/down (GPTBigCode)

    # moe
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # ssm / hybrid
    ssm_state: int = 0
    ssm_heads: int = 0
    attn_every: int = 0             # zamba2: shared attn block every N layers

    # enc-dec (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0                # precomputed frame embeddings (stub frontend)

    # vlm (paligemma)
    n_patches: int = 0              # precomputed patch embeddings (stub tower)

    tie_embeddings: bool = True
    dtype: str = "bfloat16"
    # CAT / quantization defaults for this arch
    cat_block: int = 128
    kv_quant_bits: int = 0          # >0: dynamic per-token KV cache quant

    # distribution / memory knobs (the §Perf iteration space)
    remat: bool = False             # checkpoint the layer-scan body
    act_shard: str = "none"         # none | seq (Megatron-SP carry)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def sub_quadratic(self) -> bool:
        """True if decode state does not grow with context (SSM/linear-attn
        dominated) — gates the long_500k shape (see DESIGN.md §5)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # every assigned arch has an autoregressive decoder

    def scaled(self, **kw) -> "ArchConfig":
        """Reduced variant of the same family for CPU smoke tests."""
        return dataclasses.replace(self, **kw)

    def smoke(self) -> "ArchConfig":
        small = dict(
            n_layers=min(self.n_layers, 2),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // max(self.n_heads, 1))),
            head_dim=32,
            d_ff=256,
            vocab=512,
        )
        if self.n_experts:
            # capacity_factor E/k guarantees dropless routing => the
            # prefill/decode == teacher-forced consistency contract is exact.
            small.update(n_experts=4, top_k=2, d_ff=64, capacity_factor=2.0)
        if self.ssm_state:
            small.update(ssm_state=16, ssm_heads=4)
        if self.attn_every:
            small.update(n_layers=4, attn_every=2)
        if self.n_enc_layers:
            small.update(n_enc_layers=2, enc_seq=16)
        if self.n_patches:
            small.update(n_patches=8)
        if self.window:
            small.update(window=16)
        return dataclasses.replace(self, **small)


ARCH_IDS = [
    "gemma2_2b",
    "mistral_nemo_12b",
    "granite_34b",
    "gemma3_12b",
    "zamba2_7b",
    "whisper_small",
    "rwkv6_7b",
    "granite_moe_1b_a400m",
    "moonshot_v1_16b_a3b",
    "paligemma_3b",
    # the paper's own evaluation model (a small LM used by benchmarks)
    "catlm_60m",
]


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("-", "_")
    assert arch in ARCH_IDS, f"unknown arch {arch!r}; known: {ARCH_IDS}"
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG
