from .base import ARCH_IDS, ArchConfig, get_config  # noqa: F401
