"""Gemma 3 12B [hf:google/gemma-3 family]: 5:1 local:global (window 1024),
qk-norm instead of attn softcap, 128k context, vocab 262144."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15360, vocab=262_144,
    window=1024, local_ratio=5, qk_norm=True, post_norms=True,
    logit_softcap=0.0, act="gelu", rope_theta=1_000_000.0,
)
