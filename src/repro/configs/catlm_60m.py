"""The paper's own evaluation vehicle: a small dense LM we can train from
scratch on CPU, calibrate, and PTQ with every transform (benchmarks/)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="catlm-60m", family="dense",
    n_layers=8, d_model=512, n_heads=8, n_kv_heads=4, head_dim=64,
    d_ff=1536, vocab=8192,
    cat_block=64,
)
