"""Zamba2 7B [arXiv:2411.15242]: Mamba2 backbone + shared attention block
invoked periodically (hybrid). 81 mamba layers, shared attn every 6."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab=32_000,
    ssm_state=64, ssm_heads=56,   # d_inner = 2*d_model, 64-wide heads
    attn_every=6,
)
