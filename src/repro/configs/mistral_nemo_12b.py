"""Mistral-Nemo 12B [hf:mistralai/Mistral-Nemo-Base-2407]: llama-arch,
explicit head_dim 128, 128k context, vocab 131072 (tekken)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab=131_072,
    rope_theta=1_000_000.0, tie_embeddings=False,
)
