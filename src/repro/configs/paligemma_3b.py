"""PaliGemma 3B [arXiv:2407.07726]: SigLIP vision tower STUBBED —
input_specs() provides 256 patch embeddings; gemma backbone, MQA kv=1."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, head_dim=256,
    d_ff=16384, vocab=257_216,
    n_patches=256, act="gelu",
)
