"""Whisper small [arXiv:2212.04356]: enc-dec, conv frontend STUBBED —
input_specs() provides precomputed frame embeddings (1500, d_model)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=3072, vocab=51_865,
    n_enc_layers=12, enc_seq=1500,
    act="gelu", tie_embeddings=True,
)
