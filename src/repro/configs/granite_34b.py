"""Granite 34B code [arXiv:2405.04324]: llama-arch, MQA (kv=1), 88 layers."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1, head_dim=128,
    d_ff=24576, vocab=49_152,
    act="gelu", tie_embeddings=True, gated_mlp=False,  # GPTBigCode-style MLP
)
