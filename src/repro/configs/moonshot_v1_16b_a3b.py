"""Moonlight 16B-A3B [hf:moonshotai/Moonlight-16B-A3B]: 64 experts top-6,
per-expert d_ff 1408, vocab 163840."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408, vocab=163_840,
    n_experts=64, top_k=6, tie_embeddings=False,
)
