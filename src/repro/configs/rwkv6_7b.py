"""RWKV-6 (Finch) 7B [arXiv:2404.05892]: attention-free, data-dependent
per-channel decay linear attention + token shift."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm",
    n_layers=32, d_model=4096, n_heads=64, n_kv_heads=64, head_dim=64,
    d_ff=14336, vocab=65_536,
    ssm_state=64, ssm_heads=64,
)
