"""Paged KV-cache bookkeeping: a fixed-size-page allocator + per-slot
page tables (all host-side; the device-side page *pool* arrays live in
the model cache, see ``models.dense.init_paged_cache``).

Layout contract (shared with ``models.layers`` and
``kernels.paged_attention``):

- The pool holds ``n_pages`` pages of ``page_size`` token rows each,
  per layer: leaves are (L, n_pages, page_size, KV, hd) codes plus
  congruent per-token scale leaves when the cache is quantized.
- **Page 0 is the null page** — never allocated. Page-table entries
  default to 0, so dummy writes (free decode slots, padded prefill rows
  past a slot's table) land there inertly, and dummy reads are causally
  masked. Every *owned* page belongs to exactly one slot, so real
  scatter writes never collide.
- Logical position ``p`` of a slot lives at row ``p % page_size`` of
  physical page ``table[slot, p // page_size]``.

Pages are fixed-size, so "fragmentation" cannot strand capacity: any
free page satisfies any allocation (``tests/test_paged_cache.py`` pins
this as an allocator property). Allocation order is deterministic
(lowest free page id first) so paged engine runs are reproducible.
"""
from __future__ import annotations

import heapq
from typing import List

import numpy as np

NULL_PAGE = 0


class PagePool:
    """Host-side allocator over a fixed set of page ids [1, n_pages).

    Invariants (property-tested): a page is never handed out twice
    without an intervening free, frees are exactly-once, page 0 is never
    allocated, and ``available + in_use == n_pages - 1`` at all times.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 is the reserved "
                             f"null page), got n_pages={n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages, self.page_size = n_pages, page_size
        self._free: List[int] = list(range(1, n_pages))  # heap, low id first
        heapq.heapify(self._free)
        self._in_use: set = set()
        self.peak_in_use = 0
        self.allocs = 0
        self.frees = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._in_use)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.n_pages - 1} allocatable "
                f"pages, all in use)")
        page = heapq.heappop(self._free)
        self._in_use.add(page)
        self.allocs += 1
        self.peak_in_use = max(self.peak_in_use, len(self._in_use))
        return page

    def free(self, page: int) -> None:
        if page not in self._in_use:
            raise RuntimeError(f"freeing page {page} that is not allocated "
                               f"(double free or foreign id)")
        self._in_use.remove(page)
        heapq.heappush(self._free, page)
        self.frees += 1


class SlotPageTables:
    """Per-slot page tables over a shared ``PagePool``.

    ``table`` is the (n_slots, n_ptab) int32 host array the engine ships
    to the device each step (row per slot, ``NULL_PAGE`` for unallocated
    tail entries). Pages are allocated lazily: the prompt's pages at
    admission, then one page at a time as decode crosses page
    boundaries — resident KV bytes track actual sequence lengths instead
    of the slot-cache's ``n_slots × max_len`` worst case.

    Admission additionally *reserves* the request's worst-case page count
    (prompt + decode budget) without allocating it: ``can_admit`` only
    says yes when unreserved capacity covers the whole budget, so an
    admitted request can never strand mid-decode on an exhausted pool
    (there is no preemption — a stranded slot would deadlock the batch).
    """

    def __init__(self, pool: PagePool, n_slots: int, n_ptab: int):
        self.pool = pool
        self.n_ptab = n_ptab
        self.table = np.full((n_slots, n_ptab), NULL_PAGE, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved = [0] * n_slots

    def n_owned(self, slot: int) -> int:
        return len(self._owned[slot])

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.pool.page_size)

    @property
    def reserved_unallocated(self) -> int:
        """Pages promised to admitted slots but not yet allocated."""
        return sum(max(0, r - len(o))
                   for r, o in zip(self._reserved, self._owned))

    def can_admit(self, budget_tokens: int) -> bool:
        return (self.pool.available - self.reserved_unallocated
                >= self.pages_for(budget_tokens))

    def admit(self, slot: int, n_tokens: int,
              budget_tokens: int = 0) -> None:
        """Allocate the pages covering logical rows [0, n_tokens) and
        reserve enough for ``budget_tokens`` total."""
        assert not self._owned[slot], f"slot {slot} already holds pages"
        self._reserved[slot] = self.pages_for(max(budget_tokens, n_tokens))
        for i in range(self.pages_for(n_tokens)):
            page = self.pool.alloc()
            self._owned[slot].append(page)
            self.table[slot, i] = page

    def ensure(self, slot: int, pos: int) -> None:
        """Grow the slot's table so a write at logical row ``pos`` has a
        real page (decode calls this right before each step). Growth
        within the admission reservation cannot fail."""
        idx = pos // self.pool.page_size
        if idx >= self.n_ptab:
            raise RuntimeError(f"slot {slot} position {pos} exceeds the "
                               f"table ({self.n_ptab} pages)")
        while self.n_owned(slot) <= idx:
            page = self.pool.alloc()
            self._owned[slot].append(page)
            self.table[slot, self.n_owned(slot) - 1] = page

    def release(self, slot: int) -> None:
        """Free all of a slot's pages (exactly once), drop its
        reservation, and null its row."""
        for page in self._owned[slot]:
            self.pool.free(page)
        self._owned[slot] = []
        self._reserved[slot] = 0
        self.table[slot] = NULL_PAGE
