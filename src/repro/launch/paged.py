"""Paged KV-cache bookkeeping: a fixed-size-page allocator + per-slot
page tables + the prefix cache (all host-side; the device-side page
*pool* arrays live in the model cache, see
``models.dense.init_paged_cache``).

Layout contract (shared with ``models.layers`` and
``kernels.paged_attention``):

- The pool holds ``n_pages`` pages of ``page_size`` token rows each,
  per layer: leaves are (L, n_pages, page_size, KV, hd) codes plus
  congruent per-token scale leaves when the cache is quantized.
- **Page 0 is the null page** — never allocated. Page-table entries
  default to 0, so dummy writes (free decode slots, padded prefill rows
  past a slot's table) land there inertly, and dummy reads are causally
  masked. A page is *written* only while exactly one slot maps it
  (refcount 1), so real scatter writes never collide.
- Logical position ``p`` of a slot lives at row ``p % page_size`` of
  physical page ``table[slot, p // page_size]``.

Pages are **refcounted** so they can be shared read-only across slots
(prefix caching, vLLM/SGLang-style): ``alloc`` hands a page out at
refcount 1, ``incref`` adds a mapping (another slot's table entry or a
:class:`PrefixCache` trie node), ``decref`` drops one and frees the page
when the count reaches 0. A shared page is never a scatter-write target:
the first write past a shared boundary goes through
``SlotPageTables.ensure_writable`` which allocates a private replacement
and reports the (src, dst) pair for a device-side page copy
(copy-on-write). Lifecycle: free → owned (rc 1) → shared (rc > 1) →
COW-split (writer gets a private copy, shared rc drops) → free (rc 0).

Pages are fixed-size, so "fragmentation" cannot strand capacity: any
free page satisfies any allocation (``tests/test_paged_cache.py`` pins
this as an allocator property). Allocation order is deterministic
(lowest free page id first) so paged engine runs are reproducible.
"""
from __future__ import annotations

import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

NULL_PAGE = 0


class PagePool:
    """Host-side refcounting allocator over a fixed set of page ids
    [1, n_pages).

    Invariants (property-tested): a page is never handed out twice
    without an intervening free, frees are exactly-once and only at
    refcount 0, page 0 is never allocated, and
    ``available + in_use == n_pages - 1`` at all times (``in_use`` =
    pages with refcount >= 1).
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError(f"need >= 2 pages (page 0 is the reserved "
                             f"null page), got n_pages={n_pages}")
        if page_size < 1:
            raise ValueError(f"page_size must be >= 1, got {page_size}")
        self.n_pages, self.page_size = n_pages, page_size
        self._free: List[int] = list(range(1, n_pages))  # heap, low id first
        heapq.heapify(self._free)
        self._refs: Dict[int, int] = {}     # page -> refcount (>= 1)
        self.peak_in_use = 0
        self.allocs = 0
        self.frees = 0

    @property
    def available(self) -> int:
        return len(self._free)

    @property
    def in_use(self) -> int:
        return len(self._refs)

    @property
    def total_refs(self) -> int:
        """Sum of refcounts — equals (slot table mappings + prefix-cache
        residencies); pinned by tests/test_prefix_cache_properties.py."""
        return sum(self._refs.values())

    def refcount(self, page: int) -> int:
        return self._refs.get(page, 0)

    def alloc(self) -> int:
        if not self._free:
            raise RuntimeError(
                f"page pool exhausted ({self.n_pages - 1} allocatable "
                f"pages, all in use)")
        page = heapq.heappop(self._free)
        self._refs[page] = 1
        self.allocs += 1
        self.peak_in_use = max(self.peak_in_use, len(self._refs))
        return page

    def incref(self, page: int) -> None:
        """Add a mapping to an allocated page (read-only sharing)."""
        if page not in self._refs:
            raise RuntimeError(f"incref of page {page} that is not "
                               f"allocated")
        self._refs[page] += 1

    def decref(self, page: int) -> bool:
        """Drop one mapping; frees the page (returns True) at refcount 0.
        A page can never be freed while another mapping still references
        it — that is the whole safety argument for sharing."""
        if page not in self._refs:
            raise RuntimeError(f"decref of page {page} that is not "
                               f"allocated (double free or foreign id)")
        self._refs[page] -= 1
        if self._refs[page]:
            return False
        del self._refs[page]
        heapq.heappush(self._free, page)
        self.frees += 1
        return True

    def free(self, page: int) -> None:
        """Exclusive-owner free (the historical API): refcount must be
        exactly 1 — shared pages are released one mapping at a time via
        ``decref``."""
        if page not in self._refs:
            raise RuntimeError(f"freeing page {page} that is not allocated "
                               f"(double free or foreign id)")
        if self._refs[page] != 1:
            raise RuntimeError(
                f"freeing page {page} with refcount {self._refs[page]} "
                f"(still shared; drop mappings via decref)")
        self.decref(page)


class SlotPageTables:
    """Per-slot page tables over a shared ``PagePool``.

    ``table`` is the (n_slots, n_ptab) int32 host array the engine ships
    to the device each step (row per slot, ``NULL_PAGE`` for unallocated
    tail entries). Pages are allocated lazily: the prompt's pages at
    admission, then one page at a time as decode crosses page
    boundaries — resident KV bytes track actual sequence lengths instead
    of the slot-cache's ``n_slots × max_len`` worst case.

    Admission additionally *reserves* the request's worst-case page count
    (prompt + decode budget) without allocating it: ``can_admit`` only
    says yes when unreserved capacity covers the whole budget, so an
    admitted request can never strand mid-decode on an exhausted pool
    (there is no preemption — a stranded slot would deadlock the batch).
    On a prefix hit the reservation counts only the *missed* pages —
    ``pages_for(budget) - hit // page_size`` — since the hit's full
    shared pages arrive already allocated and the one partial shared
    page, if any, needs exactly one COW replacement (the worst-case
    formula would head-of-line block cache-hit requests an undersized
    pool can actually serve; regression-tested in
    ``tests/test_prefix_cache_properties.py``).
    """

    def __init__(self, pool: PagePool, n_slots: int, n_ptab: int):
        self.pool = pool
        self.n_ptab = n_ptab
        self.table = np.full((n_slots, n_ptab), NULL_PAGE, np.int32)
        self._owned: List[List[int]] = [[] for _ in range(n_slots)]
        self._reserved = [0] * n_slots
        # pages a slot maps but does not exclusively own (prefix-shared,
        # refcount > 1): never scatter-write targets until COW-split
        self._shared: List[set] = [set() for _ in range(n_slots)]
        # 1 while an admitted slot still owes a COW replacement page for
        # its partial shared page (counted against pool capacity until
        # ensure_writable allocates it)
        self._cow_pending = [0] * n_slots

    def n_owned(self, slot: int) -> int:
        return len(self._owned[slot])

    def owned_pages(self, slot: int) -> List[int]:
        return list(self._owned[slot])

    def n_shared(self, slot: int) -> int:
        return len(self._shared[slot])

    @property
    def slot_mapped_pages(self) -> int:
        """Distinct pages referenced by live slot tables — the actual
        serving footprint. Shared prefix pages count once; pages retained
        only by the prefix cache don't count at all (they are reported
        separately as cached pages)."""
        pages: set = set()
        for o in self._owned:
            pages.update(o)
        return len(pages)

    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.pool.page_size)

    @property
    def reserved_unallocated(self) -> int:
        """Pages promised to admitted slots but not yet allocated,
        including pending COW replacement pages."""
        return sum(max(0, r - len(o)) + c
                   for r, o, c in zip(self._reserved, self._owned,
                                      self._cow_pending))

    def can_admit(self, budget_tokens: int, hit_tokens: int = 0) -> bool:
        """Missed-pages admission test: a hit's ``hit_tokens // G`` full
        shared pages are already allocated, so only the remainder (which
        algebraically folds in the +1 COW page for a partial hit) needs
        unreserved pool capacity."""
        need = (self.pages_for(budget_tokens)
                - hit_tokens // self.pool.page_size)
        return self.pool.available - self.reserved_unallocated >= need

    def admit(self, slot: int, n_tokens: int,
              budget_tokens: int = 0) -> None:
        """Allocate the pages covering logical rows [0, n_tokens) and
        reserve enough for ``budget_tokens`` total."""
        self.admit_prefix(slot, [], 0, n_tokens,
                          budget_tokens=budget_tokens)

    def admit_prefix(self, slot: int, shared_pages: List[int],
                     hit_tokens: int, n_tokens: int,
                     budget_tokens: int = 0) -> None:
        """Prefix-aware admission: map ``shared_pages`` (the cached run
        covering prompt rows [0, hit_tokens), refcount-bumped, read-only)
        into the slot's table, then allocate fresh pages for the rest of
        [0, n_tokens). Reserves ``budget_tokens`` worth of pages counting
        only the missed ones (see class docstring)."""
        assert not self._owned[slot], f"slot {slot} already holds pages"
        G = self.pool.page_size
        assert len(shared_pages) == self.pages_for(hit_tokens), \
            (len(shared_pages), hit_tokens, G)
        assert n_tokens >= hit_tokens
        self._reserved[slot] = self.pages_for(max(budget_tokens, n_tokens))
        self._cow_pending[slot] = 1 if hit_tokens % G else 0
        for i, page in enumerate(shared_pages):
            self.pool.incref(page)
            self._owned[slot].append(page)
            self._shared[slot].add(page)
            self.table[slot, i] = page
        for i in range(len(shared_pages), self.pages_for(n_tokens)):
            page = self.pool.alloc()
            self._owned[slot].append(page)
            self.table[slot, i] = page

    def ensure_writable(self, slot: int, pos: int
                        ) -> List[Tuple[int, int]]:
        """Copy-on-write split: if the page holding logical row ``pos``
        is mapped shared, allocate a private replacement, remap the
        slot's table entry, drop the shared mapping, and return the
        [(src, dst)] pair the caller must turn into a device-side page
        copy *before* the step that writes the divergent rows. Returns
        [] when the page is already exclusively owned (or not yet
        allocated). Callers dispatch the copy before releasing any other
        work to the device, so a freed ``src`` reallocated in the same
        plan is still read before its new owner writes it."""
        idx = pos // self.pool.page_size
        if idx >= self.n_owned(slot):
            return []
        src = self._owned[slot][idx]
        if src not in self._shared[slot]:
            return []
        dst = self.pool.alloc()
        self._owned[slot][idx] = dst
        self.table[slot, idx] = dst
        self._shared[slot].discard(src)
        self._cow_pending[slot] = 0
        self.pool.decref(src)
        return [(src, dst)]

    def assert_writable(self, slot: int, start: int, end: int) -> None:
        """Scatter guard: every logical row in [start, end] must land in
        an exclusively-owned page (refcount 1) — a shared page reached
        here means a missing ``ensure_writable`` (COW) call. Unallocated
        tail pages are fine (their writes hit the null page)."""
        G = self.pool.page_size
        top = min(end // G, self.n_owned(slot) - 1)
        for idx in range(start // G, top + 1):
            page = self._owned[slot][idx]
            if (page in self._shared[slot]
                    or self.pool.refcount(page) != 1):
                raise RuntimeError(
                    f"slot {slot} write rows [{start}, {end}] target page "
                    f"{page} (table idx {idx}) with refcount "
                    f"{self.pool.refcount(page)} — shared pages are "
                    f"read-only until COW-split")

    def ensure(self, slot: int, pos: int) -> None:
        """Grow the slot's table so a write at logical row ``pos`` has a
        real page (decode calls this right before each step). Growth
        within the admission reservation cannot fail."""
        idx = pos // self.pool.page_size
        if idx >= self.n_ptab:
            raise RuntimeError(f"slot {slot} position {pos} exceeds the "
                               f"table ({self.n_ptab} pages)")
        if (idx < self.n_owned(slot)
                and self._owned[slot][idx] in self._shared[slot]):
            raise RuntimeError(
                f"slot {slot} write at pos {pos} targets shared page "
                f"{self._owned[slot][idx]} (needs ensure_writable/COW)")
        while self.n_owned(slot) <= idx:
            page = self.pool.alloc()
            self._owned[slot].append(page)
            self.table[slot, self.n_owned(slot) - 1] = page

    def shrink(self, slot: int, n_tokens: int) -> int:
        """Speculative-decode rewind: free owned pages lying wholly past
        logical rows [0, n_tokens) — the page-boundary part of discarding
        rejected draft positions. No device copy is needed for the rows
        themselves: stale KV past a slot's valid length is causally
        masked (q_pos >= kv_pos) and overwritten by the next cycle's
        scatter before it is ever attendable — only the page *table* must
        match a never-drafted run so pool accounting (refcounts,
        can_admit) stays exact. Keeps ``pages_for(n_tokens)`` pages;
        returns the number freed. Refuses to drop shared pages: the
        shrink boundary is always at or past the prompt end (drafts start
        at the last generated token), so prefix-shared prompt pages are
        structurally out of reach — hitting one means a bookkeeping bug."""
        keep = self.pages_for(n_tokens)
        freed = 0
        while self.n_owned(slot) > keep:
            page = self._owned[slot][-1]
            if page in self._shared[slot]:
                raise RuntimeError(
                    f"slot {slot} shrink to {n_tokens} tokens would drop "
                    f"shared page {page} — speculative rewind must never "
                    f"reach prefix-shared prompt pages")
            self._owned[slot].pop()
            self.table[slot, self.n_owned(slot)] = NULL_PAGE
            self.pool.decref(page)
            freed += 1
        return freed

    def release(self, slot: int) -> None:
        """Drop all of the slot's page mappings (exactly once; a page is
        freed only when its last mapping — another slot's or the prefix
        cache's — goes too), drop its reservation, and null its row."""
        for page in self._owned[slot]:
            self.pool.decref(page)
        self._owned[slot] = []
        self._shared[slot].clear()
        self._reserved[slot] = 0
        self._cow_pending[slot] = 0
        self.table[slot] = NULL_PAGE


# ------------------------------------------------------------ prefix cache

class _TrieNode:
    """One cached full page: ``key`` is its page_size-token id tuple,
    ``page`` the pool page holding those tokens' (quantized) KV. Children
    key on the next page's tokens, so a root-to-node path spells a token
    prefix at page granularity."""
    __slots__ = ("key", "page", "children", "parent", "last_used")

    def __init__(self, key, page, parent):
        self.key, self.page, self.parent = key, page, parent
        self.children: dict = {}
        self.last_used = 0


class PrefixCache:
    """Radix/trie index from token-id prefixes to cached page runs
    (vLLM/SGLang-style automatic prefix caching).

    Nodes are *full* pages keyed on their page_size-token chunk; lookup
    walks exact full-page matches and then the longest partial match
    into one child's key (so two prompts diverging mid-page still share
    the cached page up to the COW boundary). The whole trie is keyed on
    ``config_key`` (model/quant digest) so pages can never be served
    across incompatible quantization configs — one engine owns one
    cache, but the key makes the invariant structural.

    Residency: every node holds one pool refcount on its page, taken at
    ``register`` and dropped at eviction — a cached page outlives the
    slot that computed it, and a page a slot still maps can never be
    freed out from under it. Correctness of reuse is exactly the repo's
    golden-fixture concern: attention always reads the *stored*
    (post-quantization) page content, and identical tokens at identical
    positions produce identical codes/scales, so serving a cached page
    is bitwise identical to recomputing it
    (``tests/test_prefix_cache_golden.py``).
    """

    def __init__(self, pool: PagePool, page_size: int, config_key=()):
        if page_size != pool.page_size:
            raise ValueError(f"page_size {page_size} != pool.page_size "
                             f"{pool.page_size}")
        self.pool = pool
        self.page_size = page_size
        self.config_key = tuple(config_key)
        self._roots: Dict[tuple, dict] = {}   # config_key -> children dict
        self._tick = 0
        # metrics (admission-scoped: note() runs once per admitted
        # request, not per head-of-line retry)
        self.lookups = 0
        self.hits = 0
        self.hit_tokens = 0
        self.lookup_tokens = 0
        self.cow_copies = 0
        self.inserted_pages = 0
        self.evicted_pages = 0
        self.resident = 0                     # pages the trie holds a ref on

    # ------------------------------------------------------------- lookup

    def _root(self) -> dict:
        return self._roots.setdefault(self.config_key, {})

    @staticmethod
    def _common(a, b) -> int:
        n = 0
        for x, y in zip(a, b):
            if x != y:
                break
            n += 1
        return n

    def lookup(self, prompt) -> Tuple[int, List[int]]:
        """Longest cached prefix of ``prompt``: (hit_tokens, pages) where
        ``pages`` covers prompt rows [0, hit_tokens) (the last page
        partial when hit_tokens % page_size != 0 — the COW boundary).

        The hit is capped at len(prompt) - 1: at least one prompt token
        must be genuinely prefilled so the first-token logits come from a
        real forward row. Touches matched nodes' LRU stamps."""
        G = self.page_size
        toks = [int(t) for t in prompt]
        cap = len(toks) - 1
        self._tick += 1
        children = self._root()
        hit, pages = 0, []
        while hit + G <= cap:
            node = children.get(tuple(toks[hit:hit + G]))
            if node is None:
                break
            node.last_used = self._tick
            pages.append(node.page)
            hit += G
            children = node.children
        lim = min(cap - hit, G)
        if lim > 0:
            best, best_n = None, 0
            for key, node in children.items():
                n = self._common(key, toks[hit:hit + lim])
                if n > best_n:
                    best, best_n = node, n
            if best is not None:
                best.last_used = self._tick
                pages.append(best.page)
                hit += best_n
        return hit, pages

    def note(self, hit_tokens: int, prompt_tokens: int) -> None:
        """Record one admission's lookup outcome (hit-rate metrics)."""
        self.lookups += 1
        self.lookup_tokens += prompt_tokens
        if hit_tokens:
            self.hits += 1
            self.hit_tokens += hit_tokens

    @property
    def hit_rate(self) -> float:
        """Fraction of admitted prompt tokens served from cache."""
        return (self.hit_tokens / self.lookup_tokens
                if self.lookup_tokens else 0.0)

    # ----------------------------------------------------------- register

    def register(self, prompt, pages: List[int]) -> int:
        """Insert a finished prefill's *full* prompt pages (``pages`` is
        the slot's owned-page run; entries [0, len(prompt) // page_size)
        are used). Called only after the pages' content has landed on
        device (prefill completion), so a later hit reads real KV. The
        partial last prompt page and decode pages stay private — their
        owner keeps writing them. Where a node already exists (another
        request cached the same chunk first) the existing page wins and
        ours stays slot-private. Returns pages newly adopted."""
        G = self.page_size
        toks = [int(t) for t in prompt]
        self._tick += 1
        children = self._root()
        parent = None
        added = 0
        for i in range(len(toks) // G):
            key = tuple(toks[i * G:(i + 1) * G])
            node = children.get(key)
            if node is None:
                node = _TrieNode(key, pages[i], parent)
                children[key] = node
                self.pool.incref(pages[i])
                self.resident += 1
                self.inserted_pages += 1
                added += 1
            node.last_used = self._tick
            parent = node
            children = node.children
        return added

    # ------------------------------------------------------------ evict

    def _walk(self):
        stack = [n for root in self._roots.values()
                 for n in root.values()]
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def _drop(self, node: _TrieNode) -> None:
        siblings = (node.parent.children if node.parent is not None
                    else self._root())
        del siblings[node.key]
        self.pool.decref(node.page)
        self.resident -= 1
        self.evicted_pages += 1

    def evict(self, need: int, protect=frozenset()) -> int:
        """Free up to ``need`` cache-only pages, LRU leaves first (leaf
        order keeps every remaining root-to-node path contiguous — a
        lookup never walks across a hole). Only pages whose sole mapping
        is the trie's (refcount 1) are candidates: pages still mapped by
        a live slot, and the ``protect`` set (the run the current
        admission is about to share), are skipped."""
        freed = 0
        while freed < need:
            leaves = [n for n in self._walk()
                      if not n.children
                      and self.pool.refcount(n.page) == 1
                      and n.page not in protect]
            if not leaves:
                break
            self._drop(min(leaves, key=lambda n: n.last_used))
            freed += 1
        return freed

    def make_room(self, tables: SlotPageTables, budget_tokens: int,
                  hit_tokens: int = 0, protect=()) -> bool:
        """Admission-time reclamation: evict cache-only pages until the
        missed-pages reservation fits (or nothing evictable remains).
        Returns the final ``can_admit`` verdict — False means genuine
        head-of-line wait (live slots hold the pages)."""
        if tables.can_admit(budget_tokens, hit_tokens=hit_tokens):
            return True
        need = (tables.pages_for(budget_tokens)
                - hit_tokens // self.page_size
                - (self.pool.available - tables.reserved_unallocated))
        self.evict(need, protect=frozenset(protect))
        return tables.can_admit(budget_tokens, hit_tokens=hit_tokens)

    def clear(self) -> int:
        """Drop every cached page (engine teardown / tests): each node's
        pool ref is returned, so a drained engine's pool goes back to
        empty. Returns the number of pages dropped."""
        n = 0
        for node in list(self._walk()):
            self.pool.decref(node.page)
            n += 1
        self._roots.clear()
        self.evicted_pages += n
        self.resident = 0
        return n

    # ------------------------------------------------------------ metrics

    def reset_stats(self) -> None:
        """Zero the counters without touching cache content (the engine's
        warmup/steady-state ``reset()`` hook: a warm cache is server
        state, like compiled code)."""
        self.lookups = self.hits = 0
        self.hit_tokens = self.lookup_tokens = 0
        self.cow_copies = 0
        self.inserted_pages = self.evicted_pages = 0

    def stats(self) -> dict:
        return {"prefix_lookups": self.lookups,
                "prefix_hits": self.hits,
                "prefix_hit_tokens": self.hit_tokens,
                "prefix_hit_rate": self.hit_rate,
                "cow_copies": self.cow_copies,
                "cached_pages": self.resident,
                "prefix_evicted_pages": self.evicted_pages}
