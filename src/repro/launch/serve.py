"""Quantized serving CLI — a thin front end over the continuous-batching
engine (``repro.launch.engine``) with the paper's deployed pipeline:
CAT-transformed int8/int4-packed weights, dynamic act quant, int8 KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch catlm_60m \
        --requests 8 --n-slots 4 --gen 32 --transform cat --kv-bits 8

Requests enter a FIFO queue deeper than the slot count; the engine
prefills on admit, steps the occupied slots as one batch, and retires /
reuses slots as requests finish — or, with ``--schedule unified``, packs
decode tokens and prefill chunks into one token-budgeted ragged step per
cycle (``--max-batch-tokens``; flat ITL under long-prompt admission,
token-identical output). ``greedy_generate`` stays here as the
static-batch oracle the engine is tested against (token-identical).
"""
from __future__ import annotations

import argparse
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import QuantizeConfig, quantize_model
from repro.core.qlinear import iter_qlinear, num_weight_bytes
from repro.data import calibration_batches, make_batch, request_workload
from repro.launch.engine import ServeEngine, jitted_model_fns
from repro.models import build


def weight_memory_report(params) -> dict:
    """Quantized-weight storage accounting: total bytes and whether any
    layer serves from int4-packed buffers."""
    leaves = [l for _, l in iter_qlinear(params)]
    return {
        "qlinear_layers": len(leaves),
        "weight_bytes": int(sum(num_weight_bytes(l) for l in leaves)),
        "packed_int4": any(l.packed for l in leaves),
    }


def greedy_generate(model, params, prompts: jnp.ndarray, gen: int,
                    max_len: int, temperature: float = 0.0, seed: int = 0):
    """prompts (B, P) -> tokens (B, P+gen). Greedy (or sampled) decode.

    Static batching (every row at the same position) — the per-request
    oracle for the continuous-batching engine."""
    b, p = prompts.shape
    cache = model.init_cache(b, max_len)
    prefill, decode = jitted_model_fns(model)
    logits, cache = prefill(params, prompts, cache)
    out = [prompts]
    key = jax.random.PRNGKey(seed)
    tok = None
    for i in range(gen):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)
            tok = tok[:, None]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
        logits, cache = decode(params, tok, cache)
    return jnp.concatenate(out, axis=1)


def run_steady(engine: ServeEngine, requests, passes: int = 1) -> tuple:
    """Drain the workload through the SAME engine ``1 + passes`` times —
    the first pass triggers every jit compile (untimed), then each
    ``engine.reset()`` + rerun measures steady-state throughput and the
    fastest pass is reported (every pass does identical work, so wall
    differences are scheduler noise; the envelope is the honest
    steady-state number on a shared host). Returns ``(results, summary)``
    from the best pass, with ``summary["compile_s"] = wall_first -
    wall_best`` (the first pass does the same work plus compilation —
    cost the old single-pass numbers were charging to tok/s, which
    buried the quantized variants: their transform+quant chains trace
    more distinct XLA programs than fp)."""
    engine.run(requests)
    wall_first = engine.summary()["wall_s"]
    best = None
    for _ in range(max(1, passes)):
        engine.reset()
        results = engine.run(requests)
        summary = engine.summary()
        if best is None or summary["wall_s"] < best[1]["wall_s"]:
            best = (results, summary)
    results, summary = best
    summary["compile_s"] = max(0.0, wall_first - summary["wall_s"])
    return results, summary


def build_served_model(arch: str, transform: str, w_bits: int, a_bits: int,
                       kv_bits: int, smoke: bool, seed: int,
                       cfg_overrides: Optional[dict] = None):
    """-> (cfg, model, params, weight-memory report). ``transform='fp'``
    skips PTQ; ``kv_bits>0`` serves from the int8 slot KV cache;
    ``cfg_overrides`` are extra ``cfg.scaled`` fields (e.g. a
    TP-divisible head count for mesh serving)."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    cfg = cfg.scaled(kv_quant_bits=kv_bits, **(cfg_overrides or {}))
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    mem = {}
    if transform != "fp":
        qcfg = QuantizeConfig(w_bits=w_bits, a_bits=a_bits,
                              transform=transform,
                              cat_block=min(cfg.cat_block, 32))
        calib = calibration_batches(cfg, n_seqs=8, seq_len=64, batch=4)
        params = quantize_model(model, params, qcfg, calib)
        mem = weight_memory_report(params)
    return cfg, model, params, mem


def build_draft_model(arch: str, smoke: bool, seed: int,
                      cfg_overrides: Optional[dict] = None,
                      a_bits: int = 8):
    """The speculative-decoding draft: the SAME checkpoint as the target
    (same arch/seed init), quantized to int4-packed weights with the
    paper's CAT transform — the paper's accuracy claim turned into a
    serving lever. The draft serves from its own int8-KV paged pool, so
    ``kv_quant_bits=8`` regardless of the target's cache dtype.
    -> (draft_model, draft_params) for ``ServeEngine(draft=...)``."""
    cfg, model, params, _ = build_served_model(
        arch, "cat", 4, a_bits, 8, smoke, seed,
        cfg_overrides=cfg_overrides)
    return model, params


def parse_mesh(spec: str):
    """``--mesh dp,tp`` -> a ("data", "model") device mesh (None when the
    spec is empty or 1,1). Needs dp*tp local devices — force host devices
    with XLA_FLAGS=--xla_force_host_platform_device_count=N on CPU."""
    if not spec:
        return None
    dp, tp = (int(v) for v in spec.split(","))
    if dp * tp <= 1:
        return None
    from repro.distributed.compat import make_mesh
    return make_mesh((dp, tp), ("data", "model"))


def serve_benchmark(arch: str = "catlm_60m", batch: int = 4,
                    prompt_len: int = 32, gen: int = 32,
                    transform: str = "cat", w_bits: int = 4,
                    a_bits: int = 4, smoke: bool = True, seed: int = 0,
                    kv_bits: int = 8, n_slots: int = 0,
                    n_requests: int = 0, mixed: bool = False,
                    mesh=None, cfg_overrides: Optional[dict] = None,
                    paged: bool = False, page_size: int = 16,
                    prefill_chunk: int = 0, max_len: int = 0,
                    schedule: str = "legacy", max_batch_tokens: int = 0,
                    warmup: int = 0, prefix_cache: bool = False,
                    shared_prefix: int = 0, speculative: int = 0,
                    adaptive_spec: bool = False,
                    pipeline: Optional[bool] = None):
    """Quantize then serve a workload through the engine.

    Default (``mixed=False``): ``batch`` uniform-length requests so
    ``tokens`` stacks to (batch, prompt_len+gen). ``mixed=True`` runs the
    seeded mixed-prompt-length workload instead (per-request sequences in
    ``results``). ``n_slots`` defaults to ``batch`` (0 = auto). ``mesh``
    serves tensor-parallel (sharded int4 weights + sharded KV cache,
    token-identical to single-device — see launch/README.md). ``paged``
    swaps the slot cache for the paged KV pool (``page_size`` tokens per
    page; ``prefill_chunk`` feeds prompts through in fixed chunks so
    prefill compiles once) — token-identical to the slot engine.
    ``schedule="unified"`` packs decode tokens + prefill chunks into one
    token-budgeted ragged step per cycle (``max_batch_tokens``) —
    token-identical again, with flat ITL under long-prompt admission.
    ``warmup=N`` (N >= 1) drains the workload once untimed then reports
    the fastest of N steady passes (``run_steady``), so the metrics are
    steady-state and compilation cost lands in the separate
    ``compile_s`` summary field. ``prefix_cache=True`` (paged/unified
    only) shares cached prefix pages across requests copy-on-write and
    skips their prefill entirely; pair with ``shared_prefix=S`` to give
    the mixed workload an S-token common system prompt so the cache has
    something to hit. ``speculative=k`` (unified only) drafts k tokens
    per slot per cycle with the int4-packed quantization of the same
    checkpoint and verifies them in one ragged target step — output
    stays token-identical to ``speculative=0``. ``adaptive_spec=True``
    lowers each slot's per-cycle draft depth toward its running
    acceptance rate (k stays the hard cap; output unchanged).
    ``pipeline`` selects the depth-1 asynchronous unified loop (pack +
    dispatch step N+1 while N runs on device; token-identical; see
    launch/README.md) — default None means ON for unified unless
    REPRO_SYNC_STEP is set; ``pipeline=False`` forces the synchronous
    loop with honest blocked per-step timing spans."""
    cfg, model, params, mem = build_served_model(
        arch, transform, w_bits, a_bits, kv_bits, smoke, seed,
        cfg_overrides=cfg_overrides)
    draft = None
    if speculative:
        draft = build_draft_model(arch, smoke, seed,
                                  cfg_overrides=cfg_overrides)

    n_requests = n_requests or batch
    if mixed or shared_prefix:
        requests = request_workload(cfg, n_requests, gen=gen, seed=seed,
                                    shared_prefix=shared_prefix)
    else:
        toks = np.asarray(make_batch(cfg, prompt_len, n_requests,
                                     seed=seed)["tokens"])
        requests = [{"rid": i, "tokens": toks[i], "max_new_tokens": gen}
                    for i in range(n_requests)]
    max_prompt = max(len(r["tokens"]) for r in requests)
    engine = ServeEngine(model, params, n_slots=n_slots or batch,
                         max_len=max_len or max_prompt + gen + 8, mesh=mesh,
                         paged=paged, page_size=page_size,
                         prefill_chunk=prefill_chunk, schedule=schedule,
                         max_batch_tokens=max_batch_tokens,
                         prefix_cache=prefix_cache,
                         speculative_k=speculative, draft=draft,
                         adaptive_spec=adaptive_spec, pipeline=pipeline)
    if warmup:
        results, summary = run_steady(engine, requests, passes=int(warmup))
    else:
        results = engine.run(requests)
        summary = engine.summary()
    out = {
        "arch": arch, "transform": transform,
        "results": results,
        "wall_s": summary["wall_s"],
        "tok_per_s": summary["tok_per_s"],
        "engine": summary,
        **mem,
    }
    if not (mixed or shared_prefix):
        out["tokens"] = np.stack([results[i].tokens
                                  for i in range(n_requests)])
    return out


def validate_flags(ap: argparse.ArgumentParser, args) -> None:
    """Flag admissibility checks, surfaced as argparse errors that name
    the offending flag(s) and the violated constraint — never bare
    asserts or deep-stack ValueErrors."""
    from repro.models.layers import KV_QUANT_GROUP

    unified = args.schedule == "unified"
    if (args.page_size != 16 or args.prefill_chunk) and not (args.paged
                                                             or unified):
        ap.error("--page-size/--prefill-chunk need --paged (or --schedule "
                 "unified, which serves from the paged pool)")
    if args.page_size < 1:
        ap.error(f"--page-size must be >= 1 (got {args.page_size})")
    if args.kv_bits and args.page_size % KV_QUANT_GROUP:
        ap.error(f"--page-size must be a multiple of the KV quant scale "
                 f"group (got {args.page_size}, group {KV_QUANT_GROUP})")
    if args.prefill_chunk < 0:
        ap.error(f"--prefill-chunk must be >= 0 (got {args.prefill_chunk})")
    if args.prefill_chunk and args.prefill_chunk % args.page_size \
            and not unified:
        ap.error(f"--prefill-chunk must be a multiple of --page-size "
                 f"(got {args.prefill_chunk}, page {args.page_size}); "
                 f"legacy chunks write whole pages — only --schedule "
                 f"unified slices chunks freely")
    if args.prefix_cache and not (args.paged or unified):
        ap.error("--prefix-cache needs --paged (or --schedule unified): "
                 "cached prefixes are shared pages of the paged KV pool")
    if args.shared_prefix < 0:
        ap.error(f"--shared-prefix must be >= 0 "
                 f"(got {args.shared_prefix})")
    if args.max_batch_tokens and not unified:
        ap.error(f"--max-batch-tokens needs --schedule unified "
                 f"(got {args.max_batch_tokens} with --schedule "
                 f"{args.schedule})")
    if args.max_batch_tokens and args.max_batch_tokens < args.batch:
        ap.error(f"--max-batch-tokens must be >= --n-slots (got "
                 f"{args.max_batch_tokens}, slots {args.batch}; every "
                 f"running slot decodes one token per step)")
    if unified and args.mesh:
        dp = args.mesh.split(",")[0]
        if dp.strip() not in ("", "1"):
            ap.error(f"--schedule unified is tensor-parallel only — use "
                     f"--mesh 1,tp (got --mesh {args.mesh}; the paged "
                     f"pool is a global allocation and cannot shard over "
                     f"a data axis)")
    if args.speculative < 0:
        ap.error(f"--speculative must be >= 0 (got {args.speculative})")
    if args.speculative and not unified:
        ap.error(f"--speculative needs --schedule unified (got "
                 f"--schedule {args.schedule}; the draft/verify cycle "
                 f"runs inside the token-budgeted ragged step)")
    if (args.speculative and args.max_batch_tokens
            and args.max_batch_tokens < args.batch *
            (args.speculative + 1)):
        ap.error(f"--max-batch-tokens must be >= --n-slots × "
                 f"(--speculative + 1) (got {args.max_batch_tokens}, "
                 f"need {args.batch * (args.speculative + 1)}; every "
                 f"decoding slot packs k+1 verify rows per step)")
    if args.adaptive_spec and not args.speculative:
        ap.error("--adaptive-spec needs --speculative K (it tunes the "
                 "per-slot draft depth below K)")
    if args.pipeline and not unified:
        ap.error("--pipeline needs --schedule unified (legacy "
                 "prefill-on-admit is inherently synchronous)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="catlm_60m")
    ap.add_argument("--batch", "--n-slots", dest="batch", type=int,
                    default=4, help="engine slot count")
    ap.add_argument("--requests", type=int, default=0,
                    help="queue depth (default: slot count)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--mixed", action="store_true",
                    help="mixed-prompt-length workload")
    ap.add_argument("--transform", default="cat",
                    choices=["fp", "none", "smoothquant", "hadamard", "cat"])
    ap.add_argument("--w-bits", "--bits-w", dest="w_bits", type=int,
                    default=4)
    ap.add_argument("--a-bits", "--bits-a", dest="a_bits", type=int,
                    default=4)
    ap.add_argument("--kv-bits", type=int, default=8,
                    help="KV-cache quant bits (0 = fp cache)")
    ap.add_argument("--mesh", default="",
                    help="dp,tp device mesh (axes data,model) for "
                         "tensor-parallel serving, e.g. --mesh 1,4")
    ap.add_argument("--paged", action="store_true",
                    help="serve from the paged KV pool (lazy per-page "
                         "allocation) instead of the slot cache")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (must be a multiple of the "
                         "KV quant scale group; needs --paged)")
    ap.add_argument("--prefill-chunk", type=int, default=0,
                    help="feed prompts through prefill in fixed chunks of "
                         "this many tokens — ONE prefill compile total "
                         "(multiple of --page-size; needs --paged); in "
                         "unified mode, a cap on per-step prefill chunks")
    ap.add_argument("--schedule", default="legacy",
                    choices=["legacy", "unified"],
                    help="unified: pack decode tokens + prefill chunks "
                         "into one token-budgeted ragged step per cycle "
                         "(implies the paged KV pool)")
    ap.add_argument("--max-batch-tokens", type=int, default=0,
                    help="unified-schedule token budget per step "
                         "(>= --n-slots; default 2×slots)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share cached prompt-prefix pages across "
                         "requests (refcounted, copy-on-write) and skip "
                         "their prefill — needs --paged or --schedule "
                         "unified")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many common system-prompt tokens "
                         "to every request (the workload --prefix-cache "
                         "hits on; implies the mixed workload)")
    ap.add_argument("--speculative", type=int, default=0, metavar="K",
                    help="draft K tokens per slot per cycle with the "
                         "int4-packed quantization of the same checkpoint "
                         "and verify all K+1 positions in one ragged "
                         "target step (greedy acceptance — output stays "
                         "token-identical; needs --schedule unified)")
    ap.add_argument("--adaptive-spec", action="store_true",
                    help="lower each slot's per-cycle draft depth toward "
                         "its running acceptance rate (K stays the hard "
                         "cap; needs --speculative)")
    ap.add_argument("--pipeline", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="depth-1 asynchronous unified loop: pack + "
                         "dispatch step N+1 while N runs on device "
                         "(token-identical; default ON for --schedule "
                         "unified unless REPRO_SYNC_STEP is set); "
                         "--no-pipeline forces the synchronous loop with "
                         "blocked per-step timing spans")
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    validate_flags(ap, args)
    out = serve_benchmark(arch=args.arch, batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen,
                          transform=args.transform, w_bits=args.w_bits,
                          a_bits=args.a_bits, smoke=not args.full_config,
                          kv_bits=args.kv_bits, n_requests=args.requests,
                          mixed=args.mixed, mesh=parse_mesh(args.mesh),
                          paged=args.paged, page_size=args.page_size,
                          prefill_chunk=args.prefill_chunk,
                          schedule=args.schedule,
                          max_batch_tokens=args.max_batch_tokens,
                          prefix_cache=args.prefix_cache,
                          shared_prefix=args.shared_prefix,
                          speculative=args.speculative,
                          adaptive_spec=args.adaptive_spec,
                          pipeline=args.pipeline)
    eng = out["engine"]
    mesh_note = (f", mesh={eng['mesh']}" if eng.get("mesh") else "")
    sched_note = ""
    if eng.get("schedule") == "unified":
        pipe = (f", pipelined {eng['overlap_frac']:.0%} overlap"
                if eng.get("pipeline") else ", sync")
        sched_note = (f", unified[{eng['max_batch_tokens']}t budget, "
                      f"itl p95 {eng['itl_p95_s'] * 1e3:.0f}ms{pipe}]")
    spec_note = ""
    if eng.get("speculative_k"):
        adapt = ", adaptive" if eng.get("adaptive_spec") else ""
        spec_note = (f", spec[k={eng['speculative_k']}{adapt}, "
                     f"{eng['spec_acceptance_rate']:.0%} accepted, "
                     f"{eng['spec_drafted_tokens']}t drafted]")
    prefix_note = ""
    if eng.get("prefix_cache"):
        prefix_note = (f", prefix[{eng['prefix_hit_rate']:.0%} hit, "
                       f"{eng['prefix_hit_tokens']}t prefill skipped, "
                       f"{eng['cow_copies']} cow]")
    # KV footprint in BOTH modes (slot-vs-paged rows compare like for
    # like): paged resident bytes track live pages, the slot cache
    # reserves its full capacity up front
    kv_note = (f", paged[{eng['page_size']}t/page, "
               f"{eng['resident_kv_bytes_mean'] / 2**10:.0f}KiB "
               f"resident vs {eng['kv_capacity_bytes'] / 2**10:.0f}"
               f"KiB slot-equivalent]") if eng.get("paged") else (
               f", slot[{eng['resident_kv_bytes_mean'] / 2**10:.0f}KiB "
               f"resident = capacity]")
    print(f"{out['arch']} [{out['transform']}]: "
          f"{out['tok_per_s']:.1f} tok/s ({out['wall_s']:.2f}s wall) | "
          f"{eng['n_requests']} reqs on {eng['n_slots']} slots, "
          f"ttft {eng['ttft_s_mean'] * 1e3:.0f}ms, "
          f"occupancy {eng['occupancy_mean']:.2f}, "
          f"kv={'int8' if eng['quantized_kv'] else 'fp'}"
          f"{kv_note}{spec_note}{prefix_note}{sched_note}{mesh_note}")
    if out.get("qlinear_layers"):
        kind = "int4-packed" if out["packed_int4"] else "int8"
        print(f"  weights: {out['weight_bytes'] / 2**20:.2f} MiB across "
              f"{out['qlinear_layers']} quantized linears ({kind})")


if __name__ == "__main__":
    main()
