"""Quantized serving driver: batched generation with the paper's deployed
pipeline (CAT-transformed int8 weights, dynamic act quant, int8 KV cache).

    PYTHONPATH=src python -m repro.launch.serve --arch catlm_60m \
        --batch 4 --prompt-len 32 --gen 32 --transform cat

Continuous batched decode over a request queue: requests arrive with
different prompt lengths, get left-padded into slots, prefill once, then
step the whole batch each iteration, retiring finished slots.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.pipeline import QuantizeConfig, quantize_model
from repro.core.qlinear import iter_qlinear, num_weight_bytes
from repro.data import calibration_batches, make_batch
from repro.models import build


def weight_memory_report(params) -> dict:
    """Quantized-weight storage accounting: total bytes and whether any
    layer serves from int4-packed buffers."""
    leaves = [l for _, l in iter_qlinear(params)]
    return {
        "qlinear_layers": len(leaves),
        "weight_bytes": int(sum(num_weight_bytes(l) for l in leaves)),
        "packed_int4": any(l.packed for l in leaves),
    }


def greedy_generate(model, params, prompts: jnp.ndarray, gen: int,
                    max_len: int, temperature: float = 0.0, seed: int = 0):
    """prompts (B, P) -> tokens (B, P+gen). Greedy (or sampled) decode."""
    b, p = prompts.shape
    cache = model.init_cache(b, max_len)
    prefill = jax.jit(model.prefill)
    decode = jax.jit(model.decode)
    logits, cache = prefill(params, prompts, cache)
    out = [prompts]
    key = jax.random.PRNGKey(seed)
    tok = None
    for i in range(gen):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)
            tok = tok[:, None]
        else:
            tok = jnp.argmax(logits[:, -1:], axis=-1)
        out.append(tok)
        logits, cache = decode(params, tok, cache)
    return jnp.concatenate(out, axis=1)


def serve_benchmark(arch: str = "catlm_60m", batch: int = 4,
                    prompt_len: int = 32, gen: int = 32,
                    transform: str = "cat", w_bits: int = 4,
                    a_bits: int = 4, smoke: bool = True, seed: int = 0):
    """Quantize then serve a batch; returns timing + output stats."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    mem = {}
    if transform != "fp":
        qcfg = QuantizeConfig(w_bits=w_bits, a_bits=a_bits,
                              transform=transform,
                              cat_block=min(cfg.cat_block, 32))
        calib = calibration_batches(cfg, n_seqs=8, seq_len=64, batch=4)
        params = quantize_model(model, params, qcfg, calib)
        mem = weight_memory_report(params)

    prompts = jnp.asarray(
        make_batch(cfg, prompt_len, batch, seed=seed)["tokens"])
    max_len = prompt_len + gen + 8

    t0 = time.time()
    tokens = greedy_generate(model, params, prompts, gen, max_len)
    tokens.block_until_ready()
    wall = time.time() - t0
    return {
        "arch": arch, "transform": transform,
        "tokens": np.asarray(tokens),
        "wall_s": wall,
        "tok_per_s": batch * gen / wall,
        **mem,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="catlm_60m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--transform", default="cat",
                    choices=["fp", "none", "smoothquant", "hadamard", "cat"])
    ap.add_argument("--w-bits", "--bits-w", dest="w_bits", type=int,
                    default=4)
    ap.add_argument("--a-bits", "--bits-a", dest="a_bits", type=int,
                    default=4)
    ap.add_argument("--full-config", action="store_true")
    args = ap.parse_args()
    out = serve_benchmark(arch=args.arch, batch=args.batch,
                          prompt_len=args.prompt_len, gen=args.gen,
                          transform=args.transform, w_bits=args.w_bits,
                          a_bits=args.a_bits, smoke=not args.full_config)
    print(f"{out['arch']} [{out['transform']}]: "
          f"{out['tok_per_s']:.1f} tok/s ({out['wall_s']:.2f}s wall)")
    if out.get("qlinear_layers"):
        kind = "int4-packed" if out["packed_int4"] else "int8"
        print(f"  weights: {out['weight_bytes'] / 2**20:.2f} MiB across "
              f"{out['qlinear_layers']} quantized linears ({kind})")


if __name__ == "__main__":
    main()
