"""Cell matrix for the multi-pod dry-run: (architecture × input shape) ->
step function + ShapeDtypeStruct stand-ins + shardings.

Shapes (per assignment):
  train_4k     seq 4096,   global_batch 256   (train_step)
  prefill_32k  seq 32768,  global_batch 32    (serve: prefill)
  decode_32k   ctx 32768,  global_batch 128   (serve: one decode step)
  long_500k    ctx 524288, global_batch 1     (decode; sub-quadratic only)

Serve cells lower the QUANTIZED deployment: int4-packed weight codes
(two nibbles per int8 byte along d_in — half the int8 buffer bytes) +
online CAT transforms + dynamic act quant + int8 KV cache (the paper's
W4A4+KV setup). Train cells lower bf16 params + f32 ZeRO-sharded
AdamW-master state, remat + Megatron-SP activations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import transforms as T
from repro.core.hadamard import hadamard_factors
from repro.core.pipeline import GroupSpec, layer_groups, shared_groups
from repro.core.qlinear import QLinear
from repro.distributed.sharding import (batch_sharding, cache_sharding,
                                        params_sharding, zero_opt_sharding)
from repro.models import build
from repro.optim.optimizer import AdamWMaster

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32_768, batch=32),
    "decode_32k": dict(kind="decode", seq=32_768, batch=128),
    "long_500k": dict(kind="decode", seq=524_288, batch=1),
}

ARCHS = ["gemma2_2b", "mistral_nemo_12b", "granite_34b", "gemma3_12b",
         "zamba2_7b", "whisper_small", "rwkv6_7b", "granite_moe_1b_a400m",
         "moonshot_v1_16b_a3b", "paligemma_3b"]


def cell_runnable(arch: str, shape: str):
    """-> (runnable, reason-if-skipped). See DESIGN.md §5."""
    cfg = get_config(arch)
    if shape == "long_500k" and not cfg.sub_quadratic:
        return False, ("full-attention arch: 500k decode requires "
                       "sub-quadratic attention (DESIGN.md §5 skip)")
    return True, ""


def cell_config(arch: str, shape: str, *, act_shard: str = "seq",
                remat: bool = True, kv_bits: int = 8,
                n_layers: Optional[int] = None):
    cfg = get_config(arch)
    kind = SHAPES[shape]["kind"]
    over = {}
    if kind == "train":
        over.update(remat=remat, act_shard=act_shard)
    else:
        if cfg.family in ("dense", "moe", "vlm"):
            over.update(kv_quant_bits=kv_bits)
    if n_layers is not None:
        over["n_layers"] = n_layers
        if cfg.family == "encdec":
            over["n_enc_layers"] = n_layers
    return cfg.scaled(**over)


def layer_period(cfg) -> int:
    """Smallest structure-preserving layer count (for L/2L roofline
    extrapolation)."""
    if cfg.family == "hybrid":
        return cfg.attn_every
    if cfg.local_ratio:
        return cfg.local_ratio + 1
    return 1


# --------------------------------------------------- abstract params (SDS)

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(int(x) for x in shape), dtype)


def abstract_params(cfg, quantized: bool):
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if not quantized:
        # train: bf16 working params
        return jax.tree.map(
            lambda l: _sds(l.shape, jnp.bfloat16
                           if l.dtype in (jnp.float32, jnp.bfloat16)
                           else l.dtype), shapes)
    return _quantized_abstract(cfg, shapes)


def _abstract_transform(d: int, k: int, stack: tuple = ()):
    k = max(j for j in range(1, min(k, d) + 1) if d % j == 0)
    n = d // k
    fa, fb = hadamard_factors(d)
    a, b = fa.shape[0], fb.shape[0]
    if k == 1:
        mt = T.Scale(_sds(stack + (d,), jnp.float32))
    else:
        mt = T.BlockDiag(_sds(stack + (n, k, k), jnp.float32),
                         _sds(stack + (n, k, k), jnp.float32))
    had = T.Hadamard(_sds(stack + (a, a), jnp.float32),
                     _sds(stack + (b, b), jnp.float32),
                     _sds(stack + (d,), jnp.float32))
    return T.Compose((mt, had))


def _quantized_abstract(cfg, shapes):
    """Mirror pipeline.quantize_model structurally with SDS leaves."""
    out = jax.tree.map(
        lambda l: _sds(l.shape, jnp.bfloat16
                       if l.dtype in (jnp.float32, jnp.bfloat16)
                       else l.dtype), shapes)

    def q_leaf(leaf, stack):
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        lead = leaf.shape[:-2]
        # W4 serving default: nibble-packed codes (two int4 per int8 byte)
        return QLinear(
            _sds(lead + ((d_in + 1) // 2, d_out), jnp.int8),
            _sds(lead + (1, d_out), jnp.float32),
            _abstract_transform(d_in, cfg.cat_block, stack),
            act_bits=4, w_bits=4, d_in=d_in)

    def convert(scope_name, groups, stacked: bool):
        scope = out.get(scope_name)
        if scope is None:
            return
        for g in groups:
            for name in g.weights:
                if name not in scope:
                    continue
                leaf = scope[name]
                stack = (leaf.shape[0],) if stacked else ()
                scope[name] = q_leaf(leaf, stack)

    convert("layers", [g for g in layer_groups(cfg) if g.scope == "layers"],
            True)
    if cfg.family == "hybrid":
        convert("mamba", [g for g in layer_groups(cfg)
                          if g.scope == "mamba"], True)
        convert("shared_attn", shared_groups(cfg), False)
    if cfg.family == "encdec":
        convert("enc_layers",
                [GroupSpec("attn_in", ("wq", "wk", "wv"), "enc_layers"),
                 GroupSpec("mlp_in", ("wg", "wu"), "enc_layers"),
                 GroupSpec("down_in", ("wd",), "enc_layers")], True)
    return out


# ----------------------------------------------------------- cell builder

@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    cfg: object
    step_fn: object          # callable(*args)
    args_sds: tuple
    in_shardings: tuple
    donate: tuple


def build_cell(arch: str, shape: str, mesh, *, n_layers=None,
               act_shard="seq", remat=True, kv_bits=8,
               quantized_serve=True) -> Cell:
    info = SHAPES[shape]
    cfg = cell_config(arch, shape, act_shard=act_shard, remat=remat,
                      kv_bits=kv_bits, n_layers=n_layers)
    model = build(cfg)
    kind = info["kind"]
    B, S = info["batch"], info["seq"]

    def batch_sds(seq, batch):
        d: dict = {"tokens": _sds((batch, seq), jnp.int32),
                   "labels": _sds((batch, seq), jnp.int32)}
        if cfg.family == "encdec":
            d["enc_embed"] = _sds((batch, cfg.enc_seq, cfg.d_model),
                                  jnp.bfloat16)
        if cfg.family == "vlm":
            d["patch_embed"] = _sds((batch, cfg.n_patches, cfg.d_model),
                                    jnp.bfloat16)
        return d

    if kind == "train":
        params = abstract_params(cfg, quantized=False)
        opt = AdamWMaster(lr=1e-4)
        opt_sds = jax.eval_shape(opt.init, params)
        batch = batch_sds(S, B)
        p_sh = params_sharding(params, mesh)
        o_sh = zero_opt_sharding(p_sh, opt_sds, mesh)
        b_sh = batch_sharding(batch, mesh)

        def train_step(params, opt_state, batch):
            def loss_fn(p):
                l, metrics = model.loss(p, batch)
                return l, metrics
            (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params)
            params, opt_state = opt.update(params, grads, opt_state)
            return params, opt_state, dict(metrics, loss=l)

        return Cell(arch, shape, cfg, train_step,
                    (params, opt_sds, batch), (p_sh, o_sh, b_sh), (0, 1))

    params = abstract_params(cfg, quantized=quantized_serve)
    p_sh = params_sharding(params, mesh)

    cache_len = S + (cfg.n_patches or 0)  # vlm: patches occupy cache slots
    if kind == "prefill":
        cache_sds = jax.eval_shape(lambda: model.init_cache(B, cache_len))
        c_sh = cache_sharding(cache_sds, mesh)
        tokens = _sds((B, S), jnp.int32)
        t_sh = batch_sharding(tokens, mesh)
        kw_sds, kw_sh = {}, {}
        if cfg.family == "encdec":
            kw_sds["enc_embed"] = _sds((B, cfg.enc_seq, cfg.d_model),
                                       jnp.bfloat16)
            kw_sh["enc_embed"] = batch_sharding(kw_sds["enc_embed"], mesh)
        if cfg.family == "vlm":
            kw_sds["extra_embed"] = _sds((B, cfg.n_patches, cfg.d_model),
                                         jnp.bfloat16)
            kw_sh["extra_embed"] = batch_sharding(kw_sds["extra_embed"], mesh)

        if kw_sds:
            names = tuple(sorted(kw_sds))

            def prefill_step(params, tokens, cache, extra):
                return model.prefill(params, tokens, cache,
                                     **dict(zip(names, extra)))

            extra_sds = tuple(kw_sds[n] for n in names)
            extra_sh = tuple(kw_sh[n] for n in names)
            return Cell(arch, shape, cfg, prefill_step,
                        (params, tokens, cache_sds, extra_sds),
                        (p_sh, t_sh, c_sh, extra_sh), (2,))

        def prefill_step(params, tokens, cache):
            return model.prefill(params, tokens, cache)

        return Cell(arch, shape, cfg, prefill_step,
                    (params, tokens, cache_sds), (p_sh, t_sh, c_sh), (2,))

    # decode: one token with a full cache of length S
    cache_sds = jax.eval_shape(lambda: model.init_cache(B, cache_len))
    c_sh = cache_sharding(cache_sds, mesh)
    token = _sds((B, 1), jnp.int32)
    t_sh = batch_sharding(token, mesh)

    def decode_step(params, token, cache):
        return model.decode(params, token, cache)

    return Cell(arch, shape, cfg, decode_step,
                (params, token, cache_sds), (p_sh, t_sh, c_sh), (2,))
