import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape ×
mesh) cell against the production meshes, record memory / cost /
collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma2_2b \
        --shape train_4k --mesh single

Results accumulate in results/dryrun.json (one entry per cell × mesh);
benchmarks/roofline_report.py reads that file.

NOTE the XLA_FLAGS line above MUST precede every other import (jax locks
the device count on first init); this module is the ONLY place the 512
fake host devices exist — tests and benches see one device.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.distributed.act_sharding import active_mesh  # noqa: E402
from repro.distributed.compat import set_mesh  # noqa: E402
from repro.launch.hlo_analysis import collective_bytes, cost_summary  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.specs import (ARCHS, SHAPES, build_cell,  # noqa: E402
                                cell_runnable, layer_period)


def run_cell(arch: str, shape: str, multi_pod: bool, *, n_layers=None,
             act_shard="seq", remat=True, kv_bits=8, quantized=True,
             save_hlo=None, exact_cost=False) -> dict:
    import contextlib
    from repro.models.flags import exact_cost_mode
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    cell = build_cell(arch, shape, mesh, n_layers=n_layers,
                      act_shard=act_shard, remat=remat, kv_bits=kv_bits,
                      quantized_serve=quantized)
    cost_ctx = exact_cost_mode() if exact_cost else contextlib.nullcontext()
    with set_mesh(mesh), active_mesh(mesh), cost_ctx:
        jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                         donate_argnums=cell.donate)
        lowered = jitted.lower(*cell.args_sds)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    summary = cost_summary(compiled)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    return {
        "arch": arch, "shape": shape,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_layers": n_layers or cell.cfg.n_layers,
        "layer_period": layer_period(cell.cfg),
        "lower_s": round(t1 - t0, 2), "compile_s": round(t2 - t1, 2),
        "collective_bytes": coll,
        **summary,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true",
                    help="run every (arch × shape × mesh) cell")
    ap.add_argument("--act-shard", default="seq", choices=["seq", "none"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=8)
    ap.add_argument("--fp-serve", action="store_true",
                    help="serve cells with bf16 weights (baseline compare)")
    ap.add_argument("--n-layers", type=int, default=None)
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--save-hlo", default=None)
    ap.add_argument("--tag", default=None,
                    help="suffix for the result key (perf iterations)")
    args = ap.parse_args()

    cells = []
    if args.all:
        for arch in ARCHS:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    n_ok = n_skip = n_fail = 0
    for arch, shape in cells:
        runnable, reason = cell_runnable(arch, shape)
        for multi in meshes:
            key = f"{arch}|{shape}|{'multi' if multi else 'single'}"
            if args.tag:
                key += f"|{args.tag}"
            if not runnable:
                results[key] = {"arch": arch, "shape": shape,
                                "skip": reason}
                n_skip += 1
                print(f"SKIP {key}: {reason}", flush=True)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1, sort_keys=True)
                continue
            try:
                rec = run_cell(arch, shape, multi,
                               n_layers=args.n_layers,
                               act_shard=args.act_shard,
                               remat=not args.no_remat,
                               kv_bits=args.kv_bits,
                               quantized=not args.fp_serve,
                               save_hlo=args.save_hlo)
                results[key] = rec
                n_ok += 1
                mem = rec["memory"]
                per_dev_gb = (mem.get("argument_size_in_bytes", 0)
                              + mem.get("temp_size_in_bytes", 0)) / 2**30
                print(f"OK   {key}: compile={rec['compile_s']}s "
                      f"flops={rec['flops']:.3g} "
                      f"coll={rec['collective_bytes'].get('total', 0):.3g}B "
                      f"mem/dev={per_dev_gb:.2f}GiB", flush=True)
            except Exception as e:  # noqa: BLE001 — record & continue
                n_fail += 1
                results[key] = {"arch": arch, "shape": shape,
                                "error": f"{type(e).__name__}: {e}"}
                print(f"FAIL {key}: {type(e).__name__}: {e}", flush=True)
                traceback.print_exc()
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1, sort_keys=True)

    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_fail} failed "
          f"-> {args.out}", flush=True)
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
