"""Production mesh construction.

Single pod: (16, 16) = 256 chips (data × model).
Multi-pod:  (2, 16, 16) = 512 chips (pod × data × model); the pod axis is
pure DP across the DCI.

A FUNCTION, not a module constant — importing this module never touches
jax device state (dry-run hygiene).
"""
from __future__ import annotations

import math

import jax

from repro.distributed import compat


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devs = jax.devices()
    assert len(devs) >= n, (
        f"need {n} devices for mesh {shape}; have {len(devs)} — run under "
        "launch/dryrun.py (it forces 512 host devices) or a real cluster")
    return compat.make_mesh(shape, axes, devices=devs[:n])


def make_mesh(shape, axes):
    n = math.prod(shape)
    return compat.make_mesh(shape, axes, devices=jax.devices()[:n])
