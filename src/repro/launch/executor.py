"""Device side of the serve stack: jitted/shard_mapped prefill, decode,
and the unified ragged step, with donated caches.

The engine façade (``repro.launch.engine``) keeps all host-side policy
(queues, slots, budgets — see ``repro.launch.scheduler``); everything
that touches a jax array lives here:

- ``LegacyExecutor`` — the prefill-on-admit + batched-decode pair the
  engine has always dispatched: fused single-dispatch slot prefill,
  paged prefill spans with donated pools, one batched decode step, and
  the tensor-parallel shard_map variants of each.
- ``RaggedExecutor`` — the unified token-budget step: ONE jitted (or
  shard_mapped) invocation per engine step that runs the flat packed
  (T, 1) token batch — decode rows and prefill-chunk rows together —
  against the paged KV pool with per-token positions and page-table
  rows, returning logits only at the packed rows the scheduler marked
  (``models.dense.ragged_step``). The cache is donated, so pools update
  in place on donation-capable backends.

Both executors own ``params`` and ``cache`` (device_put with the
quantization-aware shardings from ``distributed.sharding`` in mesh mode)
and expose small host-facing methods taking/returning numpy.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np


# ------------------------------------------------------------- jit helpers

@functools.lru_cache(maxsize=8)
def jitted_model_fns(model):
    """(jit prefill, jit decode) cached per model so repeated engine /
    oracle runs over the same model share compilations."""
    return jax.jit(model.prefill), jax.jit(model.decode)


@functools.lru_cache(maxsize=8)
def jitted_paged_fns(model, paged_kernel: bool):
    """Paged-serving (jit prefill, jit decode) — cached per (model,
    kernel flag) like ``jitted_model_fns``, so rebuilding an engine over
    the same model (benchmark variants, warmup/steady re-runs) reuses
    compilations instead of re-tracing per engine instance. The global
    pool round-trips through every call, so the cache arg is donated."""
    prefill = jax.jit(model.prefill, donate_argnums=(2,))
    dec = (lambda p, t, c: model.decode(p, t, c, paged_kernel=True)
           ) if paged_kernel else model.decode
    return prefill, jax.jit(dec, donate_argnums=(2,))


@jax.jit
def _take_slot(cache, slot):
    """Slice one slot's batch-1 cache out of the shared (L, n_slots, ...)
    arrays (leaf layout: layer axis 0, slot axis 1)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)


# Donating the shared cache lets XLA write the slot rows in place on
# backends with buffer donation (TPU); CPU falls back to a copy.
@functools.partial(jax.jit, donate_argnums=(0,))
def _put_slot(cache, part, slot):
    return jax.tree.map(
        lambda a, p: jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1),
        cache, part)


# Single-device admissions run take -> prefill -> put as ONE jitted
# program: the slot's rows are sliced, prefilled, and written back without
# the per-slot part ever surfacing as separate host-boundary buffers
# between three dispatches (the old take/prefill/put ping-pong). The
# shared cache is donated so XLA can update the slot rows in place.
# ``prefill_fn`` is static (one compile per model × token shape).
@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _prefill_slot_fused(prefill_fn, params, cache, tokens, slot, logits_at):
    part = jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)
    logits, part = prefill_fn(params, tokens, dict(part, pos=jnp.int32(0)),
                              logits_at=logits_at)
    part.pop("pos")
    cache = jax.tree.map(
        lambda a, p: jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1),
        cache, part)
    return logits, cache


# Device-side token injection (pipelined serving): a packed row whose fed
# token was still in flight at pack time carries ``tok_src[i] >= 0`` — the
# index of its true token inside the PREVIOUS step's device-resident
# (W,) token vector. The substitution runs inside the jitted step, so the
# host never has to wait for step N's tokens to pack and dispatch step
# N+1. ``tok_src = -1`` rows keep their host-packed token (sync mode
# passes prev_toks=None and skips the gather entirely).
def _inject_prev(tokens, prev_toks, tok_src):
    if prev_toks is None:
        return tokens
    fetched = jnp.take(prev_toks, jnp.maximum(tok_src, 0), axis=0)
    return jnp.where(tok_src[:, None] >= 0, fetched[:, None], tokens)


# The whole unified step is one jitted program: scatter-write every packed
# token's k/v, attend, and read logits (or, with ``greedy``, their argmax
# token ids — device-resident sampling) at the scheduler-marked rows. The
# cache (the global paged pools) is donated for in-place pool updates;
# ``step_fn`` (``model.ragged_step``), the kernel flag, and ``greedy``
# are static.
#
# Each donated wrapper also has an ``_async`` twin WITHOUT donation:
# XLA:CPU dispatches donated computations synchronously (the whole step
# executes inline in the dispatching thread), which would re-serialize
# the pipelined loop — a pipelined executor on the CPU backend therefore
# trades the in-place cache update for asynchronous dispatch (the pool
# round-trips through a fresh output buffer; see RaggedExecutor(donate=)).
def _unified_step_impl(step_fn, paged_kernel, greedy, params, cache,
                       tokens, pos, page_table, logit_rows, ragged_desc,
                       prev_toks, tok_src):
    tokens = _inject_prev(tokens, prev_toks, tok_src)
    cache = dict(cache, pos=pos, page_table=page_table)
    out, cache = step_fn(params, tokens, cache, logit_rows,
                         paged_kernel=paged_kernel, greedy=greedy,
                         ragged_desc=ragged_desc)
    cache.pop("pos")
    cache.pop("page_table")
    return out, cache


_unified_step = functools.partial(
    jax.jit, static_argnums=(0, 1, 2),
    donate_argnums=(4,))(_unified_step_impl)
_unified_step_async = functools.partial(
    jax.jit, static_argnums=(0, 1, 2))(_unified_step_impl)


# Pure-decode fast path: when a unified plan is decode-only (every packed
# row has q_len 1 — no prefill chunks, no speculative verify items, no COW
# copies), the ragged machinery buys nothing: the step IS a batched decode.
# Dispatching it as ``model.decode`` instead lets the layer body take the
# two-launch fused path (``models.dense._fused_decode_attn``: QKV-prologue
# kernel + paged attention, no XLA glue between them) on TPU, and is
# bitwise identical to the ragged step's decode rows everywhere (same
# per-row numerics — the property the unified/legacy golden fixtures pin).
# ``decode_fn`` is static; the cache (the global paged pools) is donated.
# Returns the (n_slots,) greedy token ids (device-resident sampling —
# argmax in the same program, only int32 tokens cross D2H).
def _fused_decode_step_impl(decode_fn, params, cache, tokens, pos, table,
                            prev_toks, tok_src):
    tokens = _inject_prev(tokens, prev_toks, tok_src)
    cache = dict(cache, pos=pos, page_table=table)
    logits, cache = decode_fn(params, tokens, cache)
    cache.pop("pos")
    cache.pop("page_table")
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32), cache


_fused_decode_step = functools.partial(
    jax.jit, static_argnums=(0,),
    donate_argnums=(2,))(_fused_decode_step_impl)
_fused_decode_step_async = functools.partial(
    jax.jit, static_argnums=(0,))(_fused_decode_step_impl)


# Speculative draft pass: ONE jitted dispatch runs n_steps greedy decode
# steps of the draft model over its paged pool — lax.scan with on-device
# argmax between steps, so proposing k tokens costs one host round trip
# instead of k (the whole point on a dispatch-overhead-bound host).
# ``decode_fn`` (draft model.decode) and the step count are static; the
# draft cache is donated for in-place pool updates. Each scan iteration
# feeds the previous argmax at the next position; ``forward`` advances
# ``pos`` by 1 per step and threads ``page_table`` through the carry.
# Returns all n_steps proposed tokens (n_steps, B) — callers use the
# first k as drafts (the extra step exists so a fully-accepted block's
# bonus token leaves no draft-KV hole at pos0+k).
def _draft_scan_impl(decode_fn, n_steps, params, cache, tok0, pos0, table,
                     prev_toks, tok_src):
    tok0 = _inject_prev(tok0, prev_toks, tok_src)
    cache = dict(cache, pos=pos0, page_table=table)

    def body(carry, _):
        tok, cache = carry
        logits, cache = decode_fn(params, tok, cache)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return (nxt[:, None], cache), nxt

    (_, cache), drafts = jax.lax.scan(body, (tok0, cache), None,
                                      length=n_steps)
    cache.pop("pos")
    cache.pop("page_table")
    return drafts, cache


_draft_scan = functools.partial(
    jax.jit, static_argnums=(0, 1), donate_argnums=(3,))(_draft_scan_impl)
_draft_scan_async = functools.partial(
    jax.jit, static_argnums=(0, 1))(_draft_scan_impl)


# Greedy sampling for the legacy batched decode: argmax on device so
# only (n_slots,) int32 tokens cross D2H instead of the full (n_slots,
# 1, V) logits tensor (which used to be copied inside the timed device
# span and charged to compute).
@jax.jit
def _greedy_rows(logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


# COW page copy (prefix caching): duplicate src pages' rows into dst
# pages across every pool leaf before the step that writes the divergent
# rows. ``copy_fn`` (model.copy_paged_pages) is static; the cache is
# donated so the copy is in place on donation-capable backends. Pairs
# are padded with (0, 0) null-page self-copies (inert) to ONE fixed
# width — pow-2 ceil of n_slots, the most COW splits a single plan can
# carry — so the copy compiles exactly once per engine and never traces
# inside a timed pass. Under a mesh the pools arrive sharded (heads on
# "model", page axis whole) and jit partitions the per-page
# gather/scatter over the head shards.
def _copy_pages_impl(copy_fn, cache, src, dst):
    return copy_fn(cache, src, dst)


_copy_pages = functools.partial(
    jax.jit, static_argnums=(0,), donate_argnums=(1,))(_copy_pages_impl)
_copy_pages_async = functools.partial(
    jax.jit, static_argnums=(0,))(_copy_pages_impl)


class _CopyPagesMixin:
    """Host-facing COW dispatch shared by both executors."""

    def copy_pages(self, pairs) -> None:
        """Device-copy each (src, dst) page pair in ONE dispatch (issued
        strictly before the step/prefill that writes past the shared
        boundary — dispatch order is device order on a stream, so even a
        src freed and reallocated within the same plan is read before
        its new owner writes it)."""
        if not pairs:
            return
        copy_fn = self.model.copy_paged_pages
        if copy_fn is None:
            raise NotImplementedError(
                f"family {getattr(self.model.cfg, 'family', '?')!r} has "
                f"no paged-pool page copy (copy_paged_pages)")
        self.n_dispatch += 1
        width = 1 << (max(len(pairs), self.n_slots) - 1).bit_length()
        src = np.zeros((width,), np.int32)
        dst = np.zeros((width,), np.int32)
        for i, (s, d) in enumerate(pairs):
            src[i], dst[i] = s, d
        fn = (_copy_pages if getattr(self, "_donate", True)
              else _copy_pages_async)
        self.cache = fn(copy_fn, self.cache, jnp.asarray(src),
                        jnp.asarray(dst))


# ------------------------------------------------- shared mesh validation

def _validate_tp(cfg, mesh, tp_axis: str, tp_mode: str, params) -> int:
    """Shared tensor-parallel admissibility checks (whole heads per
    shard; int4-packed row shards hold whole bytes). Returns tp size."""
    from repro.core.qlinear import iter_qlinear

    if cfg.n_experts:
        raise NotImplementedError("mesh serving covers the dense "
                                  "(non-MoE) family")
    tp = mesh.shape[tp_axis]
    packed = any(l.packed for _, l in iter_qlinear(params))
    unit = 2 * tp if (packed and tp_mode == "psum") else tp
    for dim, name in ((cfg.n_heads, "n_heads"),
                      (cfg.n_kv_heads, "n_kv_heads")):
        if dim % tp:
            raise ValueError(
                f"{name}={dim} must divide by {tp_axis}={tp} (whole "
                f"heads per shard)")
    for dim, name in ((cfg.q_dim, "q_dim"), (cfg.d_ff, "d_ff")):
        if dim % unit:
            raise ValueError(
                f"{name}={dim} must divide by {unit} "
                f"({tp_axis}={tp}"
                + (", ×2: int4-packed row shards hold whole bytes)"
                   if unit != tp else ")"))
    return tp


# --------------------------------------------------------- legacy executor

class LegacyExecutor(_CopyPagesMixin):
    """Prefill-on-admit + batched-decode dispatch (the engine's original
    device path, unchanged numerics — it stays the oracle the unified
    step is golden-tested against)."""

    def __init__(self, model, params, cache, *, n_slots: int,
                 paged: bool = False, paged_kernel: bool = False,
                 mesh=None, tp_axis: str = "model",
                 tp_mode: str = "gather", tp_kernels: bool = False):
        self.model, self.params, self.cache = model, params, cache
        self.paged, self.mesh = paged, mesh
        self.n_slots = n_slots
        self.n_dispatch = 0     # device calls issued (hot-loop accounting)
        self.d2h_s = 0.0        # token D2H seconds (attributed separately)
        if mesh is None:
            if paged:
                # paged prefill/decode round-trip the ENTIRE global pool
                # (not a batch-1 slot part), so the cache arg is donated —
                # in-place pool updates on donation-capable backends,
                # mirroring what _prefill_slot_fused does for slots
                self._prefill, self._decode = jitted_paged_fns(model,
                                                               paged_kernel)
            else:
                self._prefill, self._decode = jitted_model_fns(model)
        else:
            self._init_mesh_fns(mesh, tp_axis, tp_mode, tp_kernels,
                                paged_kernel)

    def _init_mesh_fns(self, mesh, tp_axis: str, tp_mode: str,
                       tp_kernels: bool, paged_kernel: bool) -> None:
        """Tensor-parallel serving: params and the shared slot KV cache
        are device_put with quantization-aware shardings
        (``distributed.sharding.tp_param_specs`` / ``tp_cache_specs``) and
        prefill/decode run the TP forward inside shard_map. Slot
        bookkeeping (queue, free list, positions) stays host-side in the
        engine and is identical to the single-device path; in
        ``tp_mode="gather"`` (default) the decoded tokens are
        bit-identical to it too."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed.compat import shard_map
        from repro.distributed import sharding as shlib

        cfg = self.model.cfg
        _validate_tp(cfg, mesh, tp_axis, tp_mode, self.params)
        dp_axis = next((a for a in ("data", "pod")
                        if a in mesh.axis_names
                        and self.n_slots % mesh.shape[a] == 0
                        and mesh.shape[a] > 1), None)
        if self.paged and dp_axis is not None:
            raise NotImplementedError(
                "paged mesh serving is tensor-parallel only: the page pool "
                "is a global (not per-slot) allocation, so its writes "
                "cannot shard over a data axis — use a (1, tp) mesh")

        pspecs = shlib.tp_param_specs(self.params, mesh, axis=tp_axis,
                                      cfg=cfg, row_mode=tp_mode)
        dec_cspecs = shlib.tp_cache_specs(self.cache, mesh, axis=tp_axis,
                                          dp_axis=dp_axis)
        if self.paged:
            # prefill sees the same global pool as decode (only the page
            # table narrows to the admitted slot's row)
            pre_cspecs = dec_cspecs
        else:
            part_shapes = jax.eval_shape(
                lambda c: jax.tree.map(lambda a: a[:, :1], c), self.cache)
            pre_cspecs = shlib.tp_cache_specs(part_shapes, mesh,
                                              axis=tp_axis)
        self.params = jax.device_put(self.params, shlib.named(pspecs, mesh))
        self.cache = jax.device_put(self.cache,
                                    shlib.named(dec_cspecs, mesh))
        tok_spec = P(dp_axis, None)
        # the (B,) per-slot position vector shards with the slot axis
        pos_spec = P(dp_axis) if dp_axis else P()
        tp_kw = dict(tp_axis=tp_axis, tp_mode=tp_mode, tp_kernels=tp_kernels)
        if self.paged:
            # page tables replicate (every shard gathers/scatters its own
            # head slice of the same physical pages)
            pt_spec = {"page_table": P(None, None)}
            pre_extra = dict(pt_spec, pos=P())
            dec_extra = dict(pt_spec, pos=pos_spec)
        else:
            pre_extra, dec_extra = {"pos": P()}, {"pos": pos_spec}
        model = self.model
        pk = paged_kernel

        def pre(p, t, c, la):
            return model.prefill(p, t, c, logits_at=la, **tp_kw)

        def dec(p, t, c):
            if pk:
                return model.decode(p, t, c, paged_kernel=True, **tp_kw)
            return model.decode(p, t, c, **tp_kw)

        self._prefill = jax.jit(shard_map(
            pre, mesh=mesh,
            in_specs=(pspecs, P(None, None), dict(pre_cspecs, **pre_extra),
                      P()),
            out_specs=(P(None, None, None), dict(pre_cspecs, **pre_extra)),
            check_vma=False))
        self._decode = jax.jit(shard_map(
            dec, mesh=mesh,
            in_specs=(pspecs, tok_spec, dict(dec_cspecs, **dec_extra)),
            out_specs=(P(dp_axis, None, None),
                       dict(dec_cspecs, **dec_extra)),
            check_vma=False))

    # ----------------------------------------------------------- dispatch

    def prefill_slot(self, toks: np.ndarray, slot: int, last: int):
        """Slot-cache prefill: fused take->prefill->put in one dispatch
        (single device) or explicit take/put around the shard_map'd
        forward (mesh). Returns the prefill logits."""
        self.n_dispatch += 1
        if self.mesh is None:
            logits, self.cache = _prefill_slot_fused(
                self.model.prefill, self.params, self.cache, toks[None],
                np.int32(slot), jnp.int32(last))
            return logits
        part = dict(_take_slot(self.cache, np.int32(slot)),
                    pos=jnp.int32(0))
        logits, part = self._prefill(self.params, toks[None], part,
                                     jnp.int32(last))
        part.pop("pos")
        self.cache = _put_slot(self.cache, part, np.int32(slot))
        return logits

    def prefill_paged_span(self, toks: np.ndarray, row, off: int,
                           last: int):
        """One paged prefill span at cache offset ``off`` against page
        table ``row`` (1, n_ptab). Returns (logits, rebound row) — the
        input row buffer was donated with the cache."""
        self.n_dispatch += 1
        cache = dict(self.cache, page_table=row, pos=jnp.int32(off))
        if self.mesh is None:
            logits, cache = self._prefill(self.params, toks[None], cache,
                                          logits_at=jnp.int32(last))
        else:
            logits, cache = self._prefill(self.params, toks[None], cache,
                                          jnp.int32(last))
        cache.pop("pos")
        row = cache.pop("page_table")
        self.cache = cache
        return logits, row

    def decode(self, toks: np.ndarray, pos: np.ndarray,
               table=None) -> np.ndarray:
        """One batched decode step over all slots; returns the greedy
        next token per slot as (n_slots,) int32 numpy. The argmax runs
        on device (``_greedy_rows``) so the D2H copy is n_slots ints,
        not the logits tensor; the copy itself is timed into ``d2h_s``
        (not the engine's device span — it is transfer, not compute).
        Blocks on the tokens so the engine's timed device span measures
        execution, not enqueue."""
        self.n_dispatch += 1
        cache = dict(self.cache, pos=jnp.asarray(pos))
        if table is not None:
            cache["page_table"] = jnp.asarray(table)
        logits, cache = self._decode(self.params, jnp.asarray(toks), cache)
        cache.pop("pos")
        cache.pop("page_table", None)
        self.cache = cache
        tokens = jax.block_until_ready(_greedy_rows(logits))
        td = time.perf_counter()
        out = np.asarray(tokens)
        self.d2h_s += time.perf_counter() - td
        return out


# --------------------------------------------------------- ragged executor

class RaggedExecutor(_CopyPagesMixin):
    """The unified token-budget step: one ragged model invocation per
    engine step over the flat packed token batch (see module docstring
    and ``scheduler.TokenBudgetScheduler.pack``)."""

    def __init__(self, model, params, cache, *, n_slots: int = 1,
                 paged_kernel: bool = False,
                 mesh=None, tp_axis: str = "model",
                 tp_mode: str = "gather", tp_kernels: bool = False,
                 draft=None, spec_k: int = 0, donate: bool = True):
        if model.ragged_step is None:
            raise NotImplementedError(
                f"family {getattr(model.cfg, 'family', '?')!r} has no "
                f"ragged (unified-step) forward")
        self.model, self.params, self.cache = model, params, cache
        # donate=False picks the non-donating executables so dispatch
        # stays asynchronous on XLA:CPU (which runs donated computations
        # inline) — the pipelined engine's requirement; costs one pool-
        # sized output buffer per step instead of the in-place update.
        # The shard_mapped mesh step is non-donating either way.
        self._donate = bool(donate)
        self.n_slots = n_slots
        self.paged_kernel = paged_kernel
        self.mesh = mesh
        self.n_dispatch = 0     # device calls issued (hot-loop accounting)
        self.d2h_s = 0.0        # token D2H seconds (engine resets it)
        # previous step's device-resident token vector (the injection
        # source for rows packed before their fed token was observed —
        # pipelined serving). None until the first step; chained by
        # step()/decode_step(). Widths coincide across the two step
        # kinds: the ragged vector is n_slots*(spec_k+1) wide and the
        # fused-decode vector n_slots wide, and the fast path only
        # engages at spec_k == 0.
        self._prev = None
        # speculative draft side: (model, params, cache) over a parallel
        # paged pool. Always plain-jit (never shard_mapped): only the
        # TARGET verify pass determines output tokens, so draft numerics
        # need determinism, not tp-identity — under a mesh the draft
        # runs replicated on the default device.
        self.spec_k = spec_k
        if draft is not None:
            self.draft_model, self.draft_params, self.draft_cache = draft
        else:
            self.draft_model = self.draft_params = self.draft_cache = None
        # pure-decode fast path (see _fused_decode_step): one stable
        # callable per executor so the jit cache keys on it once
        self._decode_fn = None
        if mesh is None and paged_kernel and model.decode is not None:
            self._decode_fn = (
                lambda p, t, c: model.decode(p, t, c, paged_kernel=True))
        if mesh is not None:
            self._init_mesh(mesh, tp_axis, tp_mode, tp_kernels)

    def _init_mesh(self, mesh, tp_axis: str, tp_mode: str,
                   tp_kernels: bool) -> None:
        """Unified step under shard_map: pools shard the head axis on
        ``model`` exactly as in legacy paged serving; the host-built
        descriptors (packed tokens, positions, page-table rows, logit
        rows, kernel query blocks) all replicate
        (``distributed.sharding.ragged_desc_specs``)."""
        from jax.sharding import PartitionSpec as P

        from repro.distributed.compat import shard_map
        from repro.distributed import sharding as shlib

        cfg = self.model.cfg
        _validate_tp(cfg, mesh, tp_axis, tp_mode, self.params)
        for a in ("data", "pod"):
            if a in mesh.axis_names and mesh.shape[a] > 1:
                raise NotImplementedError(
                    "unified serving is tensor-parallel only (the paged "
                    "pool is a global allocation) — use a (1, tp) mesh")
        pspecs = shlib.tp_param_specs(self.params, mesh, axis=tp_axis,
                                      cfg=cfg, row_mode=tp_mode)
        cspecs = shlib.tp_cache_specs(self.cache, mesh, axis=tp_axis)
        self.params = jax.device_put(self.params, shlib.named(pspecs, mesh))
        self.cache = jax.device_put(self.cache, shlib.named(cspecs, mesh))
        cdict = dict(cspecs, pos=P(None), page_table=P(None, None))
        model = self.model
        pk = self.paged_kernel
        tp_kw = dict(tp_axis=tp_axis, tp_mode=tp_mode, tp_kernels=tp_kernels)

        # the step returns the (R,) greedy token ids instead of logits:
        # the logits are replicated across the tp shards (tp_mode
        # "gather" materializes the full vocab row on every shard), so
        # the in-shard argmax is replicated too — device-resident
        # sampling with bitwise tp-identical tokens. prev_toks/tok_src
        # (pipelined token injection) replicate like the descriptors.
        if pk:
            desc_specs = shlib.ragged_desc_specs(
                {k: jax.ShapeDtypeStruct((1, 1), jnp.int32)
                 for k in ("qidx", "qpos", "table")}
                | {k: jax.ShapeDtypeStruct((1,), jnp.int32)
                   for k in ("lengths", "inv_seq", "inv_qi")})

            def rag(p, t, c, lr, rd, prev, src):
                t = _inject_prev(t, prev, src)
                return model.ragged_step(p, t, c, lr, paged_kernel=True,
                                         ragged_desc=rd, greedy=True,
                                         **tp_kw)

            in_specs = (pspecs, P(None, None), cdict, P(None), desc_specs,
                        P(None), P(None))
        else:
            def rag(p, t, c, lr, prev, src):
                t = _inject_prev(t, prev, src)
                return model.ragged_step(p, t, c, lr, greedy=True, **tp_kw)

            in_specs = (pspecs, P(None, None), cdict, P(None),
                        P(None), P(None))
        self._mesh_step = jax.jit(shard_map(
            rag, mesh=mesh, in_specs=in_specs,
            out_specs=(P(None), cdict), check_vma=False))

    def _prev_arr(self, width: int):
        """The previous step's device token vector (injection source),
        or inert zeros before the first step / after a reset."""
        if self._prev is None or self._prev.shape[0] != width:
            self._prev = jnp.zeros((width,), jnp.int32)
        return self._prev

    def reset_pipeline(self) -> None:
        """Forget the previous step's device tokens (engine reset):
        a fresh run must not inject a stale vector. Injection is already
        structurally dead for fresh sequences (tok_src = -1), so this is
        defense in depth."""
        self._prev = None

    def step(self, packed: dict):
        """Run one packed unified step; returns the greedy token ids at
        the packed logit rows as a DEVICE (R,) int32 array (only the
        first ``packed['n_logits']`` entries are real) — sampling runs
        inside the jitted step and the call does NOT block, so a
        pipelined caller can keep packing while the step executes.
        Synchronous callers block + ``np.asarray`` the result
        themselves."""
        self.n_dispatch += 1
        tokens = jnp.asarray(packed["tokens"])
        pos = jnp.asarray(packed["pos"])
        ptab = jnp.asarray(packed["page_table"])
        lrows = jnp.asarray(packed["logit_rows"])
        prev = self._prev_arr(lrows.shape[0])
        src = jnp.asarray(packed["tok_src"])
        desc = packed.get("ragged_desc")
        if desc is not None:
            desc = {k: jnp.asarray(v) for k, v in desc.items()}
        if self.mesh is None:
            fn = _unified_step if self._donate else _unified_step_async
            toks, self.cache = fn(
                self.model.ragged_step, self.paged_kernel, True,
                self.params, self.cache, tokens, pos, ptab, lrows, desc,
                prev, src)
        else:
            cache = dict(self.cache, pos=pos, page_table=ptab)
            if self.paged_kernel:
                toks, cache = self._mesh_step(self.params, tokens, cache,
                                              lrows, desc, prev, src)
            else:
                toks, cache = self._mesh_step(self.params, tokens, cache,
                                              lrows, prev, src)
            cache.pop("pos")
            cache.pop("page_table")
            self.cache = cache
        self._prev = toks
        return toks

    @property
    def supports_decode_step(self) -> bool:
        """True when decode-only plans may dispatch via ``decode_step``."""
        return self._decode_fn is not None

    def decode_step(self, tokens: np.ndarray, pos: np.ndarray,
                    table: np.ndarray, tok_src=None):
        """One batched decode over the compact (n_slots, 1) layout — the
        pure-decode fast path (see ``_fused_decode_step``). Non-decoding
        slots carry a dummy token at position 0 against the null table
        row (inert writes, discarded outputs). Returns the greedy token
        per slot as a DEVICE (n_slots,) int32 array without blocking
        (see ``step``)."""
        self.n_dispatch += 1
        if tok_src is None:
            tok_src = np.full((self.n_slots,), -1, np.int32)
        prev = self._prev_arr(self.n_slots)
        fn = (_fused_decode_step if self._donate
              else _fused_decode_step_async)
        toks, self.cache = fn(
            self._decode_fn, self.params, self.cache,
            jnp.asarray(tokens), jnp.asarray(pos), jnp.asarray(table),
            prev, jnp.asarray(tok_src))
        self._prev = toks
        return toks

    # ---------------------------------------------------- speculative draft

    def draft_prefill(self, packed: dict) -> None:
        """Write one packed draft-prefill step's KV into the draft pool
        (same ragged shape as ``step``, draft params/pool, logits
        discarded). Plain jit even under a mesh — a separate compile
        keyed on the draft model's ``ragged_step``."""
        self.n_dispatch += 1
        fn = _unified_step if self._donate else _unified_step_async
        _, self.draft_cache = fn(
            self.draft_model.ragged_step, False, False, self.draft_params,
            self.draft_cache, jnp.asarray(packed["tokens"]),
            jnp.asarray(packed["pos"]),
            jnp.asarray(packed["page_table"]),
            jnp.asarray(packed["logit_rows"]), None, None, None)

    def draft_k(self, tok0: np.ndarray, pos0: np.ndarray,
                table: np.ndarray, tok_src=None) -> np.ndarray:
        """Propose ``spec_k + 1`` greedy tokens per slot in ONE dispatch
        (``_draft_scan``); returns them as (spec_k + 1, n_slots) numpy.
        The scan feeds each slot's argmax back at the next position, so
        the draft pool ends the call holding KV for every proposed
        position — including the extra row the bonus-token case needs.
        ``tok_src`` (pipelined mode) injects in-flight base tokens from
        the previous TARGET step's device vector; the blocking fetch of
        the drafts therefore also waits out that step — speculative
        cycles overlap only their pack/observe host work."""
        self.n_dispatch += 1
        if tok_src is None:
            prev, src = None, None
        else:
            prev = self._prev_arr(self.n_slots * (self.spec_k + 1))
            src = jnp.asarray(tok_src)
        fn = _draft_scan if self._donate else _draft_scan_async
        drafts, self.draft_cache = fn(
            self.draft_model.decode, self.spec_k + 1, self.draft_params,
            self.draft_cache, jnp.asarray(tok0), jnp.asarray(pos0),
            jnp.asarray(table), prev, src)
        return np.asarray(jax.block_until_ready(drafts))
