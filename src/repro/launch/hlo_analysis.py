"""Post-SPMD HLO analysis: collective byte accounting + cost extraction.

collective_bytes is not in cost_analysis() — we parse the OPTIMIZED HLO
(compiled.as_text(), after GSPMD partitioning) and sum operand sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op. Shapes in HLO are per-DEVICE, so the totals are
per-device wire bytes (what the roofline's collective term wants).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.:  %ag = bf16[4,128,256]{2,1,0} all-gather(...)
_OP_RE = re.compile(
    r"=\s*(?:\()?([a-z0-9]+)\[([0-9,]*)\][^a-z]*\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
# tuple-result collectives:  (bf16[..], bf16[..]) all-reduce(...)
_TUPLE_RE = re.compile(
    r"=\s*\(([^)]*)\)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> dict:
    """-> {op_kind: per-device bytes} + {"total": ...}. '-start' ops are
    counted; their '-done' twins are skipped (same transfer)."""
    out: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if m:
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _TUPLE_RE.search(stripped)
        if m:
            shapes, kind = m.groups()
            for dt, dm in _SHAPE_RE.findall(shapes):
                out[kind] += _shape_bytes(dt, dm)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def cost_summary(compiled) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x: one dict per program
        ca = ca[0] if ca else {}
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for f in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[f] = int(getattr(ma, f, 0) or 0)
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "memory": mem,
    }
