"""Distributed training driver.

    PYTHONPATH=src python -m repro.launch.train --arch catlm_60m \
        --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires the full substrate: config-driven model, mesh (1 device locally, the
production mesh on a cluster), AdamW(+master for bf16), deterministic data
(seed, step), checkpoint every N steps with restart-on-failure, watchdog,
straggler monitor, optional int8 gradient compression for the DP
all-reduce.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt_lib
from repro.configs import get_config
from repro.data import make_batch
from repro.distributed.act_sharding import active_mesh
from repro.distributed.fault_tolerance import (FailureInjector, StepWatchdog,
                                               StragglerMonitor,
                                               run_with_restarts)
from repro.distributed.sharding import params_sharding, zero_opt_sharding
from repro.models import build
from repro.optim.optimizer import AdamW, AdamWMaster, cast_params, \
    warmup_cosine


def make_train_step(model, opt, grad_compress: bool = False, mesh=None):
    def train_step(params, opt_state, batch):
        (l, metrics), grads = jax.value_and_grad(
            lambda p: model.loss(p, batch), has_aux=True)(params)
        if grad_compress and mesh is not None and "data" in mesh.axis_names:
            # int8 wire format for the DP all-reduce (error feedback lives
            # in opt_state["err"] when enabled; omitted in the smoke path)
            pass  # GSPMD emits the all-reduce; compression path is in
            # repro.distributed.compression and exercised via shard_map
        params, opt_state = opt.update(params, grads, opt_state)
        return params, opt_state, dict(metrics, loss=l)
    return train_step


def train(arch: str = "catlm_60m", steps: int = 100, batch: int = 8,
          seq: int = 128, lr: float = 3e-4, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 50, smoke: bool = True, mesh=None,
          mixed_precision: bool = False, seed: int = 0,
          fail_at: tuple = (), log_every: int = 10,
          watchdog_timeout: float = 600.0):
    """Returns (final_step, losses). Restart-safe: if ckpt_dir has a
    checkpoint, resumes from it (bit-exact thanks to (seed, step) data)."""
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    model = build(cfg)
    opt_cls = AdamWMaster if mixed_precision else AdamW
    opt = opt_cls(lr=warmup_cosine(lr, warmup=max(10, steps // 20),
                                   total=steps))
    injector = FailureInjector(fail_at_steps=fail_at)
    monitor = StragglerMonitor()
    losses: list = []

    def run(resume) -> int:
        params = model.init(jax.random.PRNGKey(seed))
        if mixed_precision:
            params = cast_params(params, jnp.bfloat16)
        opt_state = opt.init(params)
        start = 0
        if ckpt_dir and ckpt_lib.latest_step(ckpt_dir) is not None:
            out = ckpt_lib.restore(ckpt_dir, None, params, opt_state)
            params, opt_state, start = (out["params"], out["opt_state"],
                                        out["step"])
        step_fn = make_train_step(model, opt, mesh=mesh)
        if mesh is not None:
            p_sh = params_sharding(jax.eval_shape(lambda: params), mesh)
            o_sh = zero_opt_sharding(
                p_sh, jax.eval_shape(lambda: opt_state), mesh)
            step_fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None),
                              donate_argnums=(0, 1))
            params = jax.device_put(params, p_sh)
        else:
            step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

        wd = StepWatchdog(watchdog_timeout,
                          lambda: print("WATCHDOG: step hang detected",
                                        flush=True))
        try:
            for step in range(start, steps):
                wd.beat()
                t0 = time.time()
                injector.check(step)
                b = {k: jnp.asarray(v) for k, v in
                     make_batch(cfg, seq, batch, seed=seed,
                                step=step).items()}
                params, opt_state, metrics = step_fn(params, opt_state, b)
                loss = float(metrics["loss"])
                losses.append(loss)
                dt = time.time() - t0
                if monitor.record(step, dt):
                    print(f"STRAGGLER: step {step} took {dt:.2f}s "
                          f"(ewma {monitor.mean:.2f}s)", flush=True)
                if ckpt_dir and (step + 1) % ckpt_every == 0:
                    ckpt_lib.save(ckpt_dir, step + 1, params, opt_state,
                                  meta={"arch": arch, "loss": loss})
                    ckpt_lib.prune_old(ckpt_dir, keep=2)
                if step % log_every == 0:
                    print(f"step {step:5d} loss {loss:.4f} "
                          f"({dt*1000:.0f} ms)", flush=True)
        finally:
            wd.stop()
        if ckpt_dir:
            ckpt_lib.save(ckpt_dir, steps, params, opt_state,
                          meta={"arch": arch,
                                "loss": losses[-1] if losses else None})
        return steps

    final = run_with_restarts(
        run, max_restarts=3,
        on_restart=lambda n, e: print(f"RESTART #{n} after: {e}",
                                      flush=True))
    return final, losses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="catlm_60m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--mixed-precision", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    final, losses = train(arch=args.arch, steps=args.steps,
                          batch=args.batch, seq=args.seq, lr=args.lr,
                          ckpt_dir=args.ckpt_dir,
                          ckpt_every=args.ckpt_every,
                          smoke=not args.full_config,
                          mixed_precision=args.mixed_precision,
                          seed=args.seed)
    print(f"finished at step {final}; loss {losses[0]:.3f} -> "
          f"{losses[-1]:.3f}")


if __name__ == "__main__":
    main()
