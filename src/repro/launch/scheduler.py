"""Host-side serving policy: request types + the unified token-budget
scheduler.

``TokenBudgetScheduler`` is the vLLM-style planner behind
``ServeEngine(schedule="unified")``: each engine step it packs up to
``max_batch_tokens`` of work — one decode token for every running slot
plus prefill chunks for admitting/in-flight ones — into a single
:class:`StepPlan` that the device executor (``repro.launch.executor``)
runs as ONE ragged model invocation. Splitting prompts into
budget-bounded chunks decouples time-to-first-token of a long admission
from the inter-token latency of in-flight decodes (no head-of-line
prefill stall), while the fixed packing width keeps the step at O(1)
compile shapes.

Everything here is pure host-side bookkeeping (numpy + python); the only
device-adjacent state it touches is the paged-KV page table
(``repro.launch.paged``), which it grows/releases exactly like the legacy
engine does.

Planning order per step (all FIFO-preserving):

1. **decode** — every slot that finished its prompt contributes exactly
   one token (its last generated token, written at its position). Decode
   goes first so ITL stays flat regardless of admission pressure;
   ``max_batch_tokens >= n_slots`` guarantees decodes always fit.
2. **in-flight prefill** — slots still mid-prompt (admitted on an earlier
   step) get up to ``min(remaining prompt, remaining budget[,
   prefill_chunk])`` tokens, oldest admission first.
3. **admission** — while the queue head fits (free slot, page reservation
   for its worst case, budget left), pop it and schedule its first
   chunk. The head never yields to a younger request (head-of-line wait,
   FIFO preserved — same rule as the legacy paged engine).

Invariants (property-tested in ``tests/test_scheduler_properties.py``):
every plan's packed token count is <= ``max_batch_tokens``; admission
order is submission order; no slot is both prefilling and decoding in
one plan; every admitted request retires exactly once.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np


# ----------------------------------------------------------- request types

@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` (P,) int32, decode budget."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    submit_time: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray            # (P + G,) prompt followed by G generated
    prompt_len: int
    ttft_s: float                 # submit -> first token (prefill) latency
    admit_step: int
    retire_step: int


@dataclasses.dataclass
class SeqState:
    """Unified-mode per-sequence state (the chunked-admission state
    machine): ``prefill_done`` counts prompt tokens already written to the
    KV pool; the sequence is *prefilling* until it reaches the prompt
    length, then *decoding* until retirement."""
    req: Request
    slot: int
    prefill_done: int = 0
    generated: list = dataclasses.field(default_factory=list)
    admit_step: int = 0
    admit_order: int = 0
    ttft_s: float = 0.0
    # pipelined (one-step-ahead) bookkeeping: ``inflight`` counts tokens
    # this slot is PREDICTED to append in the dispatched-but-unobserved
    # step (0 in synchronous mode); ``pending_src`` is the index of the
    # slot's next fed token inside that step's device token vector
    # (consumer-row index for a ragged step, slot index for the
    # slot-major fused decode step; -1 when nothing is in flight).
    inflight: int = 0
    pending_src: int = -1

    @property
    def prompt_len(self) -> int:
        return len(self.req.prompt)

    @property
    def decoding(self) -> bool:
        return self.prefill_done >= self.prompt_len


@dataclasses.dataclass
class StepPlan:
    """One step's packed work. ``decode``: (slot, fed token, write pos)
    triples, one per running slot. ``spec`` (speculative mode): (slot,
    fed token, base pos) triples — each packs ``spec_width`` = k+1
    verify rows (the last generated token plus k drafted tokens) instead
    of one decode row; ``spec_drafts`` maps slot -> the (k,) drafted
    tokens, filled by the engine after the draft pass and before
    ``pack``. ``prefill``: (slot, offset, q_len, tokens) chunks;
    ``draft_prefill`` mirrors them (plus prefix-hit backfill) into the
    draft pool. ``admitted``: (rid, slot) pairs admitted this step.
    ``cow``: (src, dst) page pairs the executor must device-copy BEFORE
    running the step (copy-on-write splits of partially-shared prefix
    pages). Logits are consumed in packing order: every decode row,
    every spec item's k+1 rows, then every prefill chunk that
    *completes* its prompt (``logit_consumers``)."""
    decode: list = dataclasses.field(default_factory=list)
    spec: list = dataclasses.field(default_factory=list)
    spec_width: int = 1
    spec_drafts: dict = dataclasses.field(default_factory=dict)
    # slot -> this step's draft count k' (adaptive speculation may plan
    # fewer than the configured spec_k per slot; absent -> spec_width-1)
    spec_k_of: dict = dataclasses.field(default_factory=dict)
    prefill: list = dataclasses.field(default_factory=list)
    draft_prefill: list = dataclasses.field(default_factory=list)
    admitted: list = dataclasses.field(default_factory=list)
    cow: list = dataclasses.field(default_factory=list)
    # pipelined mode: slot -> index of the slot's fed token inside the
    # PREVIOUS (in-flight) step's device token vector, -1 when the fed
    # token is a host value (``pack`` emits these as ``tok_src``)
    srcs: dict = dataclasses.field(default_factory=dict)
    # slots whose rows in THIS plan were invalidated by the previous
    # step's observation (the slot retired, or a speculative verify
    # accepted fewer rows than predicted): ``observe`` discards their
    # outputs, ``note_dispatch`` already charged them — see
    # ``_mark_stale``
    stale: set = dataclasses.field(default_factory=set)

    def spec_rows(self, slot: int) -> int:
        """Verify rows slot's item packs this step (its k' + 1)."""
        return self.spec_k_of.get(slot, self.spec_width - 1) + 1

    @property
    def n_tokens(self) -> int:
        return (len(self.decode)
                + sum(self.spec_rows(s) for s, _, _ in self.spec)
                + sum(n for _, _, n, _ in self.prefill))

    @property
    def logit_consumers(self) -> list:
        """[("decode"|"spec"|"first", slot)] aligned with the packed
        logit rows ("spec" consumes ``spec_width`` rows, others one)."""
        out = [("decode", slot) for slot, _, _ in self.decode]
        out += [("spec", slot) for slot, _, _ in self.spec]
        for slot, off, n, toks in self.prefill:
            if off + n >= self._prompt_lens[slot]:
                out.append(("first", slot))
        return out

    # slot -> prompt length, filled by the scheduler (completion test)
    _prompt_lens: dict = dataclasses.field(default_factory=dict)


class TokenBudgetScheduler:
    """Token-budget packing policy over the paged-KV bookkeeping (see the
    module docstring for the step algorithm).

    The scheduler owns the FIFO queue, the free-slot list, the active
    ``SeqState`` map, and the page pool/tables; the engine façade calls
    ``plan()``, executes the packed step on the device, then feeds the
    argmax tokens back through ``observe()`` which returns the sequences
    that retired."""

    def __init__(self, n_slots: int, max_batch_tokens: int, *, pool,
                 tables, prefill_chunk: int = 0,
                 eos_id: Optional[int] = None, plan_log_cap: int = 4096,
                 prefix=None, spec_k: int = 0, draft_tables=None,
                 adaptive_spec: bool = False):
        if adaptive_spec and not spec_k:
            raise ValueError("adaptive_spec needs spec_k > 0 (there is "
                             "no draft count to adapt)")
        if max_batch_tokens < n_slots * (spec_k + 1):
            raise ValueError(
                f"max_batch_tokens={max_batch_tokens} must be >= "
                f"n_slots*(spec_k+1)={n_slots * (spec_k + 1)} (every "
                f"running slot packs {spec_k + 1} token(s) per step)")
        if spec_k and draft_tables is None:
            raise ValueError("spec_k needs draft_tables (the draft "
                             "model's parallel paged pool)")
        self.n_slots = n_slots
        self.max_batch_tokens = max_batch_tokens
        self.prefill_chunk = prefill_chunk
        self.eos_id = eos_id
        self.pool, self.tables = pool, tables
        # speculative decoding: k drafted tokens per decoding slot per
        # cycle, verified as k+1 packed rows; the draft model's KV lives
        # in its own pool behind draft_tables (admitted/grown/shrunk/
        # released in lockstep with the target tables)
        self.spec_k = spec_k
        self.draft_tables = draft_tables
        # adaptive speculation: shrink a slot's per-step draft count k'
        # toward what its running acceptance rate earns (k' = ceil(rate ·
        # spec_k), floored at 1 so acceptance evidence keeps flowing) —
        # a slot whose drafts keep missing stops paying k wasted verify
        # rows per cycle. spec_k stays the hard cap; buffers, page
        # reservations, and the draft scan are sized for it, so adapting
        # never moves the worst case. EMA per SLOT, cleared on retire
        # (slot reuse must not inherit the last occupant's rate).
        self.adaptive_spec = adaptive_spec
        self._accept_ema: dict = {}     # slot -> acceptance-rate EMA
        self.spec_drafted = 0       # drafted tokens offered to verify
        self.spec_accepted = 0      # drafted tokens the target agreed on
        self.spec_cycles = 0        # draft/verify cycles run
        self.gen_tokens = 0         # tokens actually appended (all modes)
        # optional launch.paged.PrefixCache: admission looks up the
        # longest cached prefix and plans prefill only from the first
        # miss token (the hit's pages are mapped shared into the slot)
        self.prefix = prefix
        self.queue: deque = deque()
        self.free = list(range(n_slots))
        self.active: dict = {}          # slot -> SeqState
        self._admit_order = 0
        # lightweight per-step log for invariant tests / benchmarks:
        # (n_tokens, decode slots, prefill slots, admitted rids). A RING
        # (maxlen=plan_log_cap) so a sustained serve doesn't grow host
        # memory one tuple per step forever; running aggregates that must
        # survive eviction live in counters (packed_tokens_max, n_plans).
        self.plan_log: deque = deque(maxlen=plan_log_cap or None)
        self.packed_tokens_max = 0
        self.n_plans = 0
        # pack()/_kernel_desc() write into preallocated buffers reused
        # across steps (shapes are fixed per engine config). A 2-DEEP
        # RING, not a single set: with one-step-ahead dispatch, step N's
        # descriptors may still be in flight (jnp.asarray of a numpy
        # buffer can alias it on CPU) while pack() fills step N+1's —
        # a single reused set would let the fill race the dispatch.
        # Alternating parity means a buffer is only rewritten after the
        # NEXT step was dispatched, i.e. after its own step's arrays
        # were consumed. Allocated lazily (n_ptab comes from the tables).
        self._bufs: list = [{}, {}]
        self._buf_parity = 0
        self.mispredicts = 0    # optimistic plans invalidated by observe

    def reset(self) -> None:
        """Drop per-run bookkeeping (log, counters, admission order,
        descriptor-ring parity) on an idle scheduler — the engine's
        warmup/steady-state ``reset()`` hook. Slot and page state are
        already back at rest when idle."""
        assert self.idle, "reset() needs an idle scheduler"
        self.plan_log.clear()
        self.packed_tokens_max = 0
        self.n_plans = 0
        self._admit_order = 0
        self.free = list(range(self.n_slots))
        self.spec_drafted = self.spec_accepted = self.spec_cycles = 0
        self._accept_ema.clear()
        self.gen_tokens = 0
        self.mispredicts = 0
        self._buf_parity = 0

    # ------------------------------------------------------------ planning

    def _slot_k(self, slot: int) -> int:
        """This step's draft count for a slot: the configured ``spec_k``,
        or — with ``adaptive_spec`` — what the slot's acceptance-rate EMA
        earns, clamped to [1, spec_k] (see ``__init__``)."""
        if not self.adaptive_spec:
            return self.spec_k
        rate = self._accept_ema.get(slot)
        if rate is None:
            return self.spec_k          # no evidence yet: be optimistic
        return max(1, min(self.spec_k,
                          int(np.ceil(rate * self.spec_k))))

    def _chunk(self, want: int, budget: int) -> int:
        # Budget-remainder audit (the "sliced chunk rounds to 0" worry):
        # callers only reach here with budget >= 1 (the in-flight loop
        # breaks at budget <= 0, admission requires budget > 0) and
        # want >= 1 (an in-flight prefilling seq has prompt_len >
        # prefill_done; admission prompts are non-empty), so n >= 1
        # always — a slot can never stall a cycle receiving a 0-token
        # chunk while budget remains. Property-tested in
        # tests/test_scheduler_properties.py (chunks are never empty).
        n = min(want, budget)
        if self.prefill_chunk:
            n = min(n, self.prefill_chunk)
        return n

    def plan(self, step_idx: int) -> StepPlan:
        plan = StepPlan()
        plan.spec_width = self.spec_k + 1
        budget = self.max_batch_tokens
        # 1. decode: one token per running slot (slot order = packing
        # order, deterministic). Page growth happens here, mirroring the
        # legacy engine's pre-step ``ensure``. In speculative mode every
        # decoding slot instead packs a k+1-row verify item (its last
        # token plus k drafts, positions pos..pos+k) and BOTH pools grow
        # to cover the drafted positions up front — observe() shrinks the
        # rejected tail back so page state matches a never-drafted run.
        for slot in sorted(self.active):
            seq = self.active[slot]
            if not seq.decoding:
                continue
            # pipelined (one-step-ahead) planning is OPTIMISTIC: a slot
            # with an unobserved step in flight is assumed to append its
            # predicted ``inflight`` tokens and continue, so this plan
            # packs it at the predicted next position with its fed token
            # sourced from the in-flight step's device vector
            # (``srcs``). A slot the in-flight step is predicted to
            # RETIRE (budget exhausted) is simply not packed. observe()
            # reconciles: eos retirement or a short speculative accept
            # marks the optimistic rows stale and rewinds page state
            # (``_mark_stale`` / the shrink in ``_observe_spec``).
            # Synchronous mode never sets ``inflight``, so n_eff and
            # src degenerate to the original values.
            n_eff = len(seq.generated) + seq.inflight
            if seq.inflight and n_eff >= seq.req.max_new_tokens:
                continue        # predicted to retire in the in-flight step
            pos = seq.prompt_len + n_eff - 1
            fed = seq.generated[-1] if seq.generated else 0
            plan.srcs[slot] = seq.pending_src if seq.inflight else -1
            if self.spec_k:
                kx = self._slot_k(slot)
                # target pages cover the k' verify rows this step packs;
                # the DRAFT scan always runs spec_k + 1 fixed-length
                # steps (one compile), so its pages cover the full cap
                self.tables.ensure(slot, pos + kx)
                self.draft_tables.ensure(slot, pos + self.spec_k)
                plan.spec.append((slot, fed, pos))
                plan.spec_k_of[slot] = kx
                budget -= kx + 1
            else:
                self.tables.ensure(slot, pos)
                plan.decode.append((slot, fed, pos))
                budget -= 1
        # 2. in-flight prefill chunks, oldest admission first (mirrored
        # into the draft pool in speculative mode: the draft model needs
        # the full prompt's KV before it can propose)
        inflight = sorted((s for s in self.active.values()
                           if not s.decoding), key=lambda s: s.admit_order)
        for seq in inflight:
            if budget <= 0:
                break
            off = seq.prefill_done
            n = self._chunk(seq.prompt_len - off, budget)
            self.tables.ensure(seq.slot, off + n - 1)
            self.tables.assert_writable(seq.slot, off, off + n - 1)
            toks = np.asarray(seq.req.prompt[off:off + n], np.int32)
            plan.prefill.append((seq.slot, off, n, toks))
            if self.spec_k:
                self.draft_tables.ensure(seq.slot, off + n - 1)
                plan.draft_prefill.append((seq.slot, off, n, toks))
            seq.prefill_done += n
            budget -= n
        # 3. admission: queue head only (FIFO head-of-line wait). With a
        # prefix cache, admission looks up the longest cached prefix
        # first: its pages are mapped shared (read-only, refcount-bumped)
        # and the first chunk starts at the first miss token — cached
        # tokens are never prefilled at all.
        while self.queue and self.free and budget > 0:
            head = self.queue[0]
            # speculative verify writes k rows past the last decode
            # position, so the worst-case reservation covers them too
            budget_tokens = (len(head.prompt) + head.max_new_tokens
                             + self.spec_k)
            hit, pages = 0, []
            if self.prefix is not None:
                hit, pages = self.prefix.lookup(head.prompt)
                ok = self.prefix.make_room(self.tables, budget_tokens,
                                           hit_tokens=hit, protect=pages)
            else:
                ok = self.tables.can_admit(budget_tokens)
            if ok and self.spec_k:
                # the draft pool shares no prefix pages — it needs full
                # worst-case capacity even on a target-pool cache hit
                ok = self.draft_tables.can_admit(budget_tokens)
            if not ok:
                break
            slot = min(self.free)       # deterministic: lowest free slot
            self.free.remove(slot)
            req = self.queue.popleft()
            n = self._chunk(len(req.prompt) - hit, budget)
            self.tables.admit_prefix(slot, pages, hit, hit + n,
                                     budget_tokens=budget_tokens)
            if self.prefix is not None:
                self.prefix.note(hit, len(req.prompt))
                cow = self.tables.ensure_writable(slot, hit)
                self.prefix.cow_copies += len(cow)
                plan.cow.extend(cow)
            self.tables.assert_writable(slot, hit, hit + n - 1)
            if self.spec_k:
                self.draft_tables.admit(slot, 0,
                                        budget_tokens=budget_tokens)
                # the draft pool never shares prefix pages, so a target
                # cache hit still needs the hit region prefilled into the
                # draft pool — backfill it as extra draft-only chunks
                # (they ride outside the token budget: draft work is a
                # separate cheap dispatch, not verify-batch rows)
                cap = self._chunk(self.max_batch_tokens,
                                  self.max_batch_tokens)
                off = 0
                while off < hit:
                    dn = min(cap, hit - off)
                    self.draft_tables.ensure(slot, off + dn - 1)
                    plan.draft_prefill.append(
                        (slot, off, dn,
                         np.asarray(req.prompt[off:off + dn], np.int32)))
                    off += dn
                self.draft_tables.ensure(slot, hit + n - 1)
                plan.draft_prefill.append(
                    (slot, hit, n,
                     np.asarray(req.prompt[hit:hit + n], np.int32)))
            seq = SeqState(req, slot, prefill_done=hit + n,
                           admit_step=step_idx,
                           admit_order=self._admit_order)
            self._admit_order += 1
            self.active[slot] = seq
            plan.admitted.append((req.rid, slot))
            plan.prefill.append((slot, hit, n,
                                 np.asarray(req.prompt[hit:hit + n],
                                            np.int32)))
            budget -= n
        plan._prompt_lens = {s: seq.prompt_len
                             for s, seq in self.active.items()}
        self.packed_tokens_max = max(self.packed_tokens_max, plan.n_tokens)
        self.n_plans += 1
        self.plan_log.append((plan.n_tokens,
                              tuple(s for s, _, _ in plan.decode)
                              + tuple(s for s, _, _ in plan.spec),
                              tuple(s for s, _, _, _ in plan.prefill),
                              tuple(r for r, _ in plan.admitted)))
        return plan

    # ------------------------------------------------------------- packing

    def _buffers(self, kernel_desc: bool) -> dict:
        """The preallocated host arrays ``pack`` fills — a 2-deep ring
        (see ``__init__``: step N's arrays may still back an in-flight
        dispatch while step N+1 packs), each set allocated once (shapes
        are fixed per engine config) and RESET + reused every other
        step, so the serving hot loop stops paying a numpy allocation
        per descriptor per step. The returned views are valid until the
        next-but-one ``pack()`` call; the executor copies (or aliases)
        them to device (``jnp.asarray``) immediately."""
        buf = self._bufs[self._buf_parity]
        self._buf_parity ^= 1
        if not buf:
            T, R, n_ptab = (self.max_batch_tokens, self.n_slots,
                            self.tables.n_ptab)
            q_width = min(T, self.prefill_chunk) if self.prefill_chunk else T
            # a spec verify item is k+1 rows (and its consumer reads k+1
            # logit rows) — widen the per-item and logit buffers for it
            q_width = max(q_width, self.spec_k + 1)
            buf.update({
                "tokens": np.zeros((T,), np.int32),
                "pos": np.zeros((T,), np.int32),
                "slot_of": np.empty((T,), np.int32),
                "tok_src": np.empty((T,), np.int32),
                "logit_rows": np.zeros((R * (self.spec_k + 1),), np.int32),
                "ptab": np.zeros((T, n_ptab), np.int32),
                "qidx": np.zeros((R, q_width), np.int32),
                "qpos": np.empty((R, q_width), np.int32),
                "lengths": np.zeros((R,), np.int32),
                "table": np.zeros((R, n_ptab), np.int32),
                "inv_seq": np.zeros((T,), np.int32),
                "inv_qi": np.zeros((T,), np.int32),
            })
        b = buf
        for name in ("tokens", "pos", "logit_rows", "ptab"):
            b[name][...] = 0
        b["slot_of"].fill(-1)
        b["tok_src"].fill(-1)
        if kernel_desc:
            for name in ("qidx", "lengths", "table", "inv_seq", "inv_qi"):
                b[name][...] = 0
            b["qpos"].fill(-1)
        return b

    def pack(self, plan: StepPlan, *, kernel_desc: bool = False) -> dict:
        """Flatten a plan into the fixed-shape arrays the ragged device
        step consumes (ONE compile shape per engine): ``tokens`` (T, 1),
        ``pos`` (T,), ``page_table`` (T, n_ptab) per-token table rows
        (null rows for padding), ``logit_rows`` (n_slots,) packed-row
        indices of the logit consumers. ``kernel_desc`` additionally
        emits the per-work-item query-block descriptors the ragged
        paged-attention kernel wants (``ragged_desc``).

        The arrays are views of buffers reused across steps (see
        ``_buffers``): read/copy them before the next ``pack()``."""
        T = self.max_batch_tokens
        buf = self._buffers(kernel_desc)
        tokens = buf["tokens"]
        pos = buf["pos"]
        slot_of = buf["slot_of"]
        tok_src = buf["tok_src"]
        items = []                      # (slot, start row, q_len, last pos)
        last_row = {}                   # slot -> its item's last packed row
        i = 0
        for slot, tok, p in plan.decode:
            tokens[i], pos[i], slot_of[i] = tok, p, slot
            tok_src[i] = plan.srcs.get(slot, -1)
            items.append((slot, i, 1, p))
            last_row[slot] = i
            i += 1
        spec_start = {}                 # slot -> its verify item's first row
        for slot, tok, p in plan.spec:
            # verify item: [last token, k' drafts] at positions p..p+k'
            # (k' <= spec_k when adaptive speculation trimmed the slot);
            # only the BASE row can be an in-flight device token — the
            # draft rows are host values from this cycle's draft scan
            w = plan.spec_rows(slot)
            tokens[i] = tok
            tok_src[i] = plan.srcs.get(slot, -1)
            tokens[i + 1:i + w] = plan.spec_drafts[slot][:w - 1]
            pos[i:i + w] = p + np.arange(w)
            slot_of[i:i + w] = slot
            items.append((slot, i, w, p + w - 1))
            spec_start[slot] = i
            i += w
        for slot, off, n, toks in plan.prefill:
            tokens[i:i + n] = toks
            pos[i:i + n] = off + np.arange(n)
            slot_of[i:i + n] = slot
            items.append((slot, i, n, off + n - 1))
            last_row[slot] = i + n - 1
            i += n
        # logit rows derive from the SAME consumer list observe() zips
        # over — single-sourced so the row/consumer alignment cannot
        # drift (each consumer reads its slot's last packed row; a spec
        # consumer reads all k'+1 of its item's rows)
        consumers = plan.logit_consumers
        logit_rows = buf["logit_rows"]
        j = 0
        for kind, slot in consumers:
            if kind == "spec":
                w = plan.spec_rows(slot)
                logit_rows[j:j + w] = spec_start[slot] + np.arange(w)
                j += w
            else:
                logit_rows[j] = last_row[slot]
                j += 1
        ptab = buf["ptab"]
        valid = slot_of >= 0
        ptab[valid] = self.tables.table[slot_of[valid]]
        packed = {"tokens": tokens[:, None], "pos": pos,
                  "page_table": ptab, "logit_rows": logit_rows,
                  "tok_src": tok_src, "n_logits": j}
        if kernel_desc:
            packed["ragged_desc"] = self._kernel_desc(items, buf)
        return packed

    def _kernel_desc(self, items, buf: dict) -> dict:
        """Per-work-item query blocks for the ragged paged-attention
        kernel: row j holds work item j's packed-row indices and absolute
        positions (padded with qpos=-1 -> fully masked), its page-table
        row, and its kv length; ``inv_*`` maps each packed row back to
        its (item, row-in-item) so the blocked output scatters into the
        flat layout.

        The block width is the largest q_len any single item can reach —
        ``prefill_chunk`` when set (a decode item is 1 row), the whole
        budget otherwise — still a fixed shape per engine config (O(1)
        compiles) but without padding every item to the full packed
        width. Set ``prefill_chunk`` alongside ``paged_kernel`` to keep
        the kernel's masked padding rows small."""
        # block width Q bounds one ITEM's q_len; the inv_* maps stay at
        # the full packed width T (they are indexed by packed row).
        # All arrays are the reused _buffers views, already reset.
        qidx, qpos = buf["qidx"], buf["qpos"]
        lengths, table = buf["lengths"], buf["table"]
        inv_seq, inv_qi = buf["inv_seq"], buf["inv_qi"]
        for j, (slot, start, n, last) in enumerate(items):
            qidx[j, :n] = start + np.arange(n)
            qpos[j, :n] = last - n + 1 + np.arange(n)
            lengths[j] = last + 1
            table[j] = self.tables.table[slot]
            inv_seq[start:start + n] = j
            inv_qi[start:start + n] = np.arange(n)
        return {"qidx": qidx, "qpos": qpos, "lengths": lengths,
                "table": table, "inv_seq": inv_seq, "inv_qi": inv_qi}

    # ------------------------------------------------------- draft packing

    def _draft_buf(self) -> dict:
        """Separate reused buffers for draft-prefill packing — ``pack``
        runs after the draft dispatches each cycle, so the main ``_buf``
        views must stay untouched until then."""
        if not hasattr(self, "_dbuf") or not self._dbuf:
            T, n_ptab = self.max_batch_tokens, self.draft_tables.n_ptab
            self._dbuf = {
                "tokens": np.zeros((T,), np.int32),
                "pos": np.zeros((T,), np.int32),
                "slot_of": np.empty((T,), np.int32),
                "ptab": np.zeros((T, n_ptab), np.int32),
                "logit_rows": np.zeros(
                    (self.n_slots * (self.spec_k + 1),), np.int32),
            }
        b = self._dbuf
        for name in ("tokens", "pos", "ptab"):
            b[name][...] = 0
        b["slot_of"].fill(-1)
        return b

    def pack_draft(self, plan: StepPlan):
        """Yield packed draft-prefill steps (same fixed (T, 1) ragged
        shape as the target step, against the DRAFT page tables). Chunks
        are grouped greedily up to the token budget; logits are never
        consumed (the draft only needs its KV written). Each yielded dict
        reuses one buffer set — the executor copies to device before the
        next iteration."""
        entries = plan.draft_prefill
        gi = 0
        while gi < len(entries):
            buf = self._draft_buf()
            tokens, pos, slot_of = (buf["tokens"], buf["pos"],
                                    buf["slot_of"])
            i = 0
            while gi < len(entries):
                slot, off, n, toks = entries[gi]
                if i + n > self.max_batch_tokens:
                    assert i > 0, (n, self.max_batch_tokens)
                    break
                tokens[i:i + n] = toks
                pos[i:i + n] = off + np.arange(n)
                slot_of[i:i + n] = slot
                i += n
                gi += 1
            ptab = buf["ptab"]
            valid = slot_of >= 0
            ptab[valid] = self.draft_tables.table[slot_of[valid]]
            yield {"tokens": tokens[:, None], "pos": pos,
                   "page_table": ptab, "logit_rows": buf["logit_rows"],
                   "n_logits": 0}

    def draft_inputs(self, plan: StepPlan):
        """Host inputs for the k-step draft scan: (tok0 (n_slots, 1),
        pos0 (n_slots,), table (n_slots, n_ptab), src (n_slots,)).
        Non-drafting slots (free, or mid-prefill) feed a dummy token at
        position 0 against the NULL table row so their scan writes are
        inert — their real draft pages must not be touched. ``src``
        carries the plan's device-token sources (pipelined mode; -1
        rows keep the host token)."""
        tok0 = np.zeros((self.n_slots, 1), np.int32)
        pos0 = np.zeros((self.n_slots,), np.int32)
        table = np.zeros_like(self.draft_tables.table)
        src = np.full((self.n_slots,), -1, np.int32)
        for slot, tok, p in plan.spec:
            tok0[slot, 0] = tok
            pos0[slot] = p
            table[slot] = self.draft_tables.table[slot]
            src[slot] = plan.srcs.get(slot, -1)
        return tok0, pos0, table, src

    def pack_decode(self, plan: StepPlan):
        """Compact slot-major inputs for the pure-decode fast path:
        (tokens (n_slots, 1), pos (n_slots,), table (n_slots, n_ptab),
        src (n_slots,)). One row per SLOT (not per token) — the fused
        decode step runs at batch = n_slots, a single fixed compile
        shape. Non-decoding slots feed a dummy token at position 0
        against the NULL table row so their cache writes land on the
        null page. ``src`` carries the plan's device-token sources
        (pipelined mode). Only valid for plans that are pure decode (no
        prefill/spec/cow work)."""
        tok = np.zeros((self.n_slots, 1), np.int32)
        pos = np.zeros((self.n_slots,), np.int32)
        table = np.zeros_like(self.tables.table)
        src = np.full((self.n_slots,), -1, np.int32)
        for slot, t, p in plan.decode:
            tok[slot, 0] = t
            pos[slot] = p
            table[slot] = self.tables.table[slot]
            src[slot] = plan.srcs.get(slot, -1)
        return tok, pos, table, src

    # --------------------------------------------------- pipelined dispatch

    def note_dispatch(self, plan: StepPlan, *, slot_major: bool = False
                      ) -> None:
        """Record that ``plan`` was dispatched without waiting for its
        tokens (one-step-ahead mode): every logit consumer's slot now
        has predicted-but-unobserved tokens in flight, and its next fed
        token lives in the dispatched step's device token vector —
        ``pending_src`` is the index the NEXT plan's rows inject it
        from (consumer-row order for a ragged step; ``slot_major=True``
        for the fused decode step, whose output vector is indexed by
        slot)."""
        i = 0
        for kind, slot in plan.logit_consumers:
            w = plan.spec_rows(slot) if kind == "spec" else 1
            seq = self.active[slot]
            seq.inflight += w
            # a spec item's base token for the FOLLOWING step is its
            # last verify row's argmax (the bonus/continuation row)
            seq.pending_src = slot if slot_major else i + w - 1
            i += w

    # ---------------------------------------------------------- observation

    def _finished(self, seq: SeqState) -> bool:
        # Guard the empty-generated case explicitly (a spec verify step
        # can consult this mid-append) and never treat eos_id=None as
        # token 0 — ``None == tok`` is False today only by accident of
        # int/None comparison, so make the intent structural.
        if len(seq.generated) >= seq.req.max_new_tokens:
            return True
        return (self.eos_id is not None and bool(seq.generated)
                and seq.generated[-1] == self.eos_id)

    def _retire_slot(self, seq: SeqState, retired: list) -> None:
        retired.append(seq)
        del self.active[seq.slot]
        self._accept_ema.pop(seq.slot, None)
        self.tables.release(seq.slot)
        if self.draft_tables is not None:
            self.draft_tables.release(seq.slot)
        self.free.append(seq.slot)

    def _mark_stale(self, slot: int, ahead: Optional[StepPlan]) -> None:
        """Invalidate a slot's optimistically-packed rows in the already-
        dispatched next plan (``ahead``): the prediction they were packed
        under just failed (the slot retired on eos, or a speculative
        verify accepted fewer rows than planned). ``observe`` will skip
        the stale consumers — their device writes land strictly past the
        true valid length (or in released pages) and are overwritten
        before they are ever attendable (see launch/README.md)."""
        if ahead is None or slot in ahead.stale:
            return
        if any(s == slot for _, s in ahead.logit_consumers):
            ahead.stale.add(slot)
            self.mispredicts += 1
            seq = self.active.get(slot)
            if seq is not None:
                seq.inflight = 0
                seq.pending_src = -1

    def _observe_spec(self, plan: StepPlan, seq: SeqState,
                      ys: np.ndarray, retired: list,
                      ahead: Optional[StepPlan] = None) -> None:
        """Greedy acceptance for one verify item: every row of ``ys`` is
        the target's argmax given [prompt, generated, drafts[:j]] — append
        row j while the drafts keep matching (longest accepted prefix),
        then the first mismatching row IS the target's correction, and a
        fully-accepted block earns the bonus token from the last row.
        Every appended token is a target argmax, which is the whole
        token-identity argument. Afterwards both pools shrink back to the
        true sequence length so page tables and refcounts equal a
        never-drafted run's — UNLESS the prediction fully held and the
        next step is already in flight over the predicted extent, in
        which case the pages past the true length are exactly the ones
        that step is using and the shrink is deferred to its own
        observation."""
        slot = seq.slot
        k = plan.spec_k_of.get(slot, self.spec_k)
        drafts = plan.spec_drafts[slot][:k]
        self.spec_cycles += 1
        self.spec_drafted += k
        n_acc = 0
        n_app = 0
        done = False
        for j in range(k):
            tok = int(ys[j])
            seq.generated.append(tok)
            n_app += 1
            self.gen_tokens += 1
            accepted = tok == int(drafts[j])
            if accepted:
                self.spec_accepted += 1
                n_acc += 1
            done = self._finished(seq)
            if done or not accepted:
                break
        else:
            # all k drafts accepted -> the k+1-th row is a free token
            seq.generated.append(int(ys[k]))
            n_app += 1
            self.gen_tokens += 1
            done = self._finished(seq)
        if self.adaptive_spec:
            # per-slot acceptance EMA drives the next cycle's k' (see
            # _slot_k). Fraction of THIS cycle's offered drafts accepted.
            frac = n_acc / k
            old = self._accept_ema.get(slot)
            self._accept_ema[slot] = (frac if old is None
                                      else 0.5 * old + 0.5 * frac)
        if done:
            self._mark_stale(slot, ahead)
            self._retire_slot(seq, retired)
            return
        if n_app == k + 1 and seq.inflight > n_app:
            # the optimistic prediction held AND the next step is in
            # flight at the predicted positions — its pages must stay
            seq.inflight -= n_app
            return
        # short acceptance (or nothing in flight): the continuation rows
        # packed ahead (if any) assumed a longer sequence — discard them
        # and rewind both pools to the true length, leaving page tables
        # and refcounts equal to a synchronous trajectory's
        self._mark_stale(slot, ahead)
        seq.inflight = 0
        seq.pending_src = -1
        valid = seq.prompt_len + len(seq.generated) - 1
        self.tables.shrink(slot, valid)
        self.draft_tables.shrink(slot, valid)

    def observe(self, plan: StepPlan, toks: np.ndarray, now: float,
                ahead: Optional[StepPlan] = None) -> list:
        """Apply one step's argmax tokens (aligned with
        ``plan.logit_consumers``; a "spec" consumer takes its
        ``spec_rows(slot)`` rows); returns the retired ``SeqState``s (slot freed, pages
        released — the engine turns them into results).

        ``ahead`` (pipelined mode) is the NEXT plan, already dispatched
        under the optimistic assumption that every slot here continues:
        when that assumption fails (eos retirement, short speculative
        accept) the slot's rows in ``ahead`` are marked stale and its
        page state rewound (``_mark_stale``/``_observe_spec``). Rows of
        ``plan`` itself that an EARLIER observation marked stale are
        skipped — their slot retired (or rewound) before this step's
        tokens arrived, so its outputs here belong to a dead
        prediction."""
        retired = []
        i = 0
        for kind, slot in plan.logit_consumers:
            w = plan.spec_rows(slot) if kind == "spec" else 1
            if slot in plan.stale:
                i += w
                continue
            seq = self.active[slot]
            if kind == "spec":
                self._observe_spec(plan, seq, toks[i:i + w], retired,
                                   ahead)
                i += w
                continue
            seq.generated.append(int(toks[i]))
            self.gen_tokens += 1
            i += 1
            if seq.inflight:
                seq.inflight -= 1
            if kind == "first":
                seq.ttft_s = now - seq.req.submit_time
                if self.prefix is not None:
                    # prefill complete -> its full prompt pages hold real
                    # KV on device; adopt them into the prefix cache
                    self.prefix.register(seq.req.prompt,
                                         self.tables.owned_pages(slot))
            if self._finished(seq):
                self._mark_stale(slot, ahead)
                self._retire_slot(seq, retired)
        return retired

    @property
    def idle(self) -> bool:
        return not self.queue and not self.active
