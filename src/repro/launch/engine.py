"""Continuous-batching serve engine over a slot-allocated quantized KV cache.

Requests with arbitrary prompt lengths enter a FIFO queue. The engine owns
a shared KV cache of ``n_slots`` independent sequence rows (int8 codes +
per-token scales when the config sets ``kv_quant_bits``, bf16/f32
otherwise). Each engine step interleaves:

  1. **admit** — while a slot is free and the queue is non-empty, pop the
     oldest request, prefill it alone (batch-1) against its slot's cache
     rows, and emit its first token from the prefill logits (TTFT).
  2. **decode** — one batched greedy decode step over *all* occupied
     slots at once; every slot sits at its own sequence position, so the
     cache write and RoPE/attention run with per-slot position vectors
     (``models.layers.cache_update*`` with (B,) ``pos``).
  3. **retire** — slots whose request hit ``max_new_tokens`` (or the
     optional ``eos_id``) return their result and go back on the free
     list; the next queued request is admitted on the following step.

Slot reuse needs no cache zeroing: a new occupant's prefill overwrites
rows [0, P) and every stale row beyond the slot's position is masked by
the causal (position >= kv position) test inside ``chunked_attention``.

Decode always runs the full ``n_slots`` batch (free slots carry a dummy
token at position 0 whose output is discarded) so the decode step compiles
exactly once. Prefill compile count is tamed two ways:

- **bucketing** (default, ``bucket=True``): prompts pad right to the next
  power-of-two length and the logits slice at the true last prompt token
  (``logits_at``), so prefill compiles O(log max_len) times instead of
  once per distinct prompt length;
- **chunked prefill** (``prefill_chunk=C``, paged mode): the prompt feeds
  through in fixed C-token chunks at successive cache offsets — ONE
  prefill compile total, independent of the length distribution.

``paged=True`` swaps the slot-contiguous cache for a **paged KV pool**
(``repro.launch.paged``): fixed-size pages allocated lazily as sequences
grow, per-slot page tables gathered on device, token-identical output to
the slot cache (the gathered logical view is bitwise the same tensor).
See ``src/repro/launch/README.md`` for diagrams and the pool sizing
formula.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------- request types

@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` (P,) int32, decode budget."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    submit_time: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray            # (P + G,) prompt followed by G generated
    prompt_len: int
    ttft_s: float                 # submit -> first token (prefill) latency
    admit_step: int
    retire_step: int


@dataclasses.dataclass
class _Active:
    req: Request
    slot: int
    generated: list
    admit_step: int
    ttft_s: float


# ------------------------------------------------------------- jit helpers

@functools.lru_cache(maxsize=8)
def jitted_model_fns(model):
    """(jit prefill, jit decode) cached per model so repeated engine /
    oracle runs over the same model share compilations."""
    return jax.jit(model.prefill), jax.jit(model.decode)


@jax.jit
def _take_slot(cache, slot):
    """Slice one slot's batch-1 cache out of the shared (L, n_slots, ...)
    arrays (leaf layout: layer axis 0, slot axis 1)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)


# Donating the shared cache lets XLA write the slot rows in place on
# backends with buffer donation (TPU); CPU falls back to a copy.
@functools.partial(jax.jit, donate_argnums=(0,))
def _put_slot(cache, part, slot):
    return jax.tree.map(
        lambda a, p: jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1),
        cache, part)


# Single-device admissions run take -> prefill -> put as ONE jitted
# program: the slot's rows are sliced, prefilled, and written back without
# the per-slot part ever surfacing as separate host-boundary buffers
# between three dispatches (the old take/prefill/put ping-pong). The
# shared cache is donated so XLA can update the slot rows in place.
# ``prefill_fn`` is static (one compile per model × token shape).
@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _prefill_slot_fused(prefill_fn, params, cache, tokens, slot, logits_at):
    part = jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)
    logits, part = prefill_fn(params, tokens, dict(part, pos=jnp.int32(0)),
                              logits_at=logits_at)
    part.pop("pos")
    cache = jax.tree.map(
        lambda a, p: jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1),
        cache, part)
    return logits, cache


# ------------------------------------------------------------------ engine

class ServeEngine:
    """Continuous-batching greedy-decode engine (see module docstring).

    ``model``/``params`` follow ``repro.models.Model``; the model family
    must support per-slot position vectors in its decode cache (dense
    does). ``max_len`` bounds prompt + generated tokens per slot.
    """

    _SLOT_FAMILIES = ("dense", "moe", "vlm")   # families with (B,) pos decode

    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 mesh=None, tp_axis: str = "model",
                 tp_mode: str = "gather", tp_kernels: bool = False,
                 paged: bool = False, page_size: int = 16,
                 prefill_chunk: int = 0, n_pages: int = 0,
                 bucket: bool = True, paged_kernel: bool = False):
        family = getattr(model.cfg, "family", "dense")
        if family not in self._SLOT_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine needs per-slot position vectors in decode, "
                f"implemented for {self._SLOT_FAMILIES}; got family "
                f"{family!r}")
        self.model, self.params = model, params
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.paged, self.bucket = paged, bucket
        self.prefill_chunk, self.paged_kernel = prefill_chunk, paged_kernel
        if paged:
            from repro.launch.paged import PagePool, SlotPageTables
            from repro.models.layers import KV_QUANT_GROUP
            if getattr(model, "init_paged_cache", None) is None:
                raise NotImplementedError(
                    f"family {family!r} has no paged KV cache")
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            if prefill_chunk < 0:
                raise ValueError(
                    f"prefill_chunk must be >= 0, got {prefill_chunk}")
            if model.cfg.kv_quant_bits and page_size % KV_QUANT_GROUP:
                raise ValueError(
                    f"page_size={page_size} must be a multiple of the KV "
                    f"quant scale group ({KV_QUANT_GROUP})")
            if prefill_chunk and prefill_chunk % page_size:
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be a multiple of "
                    f"page_size={page_size} (chunks write whole pages)")
            # logical rows per slot, rounded up to whole pages
            self._kv_len = -(-max_len // page_size) * page_size
            n_ptab = self._kv_len // page_size
            n_pages = n_pages or 1 + n_slots * n_ptab  # worst case + null
            self.pool = PagePool(n_pages, page_size)
            self.tables = SlotPageTables(self.pool, n_slots, n_ptab)
            cache = model.init_paged_cache(n_pages, page_size)
            self._cache = dict(cache)
        else:
            if prefill_chunk:
                raise ValueError("prefill_chunk needs paged=True (the slot "
                                 "cache keeps whole-prompt prefill; use "
                                 "bucket=True to bound its compile count)")
            if paged_kernel:
                raise ValueError("paged_kernel needs paged=True")
            self._kv_len = max_len
            cache = model.init_cache(n_slots, max_len)
            self._cache = {k: v for k, v in cache.items() if k != "pos"}
        self.quantized_kv = "k_scale" in cache
        self._page_bytes = (sum(v.nbytes for v in self._cache.values())
                            // n_pages if paged else 0)
        self._pos = np.zeros((n_slots,), np.int32)     # per-slot positions
        self._free = list(range(n_slots))
        self._queue: deque[Request] = deque()
        self._active: dict[int, _Active] = {}          # slot -> request
        self.mesh = mesh
        if mesh is None:
            self._prefill, self._decode = jitted_model_fns(model)
            if paged:
                # paged prefill/decode round-trip the ENTIRE global pool
                # (not a batch-1 slot part), so donate the cache arg —
                # in-place pool updates on donation-capable backends,
                # mirroring what _prefill_slot_fused does for slots
                self._prefill = jax.jit(model.prefill, donate_argnums=(2,))
                dec = (lambda p, t, c: model.decode(p, t, c,
                                                    paged_kernel=True)
                       ) if paged_kernel else model.decode
                self._decode = jax.jit(dec, donate_argnums=(2,))
        else:
            self._init_mesh_fns(mesh, tp_axis, tp_mode, tp_kernels)
        self.step_count = 0
        self._next_rid = 0
        self.events: list[tuple] = []   # ("admit"|"retire", rid, slot, step)
        self.results: dict[int, RequestResult] = {}
        self.metrics = {"queue_depth": [], "occupancy": [],
                        "resident_kv_bytes": [],
                        "generated_tokens": 0, "decode_steps": 0}

    # -------------------------------------------------------- mesh serving

    def _init_mesh_fns(self, mesh, tp_axis: str, tp_mode: str,
                       tp_kernels: bool) -> None:
        """Tensor-parallel serving: params and the shared slot KV cache
        are device_put with quantization-aware shardings
        (``distributed.sharding.tp_param_specs`` / ``tp_cache_specs``) and
        prefill/decode run the TP forward inside shard_map. Slot
        bookkeeping (queue, free list, positions) stays host-side and is
        identical to the single-device engine; in ``tp_mode="gather"``
        (default) the decoded tokens are bit-identical to it too."""
        from jax.sharding import PartitionSpec as P

        from repro.core.qlinear import iter_qlinear
        from repro.distributed.compat import shard_map
        from repro.distributed import sharding as shlib

        cfg = self.model.cfg
        if cfg.n_experts:
            raise NotImplementedError("mesh serving covers the dense "
                                      "(non-MoE) family")
        tp = mesh.shape[tp_axis]
        packed = any(l.packed for _, l in iter_qlinear(self.params))
        unit = 2 * tp if (packed and tp_mode == "psum") else tp
        for dim, name in ((cfg.n_heads, "n_heads"),
                          (cfg.n_kv_heads, "n_kv_heads")):
            if dim % tp:
                raise ValueError(
                    f"{name}={dim} must divide by {tp_axis}={tp} (whole "
                    f"heads per shard)")
        for dim, name in ((cfg.q_dim, "q_dim"), (cfg.d_ff, "d_ff")):
            if dim % unit:
                raise ValueError(
                    f"{name}={dim} must divide by {unit} "
                    f"({tp_axis}={tp}"
                    + (", ×2: int4-packed row shards hold whole bytes)"
                       if unit != tp else ")"))
        dp_axis = next((a for a in ("data", "pod")
                        if a in mesh.axis_names
                        and self.n_slots % mesh.shape[a] == 0
                        and mesh.shape[a] > 1), None)
        if self.paged and dp_axis is not None:
            raise NotImplementedError(
                "paged mesh serving is tensor-parallel only: the page pool "
                "is a global (not per-slot) allocation, so its writes "
                "cannot shard over a data axis — use a (1, tp) mesh")

        pspecs = shlib.tp_param_specs(self.params, mesh, axis=tp_axis,
                                      cfg=cfg, row_mode=tp_mode)
        dec_cspecs = shlib.tp_cache_specs(self._cache, mesh, axis=tp_axis,
                                          dp_axis=dp_axis)
        if self.paged:
            # prefill sees the same global pool as decode (only the page
            # table narrows to the admitted slot's row)
            pre_cspecs = dec_cspecs
        else:
            part_shapes = jax.eval_shape(
                lambda c: jax.tree.map(lambda a: a[:, :1], c), self._cache)
            pre_cspecs = shlib.tp_cache_specs(part_shapes, mesh,
                                              axis=tp_axis)
        self.params = jax.device_put(self.params, shlib.named(pspecs, mesh))
        self._cache = jax.device_put(self._cache,
                                     shlib.named(dec_cspecs, mesh))
        tok_spec = P(dp_axis, None)
        # the (B,) per-slot position vector shards with the slot axis
        pos_spec = P(dp_axis) if dp_axis else P()
        tp_kw = dict(tp_axis=tp_axis, tp_mode=tp_mode, tp_kernels=tp_kernels)
        if self.paged:
            # page tables replicate (every shard gathers/scatters its own
            # head slice of the same physical pages)
            pt_spec = {"page_table": P(None, None)}
            pre_extra = dict(pt_spec, pos=P())
            dec_extra = dict(pt_spec, pos=pos_spec)
        else:
            pre_extra, dec_extra = {"pos": P()}, {"pos": pos_spec}
        model = self.model
        pk = self.paged_kernel

        def pre(p, t, c, la):
            return model.prefill(p, t, c, logits_at=la, **tp_kw)

        def dec(p, t, c):
            if pk:
                return model.decode(p, t, c, paged_kernel=True, **tp_kw)
            return model.decode(p, t, c, **tp_kw)

        self._prefill = jax.jit(shard_map(
            pre, mesh=mesh,
            in_specs=(pspecs, P(None, None), dict(pre_cspecs, **pre_extra),
                      P()),
            out_specs=(P(None, None, None), dict(pre_cspecs, **pre_extra)),
            check_vma=False))
        self._decode = jax.jit(shard_map(
            dec, mesh=mesh,
            in_specs=(pspecs, tok_spec, dict(dec_cspecs, **dec_extra)),
            out_specs=(P(dp_axis, None, None),
                       dict(dec_cspecs, **dec_extra)),
            check_vma=False))

    # ------------------------------------------------------------- intake

    def submit(self, prompt, max_new_tokens: int, rid: Optional[int] = None
               ) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not len(prompt):
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) "
                f"exceeds max_len={self.max_len}")
        if self.paged:
            need = self.tables.pages_for(len(prompt) + max_new_tokens)
            if need > self.pool.n_pages - 1:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.pool.n_pages - 1} allocatable (raise n_pages "
                    f"or max_len/page_size)")
        if rid is None:
            rid = self._next_rid
        elif (rid in self.results
              or any(r.req.rid == rid for r in self._active.values())
              or any(r.rid == rid for r in self._queue)):
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid) + 1
        self._queue.append(Request(rid, prompt, max_new_tokens,
                                   submit_time=time.time()))
        return rid

    # ------------------------------------------------------ slot lifecycle

    def _bucketed(self, prompt: np.ndarray):
        """Right-pad a prompt to its power-of-two bucket (compile-count
        discipline: O(log max_len) prefill shapes instead of one per
        distinct length). Returns (padded tokens, logits row index).
        Padded rows write garbage k/v past the prompt — causally masked,
        then overwritten by decode before they are ever attendable."""
        p = len(prompt)
        if not self.bucket:
            return prompt, p - 1
        width = max(8, 1 << (p - 1).bit_length())
        width = min(width, self._kv_len if self.paged else self.max_len)
        if width <= p:
            return prompt, p - 1
        return np.pad(prompt, (0, width - p)), p - 1

    def _prefill_paged(self, req: Request, slot: int):
        """Prefill into the slot's freshly-allocated pages: one bucketed
        call, or fixed-size chunks at successive offsets (ONE compile
        total) when ``prefill_chunk`` is set."""
        p = len(req.prompt)
        row = jnp.asarray(self.tables.table[slot:slot + 1])
        chunk = self.prefill_chunk
        if not chunk:
            toks, last = self._bucketed(req.prompt)
            spans = [(toks, 0, last)]
        else:
            spans = []
            for off in range(0, p, chunk):
                toks = np.zeros((chunk,), np.int32)
                n = min(chunk, p - off)
                toks[:n] = req.prompt[off:off + n]
                spans.append((toks, off, int(np.clip(p - 1 - off, 0,
                                                     chunk - 1))))
        logits = None
        for toks, off, last in spans:
            cache = dict(self._cache, page_table=row, pos=jnp.int32(off))
            if self.mesh is None:
                logits, cache = self._prefill(self.params, toks[None], cache,
                                              logits_at=jnp.int32(last))
            else:
                logits, cache = self._prefill(self.params, toks[None], cache,
                                              jnp.int32(last))
            cache.pop("pos")
            # rebind: the input row buffer was donated with the cache
            row = cache.pop("page_table")
            self._cache = cache
        return logits

    def _prefill_slot(self, req: Request, slot: int):
        """Slot-cache prefill: fused take->prefill->put in one dispatch
        (single device) or explicit take/put around the shard_map'd
        forward (mesh)."""
        toks, last = self._bucketed(req.prompt)
        if self.mesh is None:
            logits, self._cache = _prefill_slot_fused(
                self.model.prefill, self.params, self._cache, toks[None],
                np.int32(slot), jnp.int32(last))
            return logits
        part = dict(_take_slot(self._cache, np.int32(slot)),
                    pos=jnp.int32(0))
        logits, part = self._prefill(self.params, toks[None], part,
                                     jnp.int32(last))
        part.pop("pos")
        self._cache = _put_slot(self._cache, part, np.int32(slot))
        return logits

    def _admit(self) -> None:
        while self._free and self._queue:
            head = self._queue[0]
            if self.paged and not self.tables.can_admit(
                    len(head.prompt) + head.max_new_tokens):
                break                       # head-of-line wait (stays FIFO)
            slot = min(self._free)          # deterministic: lowest free slot
            self._free.remove(slot)
            req = self._queue.popleft()
            p = len(req.prompt)
            if self.paged:
                self.tables.admit(slot, p,
                                  budget_tokens=p + req.max_new_tokens)
                logits = self._prefill_paged(req, slot)
            else:
                logits = self._prefill_slot(req, slot)
            self._pos[slot] = p
            tok = int(np.argmax(np.asarray(logits[0, -1])))
            rec = _Active(req, slot, [tok], self.step_count,
                          time.time() - req.submit_time)
            self.metrics["generated_tokens"] += 1
            self.events.append(("admit", req.rid, slot, self.step_count))
            if self._finished(rec):
                self._retire(rec)
            else:
                self._active[slot] = rec

    def _finished(self, rec: _Active) -> bool:
        return (len(rec.generated) >= rec.req.max_new_tokens
                or rec.generated[-1] == self.eos_id)

    def _retire(self, rec: _Active) -> None:
        rid = rec.req.rid
        if rid in self.results:
            raise RuntimeError(f"request {rid} retired twice")
        self.results[rid] = RequestResult(
            rid=rid,
            tokens=np.concatenate([rec.req.prompt,
                                   np.asarray(rec.generated, np.int32)]),
            prompt_len=len(rec.req.prompt),
            ttft_s=rec.ttft_s,
            admit_step=rec.admit_step,
            retire_step=self.step_count,
        )
        self.events.append(("retire", rid, rec.slot, self.step_count))
        self._active.pop(rec.slot, None)
        self._pos[rec.slot] = 0       # free slots idle at position 0
        if self.paged:
            self.tables.release(rec.slot)
        self._free.append(rec.slot)

    # --------------------------------------------------------------- step

    def resident_kv_bytes(self) -> int:
        """KV bytes actually reserved for live sequences: allocated pages
        (paged) or the whole slot allocation (contiguous — every slot
        reserves max_len rows up front regardless of use)."""
        if self.paged:
            return self.pool.in_use * self._page_bytes
        return sum(v.nbytes for v in self._cache.values())

    def step(self) -> dict:
        """One admit + batched-decode + retire cycle; returns step stats."""
        self._admit()
        self.metrics["queue_depth"].append(len(self._queue))
        occ = len(self._active) / self.n_slots
        self.metrics["occupancy"].append(occ)
        if self._active:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for slot, rec in self._active.items():
                toks[slot, 0] = rec.generated[-1]
                if self.paged:   # a new page the instant pos crosses one
                    self.tables.ensure(slot, int(self._pos[slot]))
        # sampled after this step's page growth so the mean/peak include
        # the pages the decode write below is about to land in
        self.metrics["resident_kv_bytes"].append(self.resident_kv_bytes())
        if self._active:
            cache = dict(self._cache, pos=jnp.asarray(self._pos))
            if self.paged:
                cache["page_table"] = jnp.asarray(self.tables.table)
            logits, cache = self._decode(self.params, jnp.asarray(toks),
                                         cache)
            cache.pop("pos")
            cache.pop("page_table", None)
            self._cache = cache
            logits = np.asarray(logits)
            self.metrics["decode_steps"] += 1
            for slot, rec in list(self._active.items()):
                self._pos[slot] += 1          # the fed token was cached
                rec.generated.append(int(np.argmax(logits[slot, -1])))
                self.metrics["generated_tokens"] += 1
                if self._finished(rec):
                    self._retire(rec)
        self.step_count += 1
        return {"queue_depth": self.metrics["queue_depth"][-1],
                "occupancy": occ, "active": len(self._active)}

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active

    def run(self, requests=None) -> dict[int, RequestResult]:
        """Submit ``requests`` (dicts with tokens/max_new_tokens, see
        ``repro.data.request_workload``) and step until drained."""
        for r in requests or ():
            self.submit(r["tokens"], r["max_new_tokens"], rid=r.get("rid"))
        t0 = time.time()
        while not self.idle:
            self.step()
        self.metrics["wall_s"] = time.time() - t0
        return self.results

    # ------------------------------------------------------------ metrics

    def summary(self) -> dict:
        m = self.metrics
        ttfts = [r.ttft_s for r in self.results.values()]
        return {
            "n_requests": len(self.results),
            "n_slots": self.n_slots,
            "steps": self.step_count,
            "decode_steps": m["decode_steps"],
            "generated_tokens": m["generated_tokens"],
            "wall_s": m.get("wall_s", 0.0),
            "tok_per_s": (m["generated_tokens"] / m["wall_s"]
                          if m.get("wall_s") else 0.0),
            "ttft_s_mean": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_s_max": float(np.max(ttfts)) if ttfts else 0.0,
            "occupancy_mean": (float(np.mean(m["occupancy"]))
                               if m["occupancy"] else 0.0),
            "queue_depth_max": (int(np.max(m["queue_depth"]))
                                if m["queue_depth"] else 0),
            "quantized_kv": self.quantized_kv,
            "paged": self.paged,
            "kv_capacity_bytes": sum(v.nbytes for v in self._cache.values()),
            "resident_kv_bytes_mean": (float(np.mean(
                m["resident_kv_bytes"])) if m["resident_kv_bytes"] else 0),
            "resident_kv_bytes_peak": (int(np.max(m["resident_kv_bytes"]))
                                       if m["resident_kv_bytes"] else 0),
            **({"page_size": self.pool.page_size,
                "n_pages": self.pool.n_pages,
                "pages_peak": self.pool.peak_in_use,
                "prefill_chunk": self.prefill_chunk} if self.paged else {}),
            "mesh": (dict(self.mesh.shape) if self.mesh is not None
                     else None),
        }
