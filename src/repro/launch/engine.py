"""Continuous-batching serve engine over a slot-allocated quantized KV cache.

Requests with arbitrary prompt lengths enter a FIFO queue. The engine owns
a shared KV cache of ``n_slots`` independent sequence rows (int8 codes +
per-token scales when the config sets ``kv_quant_bits``, bf16/f32
otherwise). Each engine step interleaves:

  1. **admit** — while a slot is free and the queue is non-empty, pop the
     oldest request, prefill it alone (batch-1) against its slot's cache
     rows, and emit its first token from the prefill logits (TTFT).
  2. **decode** — one batched greedy decode step over *all* occupied
     slots at once; every slot sits at its own sequence position, so the
     cache write and RoPE/attention run with per-slot position vectors
     (``models.layers.cache_update*`` with (B,) ``pos``).
  3. **retire** — slots whose request hit ``max_new_tokens`` (or the
     optional ``eos_id``) return their result and go back on the free
     list; the next queued request is admitted on the following step.

Slot reuse needs no cache zeroing: a new occupant's prefill overwrites
rows [0, P) and every stale row beyond the slot's position is masked by
the causal (position >= kv position) test inside ``chunked_attention``.

Decode always runs the full ``n_slots`` batch (free slots carry a dummy
token at position 0 whose output is discarded) so the decode step compiles
exactly once; prefill compiles once per distinct prompt length — keep the
workload's length set small or bucket lengths upstream when compile time
matters. See ``src/repro/launch/README.md`` for the architecture diagram.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


# ----------------------------------------------------------- request types

@dataclasses.dataclass
class Request:
    """One generation request: ``prompt`` (P,) int32, decode budget."""
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    submit_time: float = 0.0


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray            # (P + G,) prompt followed by G generated
    prompt_len: int
    ttft_s: float                 # submit -> first token (prefill) latency
    admit_step: int
    retire_step: int


@dataclasses.dataclass
class _Active:
    req: Request
    slot: int
    generated: list
    admit_step: int
    ttft_s: float


# ------------------------------------------------------------- jit helpers

@functools.lru_cache(maxsize=8)
def jitted_model_fns(model):
    """(jit prefill, jit decode) cached per model so repeated engine /
    oracle runs over the same model share compilations."""
    return jax.jit(model.prefill), jax.jit(model.decode)


@jax.jit
def _take_slot(cache, slot):
    """Slice one slot's batch-1 cache out of the shared (L, n_slots, ...)
    arrays (leaf layout: layer axis 0, slot axis 1)."""
    return jax.tree.map(
        lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1), cache)


# Donating the shared cache lets XLA write the slot rows in place on
# backends with buffer donation (TPU); CPU falls back to a copy. A full
# take/put round trip per admission is still O(cache) HBM traffic — if
# admission ever dominates, prefill directly into the shared cache via
# the per-slot _write_kv machinery instead.
@functools.partial(jax.jit, donate_argnums=(0,))
def _put_slot(cache, part, slot):
    return jax.tree.map(
        lambda a, p: jax.lax.dynamic_update_slice_in_dim(a, p, slot, axis=1),
        cache, part)


# ------------------------------------------------------------------ engine

class ServeEngine:
    """Continuous-batching greedy-decode engine (see module docstring).

    ``model``/``params`` follow ``repro.models.Model``; the model family
    must support per-slot position vectors in its decode cache (dense
    does). ``max_len`` bounds prompt + generated tokens per slot.
    """

    _SLOT_FAMILIES = ("dense", "moe", "vlm")   # families with (B,) pos decode

    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 mesh=None, tp_axis: str = "model",
                 tp_mode: str = "gather", tp_kernels: bool = False):
        family = getattr(model.cfg, "family", "dense")
        if family not in self._SLOT_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine needs per-slot position vectors in decode, "
                f"implemented for {self._SLOT_FAMILIES}; got family "
                f"{family!r}")
        self.model, self.params = model, params
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        cache = model.init_cache(n_slots, max_len)
        self.quantized_kv = "k_scale" in cache
        self._cache = {k: v for k, v in cache.items() if k != "pos"}
        self._pos = np.zeros((n_slots,), np.int32)     # per-slot positions
        self._free = list(range(n_slots))
        self._queue: deque[Request] = deque()
        self._active: dict[int, _Active] = {}          # slot -> request
        self.mesh = mesh
        if mesh is None:
            self._prefill, self._decode = jitted_model_fns(model)
        else:
            self._init_mesh_fns(mesh, tp_axis, tp_mode, tp_kernels)
        self.step_count = 0
        self._next_rid = 0
        self.events: list[tuple] = []   # ("admit"|"retire", rid, slot, step)
        self.results: dict[int, RequestResult] = {}
        self.metrics = {"queue_depth": [], "occupancy": [],
                        "generated_tokens": 0, "decode_steps": 0}

    # -------------------------------------------------------- mesh serving

    def _init_mesh_fns(self, mesh, tp_axis: str, tp_mode: str,
                       tp_kernels: bool) -> None:
        """Tensor-parallel serving: params and the shared slot KV cache
        are device_put with quantization-aware shardings
        (``distributed.sharding.tp_param_specs`` / ``tp_cache_specs``) and
        prefill/decode run the TP forward inside shard_map. Slot
        bookkeeping (queue, free list, positions) stays host-side and is
        identical to the single-device engine; in ``tp_mode="gather"``
        (default) the decoded tokens are bit-identical to it too."""
        from jax.sharding import PartitionSpec as P

        from repro.core.qlinear import iter_qlinear
        from repro.distributed.compat import shard_map
        from repro.distributed import sharding as shlib

        cfg = self.model.cfg
        if cfg.n_experts:
            raise NotImplementedError("mesh serving covers the dense "
                                      "(non-MoE) family")
        tp = mesh.shape[tp_axis]
        packed = any(l.packed for _, l in iter_qlinear(self.params))
        unit = 2 * tp if (packed and tp_mode == "psum") else tp
        for dim, name in ((cfg.n_heads, "n_heads"),
                          (cfg.n_kv_heads, "n_kv_heads")):
            if dim % tp:
                raise ValueError(
                    f"{name}={dim} must divide by {tp_axis}={tp} (whole "
                    f"heads per shard)")
        for dim, name in ((cfg.q_dim, "q_dim"), (cfg.d_ff, "d_ff")):
            if dim % unit:
                raise ValueError(
                    f"{name}={dim} must divide by {unit} "
                    f"({tp_axis}={tp}"
                    + (", ×2: int4-packed row shards hold whole bytes)"
                       if unit != tp else ")"))
        dp_axis = next((a for a in ("data", "pod")
                        if a in mesh.axis_names
                        and self.n_slots % mesh.shape[a] == 0
                        and mesh.shape[a] > 1), None)

        pspecs = shlib.tp_param_specs(self.params, mesh, axis=tp_axis,
                                      cfg=cfg, row_mode=tp_mode)
        dec_cspecs = shlib.tp_cache_specs(self._cache, mesh, axis=tp_axis,
                                          dp_axis=dp_axis)
        part_shapes = jax.eval_shape(
            lambda c: jax.tree.map(lambda a: a[:, :1], c), self._cache)
        pre_cspecs = shlib.tp_cache_specs(part_shapes, mesh, axis=tp_axis)
        self.params = jax.device_put(self.params, shlib.named(pspecs, mesh))
        self._cache = jax.device_put(self._cache,
                                     shlib.named(dec_cspecs, mesh))
        tok_spec = P(dp_axis, None)
        # the (B,) per-slot position vector shards with the slot axis
        pos_spec = P(dp_axis) if dp_axis else P()
        tp_kw = dict(tp_axis=tp_axis, tp_mode=tp_mode, tp_kernels=tp_kernels)
        model = self.model

        def pre(p, t, c):
            return model.prefill(p, t, c, **tp_kw)

        def dec(p, t, c):
            return model.decode(p, t, c, **tp_kw)

        self._prefill = jax.jit(shard_map(
            pre, mesh=mesh,
            in_specs=(pspecs, P(None, None), dict(pre_cspecs, pos=P())),
            out_specs=(P(None, None, None), dict(pre_cspecs, pos=P())),
            check_vma=False))
        self._decode = jax.jit(shard_map(
            dec, mesh=mesh,
            in_specs=(pspecs, tok_spec, dict(dec_cspecs, pos=pos_spec)),
            out_specs=(P(dp_axis, None, None),
                       dict(dec_cspecs, pos=pos_spec)),
            check_vma=False))

    # ------------------------------------------------------------- intake

    def submit(self, prompt, max_new_tokens: int, rid: Optional[int] = None
               ) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not len(prompt):
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) "
                f"exceeds max_len={self.max_len}")
        if rid is None:
            rid = self._next_rid
        elif (rid in self.results
              or any(r.req.rid == rid for r in self._active.values())
              or any(r.rid == rid for r in self._queue)):
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid) + 1
        self._queue.append(Request(rid, prompt, max_new_tokens,
                                   submit_time=time.time()))
        return rid

    # ------------------------------------------------------ slot lifecycle

    def _admit(self) -> None:
        while self._free and self._queue:
            slot = min(self._free)          # deterministic: lowest free slot
            self._free.remove(slot)
            req = self._queue.popleft()
            p = len(req.prompt)
            part = dict(_take_slot(self._cache, np.int32(slot)),
                        pos=jnp.int32(0))
            logits, part = self._prefill(self.params, req.prompt[None], part)
            part.pop("pos")
            self._cache = _put_slot(self._cache, part, np.int32(slot))
            self._pos[slot] = p
            tok = int(np.argmax(np.asarray(logits[0, -1])))
            rec = _Active(req, slot, [tok], self.step_count,
                          time.time() - req.submit_time)
            self.metrics["generated_tokens"] += 1
            self.events.append(("admit", req.rid, slot, self.step_count))
            if self._finished(rec):
                self._retire(rec)
            else:
                self._active[slot] = rec

    def _finished(self, rec: _Active) -> bool:
        return (len(rec.generated) >= rec.req.max_new_tokens
                or rec.generated[-1] == self.eos_id)

    def _retire(self, rec: _Active) -> None:
        rid = rec.req.rid
        if rid in self.results:
            raise RuntimeError(f"request {rid} retired twice")
        self.results[rid] = RequestResult(
            rid=rid,
            tokens=np.concatenate([rec.req.prompt,
                                   np.asarray(rec.generated, np.int32)]),
            prompt_len=len(rec.req.prompt),
            ttft_s=rec.ttft_s,
            admit_step=rec.admit_step,
            retire_step=self.step_count,
        )
        self.events.append(("retire", rid, rec.slot, self.step_count))
        self._active.pop(rec.slot, None)
        self._pos[rec.slot] = 0       # free slots idle at position 0
        self._free.append(rec.slot)

    # --------------------------------------------------------------- step

    def step(self) -> dict:
        """One admit + batched-decode + retire cycle; returns step stats."""
        self._admit()
        self.metrics["queue_depth"].append(len(self._queue))
        occ = len(self._active) / self.n_slots
        self.metrics["occupancy"].append(occ)
        if self._active:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for slot, rec in self._active.items():
                toks[slot, 0] = rec.generated[-1]
            cache = dict(self._cache, pos=jnp.asarray(self._pos))
            logits, cache = self._decode(self.params, jnp.asarray(toks),
                                         cache)
            cache.pop("pos")
            self._cache = cache
            logits = np.asarray(logits)
            self.metrics["decode_steps"] += 1
            for slot, rec in list(self._active.items()):
                self._pos[slot] += 1          # the fed token was cached
                rec.generated.append(int(np.argmax(logits[slot, -1])))
                self.metrics["generated_tokens"] += 1
                if self._finished(rec):
                    self._retire(rec)
        self.step_count += 1
        return {"queue_depth": self.metrics["queue_depth"][-1],
                "occupancy": occ, "active": len(self._active)}

    @property
    def idle(self) -> bool:
        return not self._queue and not self._active

    def run(self, requests=None) -> dict[int, RequestResult]:
        """Submit ``requests`` (dicts with tokens/max_new_tokens, see
        ``repro.data.request_workload``) and step until drained."""
        for r in requests or ():
            self.submit(r["tokens"], r["max_new_tokens"], rid=r.get("rid"))
        t0 = time.time()
        while not self.idle:
            self.step()
        self.metrics["wall_s"] = time.time() - t0
        return self.results

    # ------------------------------------------------------------ metrics

    def summary(self) -> dict:
        m = self.metrics
        ttfts = [r.ttft_s for r in self.results.values()]
        return {
            "n_requests": len(self.results),
            "n_slots": self.n_slots,
            "steps": self.step_count,
            "decode_steps": m["decode_steps"],
            "generated_tokens": m["generated_tokens"],
            "wall_s": m.get("wall_s", 0.0),
            "tok_per_s": (m["generated_tokens"] / m["wall_s"]
                          if m.get("wall_s") else 0.0),
            "ttft_s_mean": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_s_max": float(np.max(ttfts)) if ttfts else 0.0,
            "occupancy_mean": (float(np.mean(m["occupancy"]))
                               if m["occupancy"] else 0.0),
            "queue_depth_max": (int(np.max(m["queue_depth"]))
                                if m["queue_depth"] else 0),
            "quantized_kv": self.quantized_kv,
            "mesh": (dict(self.mesh.shape) if self.mesh is not None
                     else None),
        }
