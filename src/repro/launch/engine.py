"""Continuous-batching serve engine over a slot-allocated quantized KV cache.

``ServeEngine`` is a thin façade over two modules:

- ``repro.launch.scheduler`` — host-side policy: request queue, slot
  lifecycle, and (in unified mode) the token-budget planner;
- ``repro.launch.executor`` — device side: jitted/shard_mapped prefill,
  decode, and the unified ragged step, with donated caches.

Two scheduling modes share the same API, caches, and metrics:

**legacy** (default, the historical engine — and the oracle the unified
mode is golden-tested against). Each step interleaves:

  1. **admit** — while a slot is free and the queue is non-empty, pop the
     oldest request, prefill it alone (batch-1) against its slot's cache
     rows, and emit its first token from the prefill logits (TTFT).
  2. **decode** — one batched greedy decode step over *all* occupied
     slots at once; every slot sits at its own sequence position, so the
     cache write and RoPE/attention run with per-slot position vectors
     (``models.layers.cache_update*`` with (B,) ``pos``).
  3. **retire** — slots whose request hit ``max_new_tokens`` (or the
     optional ``eos_id``) return their result and go back on the free
     list; the next queued request is admitted on the following step.

Slot reuse needs no cache zeroing: a new occupant's prefill overwrites
rows [0, P) and every stale row beyond the slot's position is masked by
the causal (position >= kv position) test inside ``chunked_attention``.
Decode always runs the full ``n_slots`` batch (free slots carry a dummy
token at position 0 whose output is discarded) so the decode step
compiles exactly once; prefill compile count is tamed by pow-2
**bucketing** (default) or fixed-size **chunked prefill**
(``prefill_chunk=C``, paged mode).

Legacy's weakness is head-of-line coupling: prefill-on-admit runs as its
own dispatch(es) *before* the decode step, so a long admission stalls
every in-flight decode (TTFT work blocks ITL).

**unified** (``schedule="unified"``) removes that coupling with a
vLLM-style token budget: each step the scheduler packs up to
``max_batch_tokens`` of work — one decode token per running slot plus
prefill *chunks* for admitting ones — into ONE ragged model invocation
(``models.dense.ragged_step``) against the paged KV pool. Long prompts
spread across steps instead of stalling them, decode tokens ride in
every step, and the fixed packing width gives O(1) step compile shapes.
Decoded tokens are **bitwise identical** to legacy (the per-row numerics
are unchanged; the golden fixtures run against both modes).

``paged=True`` (implied by unified) swaps the slot-contiguous cache for
a **paged KV pool** (``repro.launch.paged``): fixed-size pages allocated
lazily as sequences grow, per-slot page tables gathered on device,
token-identical output to the slot cache. See
``src/repro/launch/README.md`` for diagrams and the pool sizing formula.
"""
from __future__ import annotations

import dataclasses
import os
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

# Re-exported for backward compatibility: these historically lived here.
from repro.launch.executor import (LegacyExecutor, RaggedExecutor,
                                   jitted_model_fns)  # noqa: F401
from repro.launch.scheduler import (Request, RequestResult, SeqState,
                                    TokenBudgetScheduler)


@dataclasses.dataclass
class _Active:
    """Legacy-mode per-slot record (unified mode uses ``SeqState``)."""
    req: Request
    slot: int
    generated: list
    admit_step: int
    ttft_s: float


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unobserved pipelined step: its plan, the
    device token array its observation will fetch, and whether the
    token vector is slot-major (fused decode fast path) or consumer-row
    major (ragged step)."""
    plan: object
    toks: object
    slot_major: bool


# ------------------------------------------------------------------ engine

class ServeEngine:
    """Continuous-batching greedy-decode engine (see module docstring).

    ``model``/``params`` follow ``repro.models.Model``; the model family
    must support per-slot position vectors in its decode cache (dense
    does). ``max_len`` bounds prompt + generated tokens per slot.
    """

    _SLOT_FAMILIES = ("dense", "moe", "vlm")   # families with (B,) pos decode

    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 mesh=None, tp_axis: str = "model",
                 tp_mode: str = "gather", tp_kernels: bool = False,
                 paged: bool = False, page_size: int = 16,
                 prefill_chunk: int = 0, n_pages: int = 0,
                 bucket: bool = True, paged_kernel: bool = False,
                 schedule: str = "legacy", max_batch_tokens: int = 0,
                 fused: bool = True, prefix_cache: bool = False,
                 speculative_k: int = 0, draft=None,
                 adaptive_spec: bool = False,
                 pipeline: Optional[bool] = None):
        family = getattr(model.cfg, "family", "dense")
        if family not in self._SLOT_FAMILIES:
            raise NotImplementedError(
                f"ServeEngine needs per-slot position vectors in decode, "
                f"implemented for {self._SLOT_FAMILIES}; got family "
                f"{family!r}")
        if schedule not in ("legacy", "unified"):
            raise ValueError(f"schedule must be 'legacy' or 'unified', "
                             f"got {schedule!r}")
        if speculative_k < 0:
            raise ValueError(
                f"speculative_k must be >= 0, got {speculative_k}")
        if speculative_k and schedule != "unified":
            raise ValueError(
                "speculative_k needs schedule='unified' (the draft/verify "
                "cycle runs inside the token-budgeted ragged step)")
        if speculative_k and draft is None:
            raise ValueError(
                "speculative_k needs draft=(draft_model, draft_params) — "
                "e.g. the int4-packed quantization of the target "
                "checkpoint (launch.serve.build_draft_model)")
        if adaptive_spec and not speculative_k:
            raise ValueError("adaptive_spec needs speculative_k > 0 "
                             "(it tunes the per-slot draft depth)")
        self.spec_k = int(speculative_k)
        # Pipelined (depth-1 asynchronous) unified loop: pack + dispatch
        # step N+1 while step N executes, observe step N's device-
        # resident tokens afterwards. Default ON for unified serving;
        # REPRO_SYNC_STEP=1 forces the synchronous loop (honest blocked
        # per-step timing spans for profiling).
        if pipeline is None:
            pipeline = (schedule == "unified"
                        and not os.environ.get("REPRO_SYNC_STEP"))
        if pipeline and schedule != "unified":
            raise ValueError("pipeline=True needs schedule='unified' "
                             "(legacy prefill-on-admit is inherently "
                             "synchronous); pass pipeline=False or None")
        self.pipeline = bool(pipeline)
        self._inflight: Optional[_InFlight] = None
        self._host_s = 0.0      # host-side planning/pack/observe seconds
        self._hidden_s = 0.0    # ... of which spent while a step was in
        #                         flight on device (the overlap win)
        if schedule == "unified":
            paged = True    # the unified step serves from the paged pool
        elif max_batch_tokens:
            raise ValueError("max_batch_tokens needs schedule='unified' "
                             "(legacy packs per-slot, not per-token)")
        self.model = model
        self.schedule = schedule
        self.n_slots, self.max_len, self.eos_id = n_slots, max_len, eos_id
        self.paged, self.bucket = paged, bucket
        self.prefill_chunk, self.paged_kernel = prefill_chunk, paged_kernel
        if paged:
            from repro.launch.paged import PagePool, SlotPageTables
            from repro.models.layers import KV_QUANT_GROUP
            if getattr(model, "init_paged_cache", None) is None:
                raise NotImplementedError(
                    f"family {family!r} has no paged KV cache")
            if page_size < 1:
                raise ValueError(f"page_size must be >= 1, got {page_size}")
            if prefill_chunk < 0:
                raise ValueError(
                    f"prefill_chunk must be >= 0, got {prefill_chunk}")
            if model.cfg.kv_quant_bits and page_size % KV_QUANT_GROUP:
                raise ValueError(
                    f"page_size={page_size} must be a multiple of the KV "
                    f"quant scale group ({KV_QUANT_GROUP})")
            if prefill_chunk and prefill_chunk % page_size \
                    and schedule == "legacy":
                # unified chunks are budget-sliced scatter writes, free of
                # page alignment; legacy chunks must write whole pages
                raise ValueError(
                    f"prefill_chunk={prefill_chunk} must be a multiple of "
                    f"page_size={page_size} (chunks write whole pages)")
            # logical rows per slot, rounded up to whole pages; a
            # speculative verify writes up to spec_k rows past the last
            # decode position, so the table covers max_len + spec_k
            self._kv_len = (-(-(max_len + self.spec_k) // page_size)
                            * page_size)
            n_ptab = self._kv_len // page_size
            n_pages = n_pages or 1 + n_slots * n_ptab  # worst case + null
            self.pool = PagePool(n_pages, page_size)
            self.tables = SlotPageTables(self.pool, n_slots, n_ptab)
            self.prefix = None
            if prefix_cache:
                # automatic prefix caching: COW page sharing across
                # requests (launch.paged.PrefixCache). The config digest
                # keys the trie so pages can never cross quantization
                # configs.
                from repro.launch.paged import PrefixCache
                cfg = model.cfg
                self.prefix = PrefixCache(
                    self.pool, page_size,
                    config_key=(family, cfg.n_layers, cfg.n_kv_heads,
                                cfg.head_dim, cfg.kv_quant_bits,
                                str(getattr(cfg, "dtype", "?"))))
            cache = model.init_paged_cache(n_pages, page_size)
            cache = dict(cache)
            # Speculative decoding: the draft model's KV lives in a
            # PARALLEL quantized pool with identical geometry, behind its
            # own tables (no prefix sharing — draft pages are always
            # private), admitted/grown/shrunk/released in lockstep with
            # the target tables by the scheduler.
            self.draft_pool = self.draft_tables = None
            draft_exec = None
            if self.spec_k:
                draft_model, draft_params = draft
                if getattr(draft_model, "init_paged_cache", None) is None \
                        or draft_model.ragged_step is None:
                    raise NotImplementedError(
                        "the draft model needs paged-cache + ragged-step "
                        "support (family "
                        f"{getattr(draft_model.cfg, 'family', '?')!r})")
                if draft_model.cfg.vocab != model.cfg.vocab:
                    raise ValueError(
                        f"draft vocab {draft_model.cfg.vocab} != target "
                        f"vocab {model.cfg.vocab} — drafted token ids "
                        f"must be target token ids")
                self.draft_pool = PagePool(n_pages, page_size)
                self.draft_tables = SlotPageTables(self.draft_pool,
                                                   n_slots, n_ptab)
                dmsp = getattr(draft_model, "make_serving_params", None)
                if fused and dmsp is not None:
                    # the draft always runs single-device plain jit (even
                    # under a mesh), so it can always take the fused path
                    draft_params = dmsp(draft_params)
                draft_cache = dict(
                    draft_model.init_paged_cache(n_pages, page_size))
                draft_exec = (draft_model, draft_params, draft_cache)
        else:
            if prefix_cache:
                raise ValueError("prefix_cache needs paged=True (cached "
                                 "prefixes are shared pool pages)")
            self.prefix = None
            self.draft_pool = self.draft_tables = None
            draft_exec = None
            if prefill_chunk:
                raise ValueError("prefill_chunk needs paged=True (the slot "
                                 "cache keeps whole-prompt prefill; use "
                                 "bucket=True to bound its compile count)")
            if paged_kernel:
                raise ValueError("paged_kernel needs paged=True")
            self._kv_len = max_len
            cache = model.init_cache(n_slots, max_len)
            cache = {k: v for k, v in cache.items() if k != "pos"}
        self.quantized_kv = "k_scale" in cache
        self._page_bytes = (sum(v.nbytes for v in cache.values())
                            // n_pages if paged else 0)
        self.mesh = mesh
        # Fused serving params (QKV / gate-up concat + integer-epilogue
        # colsums, models.Model.make_serving_params): single-device hot
        # path only — the concatenated output dim would split unevenly
        # across tensor-parallel head shards. Token-identical to the
        # unfused params (golden-tested), so on by default.
        msp = getattr(model, "make_serving_params", None)
        self.fused = bool(fused and msp is not None and mesh is None)
        if self.fused:
            params = msp(params)
        tp_kw = dict(mesh=mesh, tp_axis=tp_axis, tp_mode=tp_mode,
                     tp_kernels=tp_kernels)
        if schedule == "unified":
            # speculative mode packs k+1 verify rows per decoding slot,
            # so the default budget scales with the spec width and an
            # explicit budget must still fit every slot's verify item
            self.max_batch_tokens = max_batch_tokens or max(
                16, 2 * n_slots, n_slots * (self.spec_k + 2))
            if self.max_batch_tokens < n_slots * (self.spec_k + 1):
                raise ValueError(
                    f"max_batch_tokens={self.max_batch_tokens} must be >= "
                    f"n_slots*(speculative_k+1)="
                    f"{n_slots * (self.spec_k + 1)} (every decoding slot "
                    f"packs speculative_k+1 verify rows per step)")
            self.sched = TokenBudgetScheduler(
                n_slots, self.max_batch_tokens, pool=self.pool,
                tables=self.tables, prefill_chunk=prefill_chunk,
                eos_id=eos_id, prefix=self.prefix, spec_k=self.spec_k,
                draft_tables=self.draft_tables,
                adaptive_spec=adaptive_spec)
            # XLA:CPU executes donated computations synchronously in the
            # dispatching thread, which would re-serialize the pipelined
            # loop — a pipelined engine on CPU trades the in-place cache
            # donation for asynchronous dispatch (one pool-sized output
            # buffer per step; REPRO_PIPELINE_DONATE=1 forces donation
            # back for memory profiling). Donation-capable accelerator
            # backends dispatch donated computations asynchronously, so
            # they keep the in-place update.
            donate = not (self.pipeline
                          and jax.default_backend() == "cpu"
                          and not os.environ.get("REPRO_PIPELINE_DONATE"))
            self.exec = RaggedExecutor(model, params, cache,
                                       n_slots=n_slots,
                                       paged_kernel=paged_kernel,
                                       draft=draft_exec,
                                       spec_k=self.spec_k,
                                       donate=donate, **tp_kw)
            # shared host state lives in the scheduler; alias it so the
            # introspection surface matches legacy mode
            self._queue = self.sched.queue
            self._free = self.sched.free
            self._active = self.sched.active
        else:
            self.max_batch_tokens = 0
            self.sched = None
            self.exec = LegacyExecutor(model, params, cache,
                                       n_slots=n_slots, paged=paged,
                                       paged_kernel=paged_kernel, **tp_kw)
            self._queue = deque()
            self._free = list(range(n_slots))
            self._active = {}          # slot -> _Active
        self.params = self.exec.params
        self._pos = np.zeros((n_slots,), np.int32)     # per-slot positions
        self.step_count = 0
        self._next_rid = 0
        self._dev_acc = 0.0             # device seconds within current step
        self.events: list[tuple] = []   # ("admit"|"retire", rid, slot, step)
        self.results: dict[int, RequestResult] = {}
        self.metrics = self._fresh_metrics()

    @staticmethod
    def _fresh_metrics() -> dict:
        return {"queue_depth": [], "occupancy": [],
                "resident_kv_bytes": [], "step_s": [], "device_s": [],
                "generated_tokens": 0, "decode_steps": 0}

    def reset(self) -> None:
        """Return an idle (drained) engine to its just-built state — fresh
        metrics, results, events, positions, and scheduler/executor
        counters — WITHOUT touching params, caches, or the jitted
        executables. This is the warmup/steady-state benchmark hook: run
        a workload once (pays every compile), ``reset()``, run it again
        and read pure steady-state timings. Stale KV content from the
        first run is harmless for exactly the reason slot reuse is: a
        new occupant's prefill overwrites its rows and everything past
        its position is causally masked."""
        if not self.idle:
            raise RuntimeError("reset() needs an idle engine "
                               "(drain the queue first)")
        self._pos[:] = 0
        self.step_count = 0
        self._next_rid = 0
        self._dev_acc = 0.0
        # pipelined state: idle implies nothing is in flight, but drop
        # it defensively (and forget the executor's previous-step token
        # vector + the descriptor-ring parity) so a stale step can never
        # leak into the next run — warmup reuse must start cold.
        self._inflight = None
        self._host_s = self._hidden_s = 0.0
        if getattr(self.exec, "reset_pipeline", None) is not None:
            self.exec.reset_pipeline()
        self.exec.d2h_s = 0.0
        self.events = []
        self.results = {}
        self.metrics = self._fresh_metrics()
        self.exec.n_dispatch = 0
        if self.schedule == "unified":
            self.sched.reset()
            self._free = self.sched.free    # sched.reset() rebinds its list
        else:
            self._free = list(range(self.n_slots))
        if self.paged:
            self.pool.peak_in_use = self.pool.in_use
        if self.draft_pool is not None:
            self.draft_pool.peak_in_use = self.draft_pool.in_use
        if self.prefix is not None:
            # a warm cache is server state (like compiled code): keep the
            # trie across warmup/steady resets, zero only the counters
            self.prefix.reset_stats()

    # The executor owns the device cache; expose it under the historical
    # name so engine code (and tests) read/write one source of truth.
    @property
    def _cache(self):
        return self.exec.cache

    @_cache.setter
    def _cache(self, value):
        self.exec.cache = value

    # ------------------------------------------------------------- intake

    def submit(self, prompt, max_new_tokens: int, rid: Optional[int] = None
               ) -> int:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if not len(prompt):
            raise ValueError("empty prompt")
        if max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, "
                             f"got {max_new_tokens}")
        if len(prompt) + max_new_tokens > self.max_len:
            raise ValueError(
                f"prompt({len(prompt)}) + max_new_tokens({max_new_tokens}) "
                f"exceeds max_len={self.max_len}")
        if self.paged:
            # +spec_k: speculative verify rows past the decode budget
            need = self.tables.pages_for(len(prompt) + max_new_tokens
                                         + self.spec_k)
            if need > self.pool.n_pages - 1:
                raise ValueError(
                    f"request needs {need} pages but the pool only has "
                    f"{self.pool.n_pages - 1} allocatable (raise n_pages "
                    f"or max_len/page_size)")
        if rid is None:
            rid = self._next_rid
        elif (rid in self.results
              or any(r.req.rid == rid for r in self._active.values())
              or any(r.rid == rid for r in self._queue)):
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid) + 1
        # monotonic clock: submit_time is only ever *differenced* against
        # later perf_counter() reads (TTFT) — wall clock (time.time) can
        # step under NTP and yield negative latencies
        self._queue.append(Request(rid, prompt, max_new_tokens,
                                   submit_time=time.perf_counter()))
        return rid

    # ---------------------------------------------- legacy slot lifecycle

    def _bucketed(self, prompt: np.ndarray):
        """Right-pad a prompt to its power-of-two bucket (compile-count
        discipline: O(log max_len) prefill shapes instead of one per
        distinct length). Returns (padded tokens, logits row index).
        Padded rows write garbage k/v past the prompt — causally masked,
        then overwritten by decode before they are ever attendable."""
        p = len(prompt)
        if not self.bucket:
            return prompt, p - 1
        width = max(8, 1 << (p - 1).bit_length())
        width = min(width, self._kv_len if self.paged else self.max_len)
        if width <= p:
            return prompt, p - 1
        return np.pad(prompt, (0, width - p)), p - 1

    def _prefill_paged(self, req: Request, slot: int, start: int = 0):
        """Prefill rows [start, P) into the slot's pages: one bucketed
        call, or fixed-size chunks at successive offsets (ONE compile
        total) when ``prefill_chunk`` is set. ``start > 0`` is the prefix
        cache's first-miss offset — rows [0, start) are already served by
        shared (or COW-copied) pages, so their prefill is skipped
        entirely; a chunked span starting mid-page writes only the rows
        past the COW boundary (numerically a suffix of the chunked
        schedule the golden fixtures already pin)."""
        p = len(req.prompt)
        row = jnp.asarray(self.tables.table[slot:slot + 1])
        chunk = self.prefill_chunk
        if not chunk:
            toks, last = self._bucketed(req.prompt[start:])
            spans = [(toks, start, last)]
        else:
            spans = []
            for off in range(start, p, chunk):
                toks = np.zeros((chunk,), np.int32)
                n = min(chunk, p - off)
                toks[:n] = req.prompt[off:off + n]
                spans.append((toks, off, int(np.clip(p - 1 - off, 0,
                                                     chunk - 1))))
        logits = None
        for toks, off, last in spans:
            logits, row = self.exec.prefill_paged_span(toks, row, off, last)
        return logits

    def _admit(self) -> None:
        while self._free and self._queue:
            head = self._queue[0]
            hit, pages = 0, []
            if self.paged:
                budget_tokens = len(head.prompt) + head.max_new_tokens
                if self.prefix is not None:
                    hit, pages = self.prefix.lookup(head.prompt)
                    ok = self.prefix.make_room(self.tables, budget_tokens,
                                               hit_tokens=hit,
                                               protect=pages)
                else:
                    ok = self.tables.can_admit(budget_tokens)
                if not ok:
                    break                   # head-of-line wait (stays FIFO)
            slot = min(self._free)          # deterministic: lowest free slot
            self._free.remove(slot)
            req = self._queue.popleft()
            p = len(req.prompt)
            td = time.perf_counter()
            if self.paged:
                self.tables.admit_prefix(slot, pages, hit, p,
                                         budget_tokens=p
                                         + req.max_new_tokens)
                if self.prefix is not None:
                    self.prefix.note(hit, p)
                    cow = self.tables.ensure_writable(slot, hit)
                    if cow:
                        self.prefix.cow_copies += len(cow)
                        self.exec.copy_pages(cow)
                self.tables.assert_writable(slot, hit, p - 1)
                logits = self._prefill_paged(req, slot, start=hit)
            else:
                toks, last = self._bucketed(req.prompt)
                logits = self.exec.prefill_slot(toks, slot, last)
            logits.block_until_ready()
            self._dev_acc += time.perf_counter() - td
            if self.prefix is not None:
                # prefill landed -> adopt the full prompt pages
                self.prefix.register(req.prompt,
                                     self.tables.owned_pages(slot))
            self._pos[slot] = p
            tok = int(np.argmax(np.asarray(logits[0, -1])))
            rec = _Active(req, slot, [tok], self.step_count,
                          time.perf_counter() - req.submit_time)
            self.metrics["generated_tokens"] += 1
            self.events.append(("admit", req.rid, slot, self.step_count))
            if self._finished(rec):
                self._retire(rec)
            else:
                self._active[slot] = rec

    def _finished(self, rec: _Active) -> bool:
        # mirrors TokenBudgetScheduler._finished: guard empty generated
        # and never let eos_id=None shadow a real token id
        if len(rec.generated) >= rec.req.max_new_tokens:
            return True
        return (self.eos_id is not None and bool(rec.generated)
                and rec.generated[-1] == self.eos_id)

    def _retire(self, rec: _Active) -> None:
        rid = rec.req.rid
        if rid in self.results:
            raise RuntimeError(f"request {rid} retired twice")
        self.results[rid] = RequestResult(
            rid=rid,
            tokens=np.concatenate([rec.req.prompt,
                                   np.asarray(rec.generated, np.int32)]),
            prompt_len=len(rec.req.prompt),
            ttft_s=rec.ttft_s,
            admit_step=rec.admit_step,
            retire_step=self.step_count,
        )
        self.events.append(("retire", rid, rec.slot, self.step_count))
        self._active.pop(rec.slot, None)
        self._pos[rec.slot] = 0       # free slots idle at position 0
        if self.paged:
            self.tables.release(rec.slot)
        self._free.append(rec.slot)

    # --------------------------------------------------------------- step

    def resident_kv_bytes(self) -> int:
        """KV bytes actually reserved for live sequences: allocated pages
        (paged) or the whole slot allocation (contiguous — every slot
        reserves max_len rows up front regardless of use). Reported in
        BOTH modes so slot-vs-paged benchmark rows compare like for
        like. With a prefix cache, pages shared across slots count once
        (the dedup win) and pages retained only by the cache don't count
        as live at all — cache retention is reported separately
        (``cached_kv_bytes`` in ``summary()``)."""
        if self.paged:
            n = (self.tables.slot_mapped_pages if self.prefix is not None
                 else self.pool.in_use)
            return n * self._page_bytes
        return sum(v.nbytes for v in self._cache.values())

    def step(self) -> dict:
        """One engine cycle; returns step stats. Legacy: admit (prefill
        dispatches) + one batched decode + retire. Unified: plan one
        token-budgeted ragged step, run it, feed tokens back, retire."""
        if self.schedule == "unified":
            return self._step_unified()
        t0 = time.perf_counter()
        self._dev_acc = 0.0
        events_before = len(self.events)
        self._admit()
        admitted = len(self.events) > events_before
        self.metrics["queue_depth"].append(len(self._queue))
        occ = len(self._active) / self.n_slots
        self.metrics["occupancy"].append(occ)
        if self._active:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for slot, rec in self._active.items():
                toks[slot, 0] = rec.generated[-1]
                if self.paged:   # a new page the instant pos crosses one
                    self.tables.ensure(slot, int(self._pos[slot]))
        # sampled after this step's page growth so the mean/peak include
        # the pages the decode write below is about to land in
        self.metrics["resident_kv_bytes"].append(self.resident_kv_bytes())
        if self._active:
            table = jnp.asarray(self.tables.table) if self.paged else None
            td = time.perf_counter()
            d2h0 = self.exec.d2h_s
            next_toks = self.exec.decode(toks, self._pos, table)
            # the decode span is compute-only: the executor's (tiny)
            # token D2H copy is attributed to d2h_s, not device time
            self._dev_acc += (time.perf_counter() - td
                              - (self.exec.d2h_s - d2h0))
            self.metrics["decode_steps"] += 1
            for slot, rec in list(self._active.items()):
                self._pos[slot] += 1          # the fed token was cached
                rec.generated.append(int(next_toks[slot]))
                self.metrics["generated_tokens"] += 1
                if self._finished(rec):
                    self._retire(rec)
        if admitted or occ > 0:
            self.metrics["step_s"].append(time.perf_counter() - t0)
            self.metrics["device_s"].append(self._dev_acc)
        self.step_count += 1
        return {"queue_depth": self.metrics["queue_depth"][-1],
                "occupancy": occ, "active": len(self._active)}

    def _step_unified(self) -> dict:
        if self.pipeline:
            return self._step_pipelined()
        return self._step_sync()

    def _plan_and_dispatch(self):
        """Shared front half of a unified cycle: plan, account metrics,
        run the draft cycle (speculative mode), and dispatch the packed
        step WITHOUT blocking. Returns (plan, in_flight) where in_flight
        is None for an empty plan."""
        plan = self.sched.plan(self.step_count)
        for rid, slot in plan.admitted:
            self.events.append(("admit", rid, slot, self.step_count))
        self.metrics["queue_depth"].append(len(self._queue))
        self.metrics["occupancy"].append(len(self._active) / self.n_slots)
        self.metrics["resident_kv_bytes"].append(self.resident_kv_bytes())
        if not plan.n_tokens:
            return plan, None
        if self.spec_k:
            # draft/verify cycle:
            # 1. mirror prefill chunks into the draft pool;
            # 2. ONE scan dispatch proposes k+1 tokens per slot;
            # 3. the target verifies all k+1 rows per slot in the
            #    ragged step below (greedy acceptance in observe()).
            # The draft fetch BLOCKS (acceptance packs host drafts), so
            # a pipelined speculative cycle overlaps only its pack +
            # observe host work with the in-flight target step.
            for dp in self.sched.pack_draft(plan):
                self.exec.draft_prefill(dp)
            if plan.spec:
                tok0, pos0, dtable, dsrc = self.sched.draft_inputs(plan)
                drafts = self.exec.draft_k(
                    tok0, pos0, dtable,
                    dsrc if self.pipeline else None)
                plan.spec_drafts = {
                    slot: drafts[:self.spec_k, slot]
                    for slot, _, _ in plan.spec}
        if (plan.decode and not plan.prefill and not plan.spec
                and not plan.cow and self.exec.supports_decode_step):
            # pure-decode fast path: slot-major compact batch, one
            # dispatch through model.decode (two Pallas launches per
            # layer when the fused prologue is enabled). Token-
            # identical to the ragged pack — single-row decode
            # through the unified step already matches legacy
            # model.decode bitwise (golden-tested), and this IS the
            # legacy decode call shape.
            tok, dpos, table, src = self.sched.pack_decode(plan)
            inf = _InFlight(plan,
                            self.exec.decode_step(tok, dpos, table, src),
                            True)
        else:
            packed = self.sched.pack(plan, kernel_desc=self.paged_kernel)
            if plan.cow:
                # COW page copies dispatch BEFORE the step so shared
                # content is duplicated before any divergent row lands
                self.exec.copy_pages(plan.cow)
            inf = _InFlight(plan, self.exec.step(packed), False)
        if plan.decode or plan.spec:
            self.metrics["decode_steps"] += 1
        return plan, inf

    def _observe_tokens(self, inf: _InFlight, toks: np.ndarray,
                        ahead=None) -> None:
        """Shared back half: feed a step's fetched tokens through the
        scheduler and retire what finished."""
        plan = inf.plan
        if inf.slot_major:
            # fused-decode vector is slot-indexed; consumers are decode
            # rows only (the fast path precondition)
            toks = toks[[slot for slot, _, _ in plan.decode]]
        gen_before = self.sched.gen_tokens
        retired = self.sched.observe(plan, toks, time.perf_counter(),
                                     ahead=ahead)
        # actual appended count (speculative steps emit 1..k+1 per
        # slot depending on acceptance — n_logits would overcount)
        self.metrics["generated_tokens"] += (self.sched.gen_tokens
                                             - gen_before)
        for seq in retired:
            self._retire_seq(seq)

    def _step_sync(self) -> dict:
        """The synchronous unified cycle (REPRO_SYNC_STEP /
        pipeline=False): dispatch, block, observe — the per-step device
        span is an honest blocked measurement."""
        t0 = time.perf_counter()
        plan, inf = self._plan_and_dispatch()
        if inf is not None:
            td = time.perf_counter()
            toks = np.asarray(jax.block_until_ready(inf.toks))
            dev_s = time.perf_counter() - td
            self._observe_tokens(inf, toks)
            self.metrics["step_s"].append(time.perf_counter() - t0)
            self.metrics["device_s"].append(dev_s)
        self.step_count += 1
        return {"queue_depth": self.metrics["queue_depth"][-1],
                "occupancy": self.metrics["occupancy"][-1],
                "active": len(self._active),
                "packed_tokens": plan.n_tokens}

    def _step_pipelined(self) -> dict:
        """The depth-1 asynchronous cycle: plan + pack + dispatch step N
        optimistically (decoding slots assumed to continue, fed tokens
        injected on device from step N-1's vector), THEN block on step
        N-1's (n_logits,) int32 tokens — the only D2H of the cycle —
        and observe them, rolling back step N's rows for any slot whose
        prediction failed (see ``TokenBudgetScheduler.observe``). All
        host work between the dispatch and the fetch is hidden under
        device compute; ``overlap_frac`` reports the hidden fraction.

        Timing spans: a (step_s, device_s) pair is appended only on
        cycles that OBSERVE a step, with device_s = the token-fetch
        wait — so span counts equal observed steps and device_s <=
        step_s still holds. REPRO_SYNC_STEP gives blocked spans
        instead."""
        t0 = time.perf_counter()
        prev = self._inflight
        self._inflight = None
        plan, inf = self._plan_and_dispatch()
        if inf is not None:
            self.sched.note_dispatch(inf.plan, slot_major=inf.slot_major)
        seg = time.perf_counter() - t0
        self._host_s += seg
        if prev is not None:
            self._hidden_s += seg       # packed under step N-1's compute
        observed = prev is not None
        if observed:
            tw = time.perf_counter()
            toks = np.asarray(jax.block_until_ready(prev.toks))
            wait_s = time.perf_counter() - tw
            t1 = time.perf_counter()
            self._observe_tokens(prev, toks,
                                 ahead=inf.plan if inf else None)
            seg = time.perf_counter() - t1
            self._host_s += seg
            if inf is not None:
                self._hidden_s += seg   # observed under step N's compute
        self._inflight = inf
        if observed:
            self.metrics["step_s"].append(time.perf_counter() - t0)
            self.metrics["device_s"].append(wait_s)
        self.step_count += 1
        return {"queue_depth": self.metrics["queue_depth"][-1],
                "occupancy": self.metrics["occupancy"][-1],
                "active": len(self._active),
                "packed_tokens": plan.n_tokens}

    def _retire_seq(self, seq: SeqState) -> None:
        """Unified-mode retirement bookkeeping (the scheduler already
        freed the slot and released its pages in ``observe``)."""
        rid = seq.req.rid
        if rid in self.results:
            raise RuntimeError(f"request {rid} retired twice")
        self.results[rid] = RequestResult(
            rid=rid,
            tokens=np.concatenate([seq.req.prompt,
                                   np.asarray(seq.generated, np.int32)]),
            prompt_len=seq.prompt_len,
            ttft_s=seq.ttft_s,
            admit_step=seq.admit_step,
            retire_step=self.step_count,
        )
        self.events.append(("retire", rid, seq.slot, self.step_count))

    @property
    def idle(self) -> bool:
        # a dispatched-but-unobserved pipelined step keeps the engine
        # non-idle even when every slot already retired (the final
        # in-flight plan can be all-stale after an eos mispredict — one
        # more drain cycle discards it)
        return (not self._queue and not self._active
                and self._inflight is None)

    def run(self, requests=None) -> dict[int, RequestResult]:
        """Submit ``requests`` (dicts with tokens/max_new_tokens, see
        ``repro.data.request_workload``) and step until drained."""
        for r in requests or ():
            self.submit(r["tokens"], r["max_new_tokens"], rid=r.get("rid"))
        t0 = time.perf_counter()
        while not self.idle:
            self.step()
        self.metrics["wall_s"] = time.perf_counter() - t0
        return self.results

    # ------------------------------------------------------------ metrics

    def summary(self) -> dict:
        m = self.metrics
        ttfts = [r.ttft_s for r in self.results.values()]
        # TTFT is a perf_counter difference end-to-end (submit ->
        # first-logit); negative means a clock regression crept back in
        assert all(t >= 0 for t in ttfts), \
            f"negative TTFT (non-monotonic clock?): {min(ttfts)}"
        step_s = m["step_s"]
        dev_s = m["device_s"]
        device_ms = 1e3 * float(np.mean(dev_s)) if dev_s else 0.0
        host_ms = (1e3 * float(np.mean(step_s)) - device_ms
                   if step_s else 0.0)
        return {
            "n_requests": len(self.results),
            "n_slots": self.n_slots,
            "steps": self.step_count,
            "decode_steps": m["decode_steps"],
            "generated_tokens": m["generated_tokens"],
            "wall_s": m.get("wall_s", 0.0),
            "tok_per_s": (m["generated_tokens"] / m["wall_s"]
                          if m.get("wall_s") else 0.0),
            "ttft_s_mean": float(np.mean(ttfts)) if ttfts else 0.0,
            "ttft_s_max": float(np.max(ttfts)) if ttfts else 0.0,
            # per-step latency percentiles: the inter-token latency a
            # decoding request observes (each step emits one token per
            # running slot; legacy admission prefills inflate the tail)
            "itl_p50_s": (float(np.percentile(step_s, 50))
                          if step_s else 0.0),
            "itl_p95_s": (float(np.percentile(step_s, 95))
                          if step_s else 0.0),
            "occupancy_mean": (float(np.mean(m["occupancy"]))
                               if m["occupancy"] else 0.0),
            "queue_depth_max": (int(np.max(m["queue_depth"]))
                                if m["queue_depth"] else 0),
            "quantized_kv": self.quantized_kv,
            "paged": self.paged,
            "schedule": self.schedule,
            "fused": self.fused,
            # hot-loop attribution: device vs host milliseconds per
            # timed step, and device dispatches per engine step
            "device_ms_mean": device_ms,
            "host_ms_mean": max(0.0, host_ms),
            "n_dispatch": self.exec.n_dispatch,
            "dispatch_per_step": (self.exec.n_dispatch
                                  / max(1, self.step_count)),
            # host-side dispatches amortized over emitted tokens — the
            # serving-level view of the two-launch decode work (device
            # kernel launches per dispatch are the roofline's column)
            "launches_per_token": (self.exec.n_dispatch
                                   / max(1, m["generated_tokens"])),
            "kv_capacity_bytes": sum(v.nbytes for v in self._cache.values()),
            "resident_kv_bytes_mean": (float(np.mean(
                m["resident_kv_bytes"])) if m["resident_kv_bytes"] else 0),
            "resident_kv_bytes_peak": (int(np.max(m["resident_kv_bytes"]))
                                       if m["resident_kv_bytes"] else 0),
            **({"page_size": self.pool.page_size,
                "n_pages": self.pool.n_pages,
                "pages_peak": self.pool.peak_in_use,
                "prefill_chunk": self.prefill_chunk,
                "prefix_cache": self.prefix is not None}
               if self.paged else {}),
            **({**self.prefix.stats(),
                "cached_kv_bytes": self.prefix.resident * self._page_bytes}
               if self.prefix is not None else {}),
            **({"max_batch_tokens": self.max_batch_tokens,
                # running counter, not a plan_log scan — the log is a
                # capped ring and may have evicted the peak step
                "packed_tokens_max": self.sched.packed_tokens_max,
                "pipeline": self.pipeline,
                # fraction of host planning/pack/observe seconds spent
                # while a step was in flight on device (1.0 = every host
                # cycle fully hidden under compute; 0.0 = synchronous)
                "overlap_frac": (self._hidden_s / self._host_s
                                 if self._host_s else 0.0),
                # mean hidden host milliseconds per observed step — the
                # absolute per-step latency the pipeline removes
                "host_ms_hidden": (1e3 * self._hidden_s / len(dev_s)
                                   if dev_s else 0.0),
                "mispredicts": self.sched.mispredicts}
               if self.schedule == "unified" else
               # legacy: the per-decode-step token D2H fetch, attributed
               # separately so device_ms_mean stays compute-only
               {"d2h_ms_mean": (1e3 * self.exec.d2h_s
                                / max(1, m["decode_steps"]))}),
            **({"speculative_k": self.spec_k,
                "adaptive_spec": self.sched.adaptive_spec,
                "spec_cycles": self.sched.spec_cycles,
                "spec_drafted_tokens": self.sched.spec_drafted,
                "spec_accepted_tokens": self.sched.spec_accepted,
                "spec_acceptance_rate": (
                    self.sched.spec_accepted / self.sched.spec_drafted
                    if self.sched.spec_drafted else 0.0),
                "draft_pages_peak": self.draft_pool.peak_in_use}
               if self.spec_k else {}),
            "mesh": (dict(self.mesh.shape) if self.mesh is not None
                     else None),
        }
