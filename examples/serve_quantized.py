"""End-to-end serving driver (the paper's deployment): batched requests
against a CAT-quantized model — prefill + continuous greedy decode,
fp-vs-quantized agreement stats and throughput.

    PYTHONPATH=src python examples/serve_quantized.py [--batch 4] [--gen 48]
"""
import argparse
import sys
sys.path.insert(0, ".")
sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_model
from repro.core.pipeline import QuantizeConfig, quantize_model
from repro.data import calibration_batches, make_batch
from repro.launch.serve import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    cfg, model, params = trained_model()
    print(f"serving {cfg.name} | batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")

    qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat", cat_block=64)
    qparams = quantize_model(
        model, params, qcfg,
        calibration_batches(cfg, n_seqs=16, seq_len=128, batch=4))

    prompts = jnp.asarray(make_batch(cfg, args.prompt_len, args.batch,
                                     seed=11)["tokens"])
    max_len = args.prompt_len + args.gen + 8

    import time
    outs = {}
    for nm, p in (("fp", params), ("cat-w4a4", qparams)):
        t0 = time.time()
        toks = greedy_generate(model, p, prompts, args.gen, max_len)
        toks.block_until_ready()
        dt = time.time() - t0
        outs[nm] = np.asarray(toks)
        print(f"  {nm:10s} {args.batch*args.gen/dt:7.1f} tok/s "
              f"({dt:.2f}s incl. compile)")

    gen_fp = outs["fp"][:, args.prompt_len:]
    gen_q = outs["cat-w4a4"][:, args.prompt_len:]
    agree = float((gen_fp == gen_q).mean())
    print(f"\nfp-vs-quantized greedy token agreement: {100*agree:.1f}%")
    print("sample (request 0):")
    print("  fp :", gen_fp[0][:24].tolist())
    print("  q4 :", gen_q[0][:24].tolist())

    # --- the same quantized model behind the continuous-batching engine:
    # mixed-prompt FIFO queue, int8 slot KV cache, prefill-on-admit
    import dataclasses
    from repro.data import request_workload
    from repro.launch.engine import ServeEngine
    from repro.models import build
    qcfg8 = dataclasses.replace(cfg, kv_quant_bits=8)
    model8 = build(qcfg8)
    reqs = request_workload(qcfg8, 2 * args.batch, gen=args.gen, seed=11)
    engine = ServeEngine(model8, qparams, n_slots=args.batch,
                         max_len=max(len(r["tokens"]) for r in reqs)
                         + args.gen + 8)
    engine.run(reqs)
    s = engine.summary()
    print(f"\nengine: {s['n_requests']} mixed-length reqs on "
          f"{s['n_slots']} slots (int8 KV cache) -> "
          f"{s['tok_per_s']:.1f} tok/s, ttft {s['ttft_s_mean']*1e3:.0f}ms, "
          f"occupancy {s['occupancy_mean']:.2f}")


if __name__ == "__main__":
    main()
