"""Reproduce the paper's Figures 4/5/6 analysis as printed tables:
per-layer concentration, alignment (vs optimum), and joint SQNR under
{none, SmoothQuant, Hadamard, CAT}.

    PYTHONPATH=src python examples/transform_analysis.py
"""
import sys
sys.path.insert(0, ".")
sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from benchmarks.common import layer_cases
from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.quantizers import act_spec, weight_spec


def main():
    rng = np.random.default_rng(0)
    hdr = (f"{'layer':16s} | {'C(x) dB':>24s} | {'A dB':>31s} | "
           f"{'W4A4 SQNR dB':>31s}")
    sub = (f"{'':16s} | {'none':>7s} {'had':>7s} {'cat':>7s} | "
           f"{'none':>7s} {'cat':>7s} {'A*':>7s} {'had-none':>7s} | "
           f"{'none':>7s} {'had':>7s} {'cat':>7s} {'w6a6':>7s}")
    print(hdr); print(sub); print("-" * len(sub))
    for name, w, stats in layer_cases():
        x = jnp.asarray(stats.sample_matrix()[:768])
        wj = jnp.asarray(w)
        sw, sx = wj.T @ wj, jnp.asarray(stats.sigma, jnp.float32)
        had = T.make_hadamard(w.shape[1], rng)
        cat = T.make_cat_block(sw, sx, k=64, hadamard=True, rng=rng)

        def cx(t):
            return float(S.db(S.concentration_act(T.apply(t, x),
                                                  act_spec(4))))

        def al(t):
            return float(S.db(S.alignment(T.fuse_weight(t, wj),
                                          T.apply(t, x))))

        def joint(t, b=4):
            return float(S.db(S.sqnr_quantized_layer(
                T.fuse_weight(t, wj), T.apply(t, x),
                weight_spec(b, range_p=None), act_spec(b))))

        astar = float(S.db(S.alignment_optimal(wj, sx)))
        i = T.Identity()
        print(f"{name:16s} | {cx(i):7.2f} {cx(had):7.2f} {cx(cat):7.2f} | "
              f"{al(i):7.2f} {al(cat):7.2f} {astar:7.2f} "
              f"{al(had)-al(i):7.3f} | "
              f"{joint(i):7.2f} {joint(had):7.2f} {joint(cat):7.2f} "
              f"{joint(i, 6):7.2f}")
    print("\nClaims to observe: had-none column == 0 (rotation invariance);"
          "\ncat <= A*; cat SQNR > had SQNR; cat W4A4 approaches w6a6.")


if __name__ == "__main__":
    main()
