"""Fault-tolerant training demo: trains a small LM with checkpointing,
kills itself mid-run (injected failure), restarts from the checkpoint,
and verifies the resumed trajectory is bit-exact.

    PYTHONPATH=src python examples/train_small.py
"""
import sys, tempfile
sys.path.insert(0, "src")

import numpy as np

from repro.launch.train import train


def main():
    with tempfile.TemporaryDirectory() as d:
        print("== run A: 40 steps straight ==")
        _, la = train(arch="catlm_60m", steps=40, batch=4, seq=64,
                      ckpt_dir=None, seed=3, log_every=10)
        print("== run B: fails at steps 13 & 27, restarts from ckpt ==")
        _, lb = train(arch="catlm_60m", steps=40, batch=4, seq=64,
                      ckpt_dir=d, ckpt_every=10, seed=3,
                      fail_at=(13, 27), log_every=10)
        print(f"final losses: straight={la[-1]:.5f} restarted={lb[-1]:.5f}")
        assert np.allclose(la[-1], lb[-1], rtol=1e-4), "resume not exact!"
        print("restart trajectory matches — deterministic (seed, step) "
              "data + atomic checkpoints")


if __name__ == "__main__":
    main()
