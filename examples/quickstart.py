"""Quickstart: quantize a small LM with CAT and see why it works.

    PYTHONPATH=src python examples/quickstart.py

1. trains a tiny LM on synthetic data (so activations have real structure)
2. calibrates Σ_x on 16 sequences
3. quantizes W4A4 with {none, Hadamard, CAT} and compares:
   - per-layer concentration / alignment / SQNR (the paper's decomposition)
   - end-to-end eval CE vs the fp model
"""
import sys
sys.path.insert(0, ".")
sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import trained_model, calibrated_taps, layer_cases
from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.pipeline import QuantizeConfig, eval_quantized, \
    quantize_model
from repro.core.quantizers import act_spec, weight_spec
from repro.data import calibration_batches, make_batch


def main():
    print("== training the demo LM (cached after first run) ==")
    cfg, model, params = trained_model()
    print(f"arch={cfg.name} layers={cfg.n_layers} d={cfg.d_model}")

    print("\n== the Concentration-Alignment decomposition (Thm 2.4) ==")
    name, w, stats = layer_cases()[-1]   # a down-proj
    x = jnp.asarray(stats.sample_matrix()[:512])
    rep = S.layer_report(jnp.asarray(w), x)
    for k, v in rep.items():
        print(f"  {k:26s} {float(v):8.2f} dB")

    print("\n== transforms on that layer (W4A4 joint SQNR) ==")
    wj = jnp.asarray(w)
    sw, sx = wj.T @ wj, jnp.asarray(stats.sigma, jnp.float32)
    for tname, t in [
            ("none", T.Identity()),
            ("hadamard", T.make_hadamard(w.shape[1],
                                         np.random.default_rng(0))),
            ("CAT(block)", T.make_cat_block(sw, sx, k=64, hadamard=True,
                                            rng=np.random.default_rng(0)))]:
        wt, xt = T.fuse_weight(t, wj), T.apply(t, x)
        db = float(S.db(S.sqnr_quantized_layer(
            wt, xt, weight_spec(4, range_p=None), act_spec(4))))
        al = float(S.db(S.alignment(wt, xt)))
        print(f"  {tname:12s} sqnr={db:6.2f} dB  alignment={al:7.2f} dB")

    print("\n== end-to-end W4A4 PTQ ==")
    evalb = [make_batch(cfg, 256, 4, seed=999)]
    for tr in ("none", "hadamard", "cat"):
        qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform=tr, cat_block=64)
        qp = quantize_model(model, params, qcfg,
                            calibration_batches(cfg, n_seqs=16,
                                                seq_len=128, batch=4))
        ev = eval_quantized(model, params, qp, evalb)
        print(f"  {tr:10s} ce_fp={ev['ce_fp']:.3f} "
              f"ce_quant={ev['ce_quant']:.3f} (delta {ev['delta']:+.3f})")


if __name__ == "__main__":
    main()
