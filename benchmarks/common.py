"""Shared benchmark infrastructure: a small *trained* LM (realistic
activation correlations/outliers come from training, not init), calibrated
taps, and per-layer (W, Σx, samples) extraction.

The trained checkpoint is cached under results/bench_model so the whole
benchmark suite trains it once.
"""
from __future__ import annotations

import os
import time
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ck
from repro.configs import get_config
from repro.core.calibration import Taps, calibrate
from repro.data import calibration_batches, make_batch
from repro.models import build

BENCH_DIR = os.environ.get("REPRO_BENCH_DIR", "results/bench_model")
_ARCH = "catlm_60m"


def bench_cfg():
    return get_config(_ARCH).scaled(
        n_layers=4, d_model=256, n_heads=4, n_kv_heads=2, head_dim=64,
        d_ff=768, vocab=2048, cat_block=64)


@lru_cache(maxsize=1)
def trained_model(steps: int = 120):
    """-> (cfg, model, params) — trained once, cached on disk."""
    cfg = bench_cfg()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    if ck.latest_step(BENCH_DIR) is not None:
        out = ck.restore(BENCH_DIR, None, params)
        return cfg, model, out["params"]
    from repro.optim import AdamW, warmup_cosine
    opt = AdamW(lr=warmup_cosine(1e-3, 10, steps))
    state = opt.init(params)

    @jax.jit
    def step_fn(p, s, batch):
        (l, _), g = jax.value_and_grad(model.loss, has_aux=True)(p, batch)
        p, s = opt.update(p, g, s)
        return p, s, l

    for step in range(steps):
        b = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, 128, 8, seed=0, step=step).items()}
        params, state, loss = step_fn(params, state, b)
    os.makedirs(BENCH_DIR, exist_ok=True)
    ck.save(BENCH_DIR, steps, params, meta={"loss": float(loss)})
    return cfg, model, params


@lru_cache(maxsize=1)
def calibrated_taps():
    cfg, model, params = trained_model()
    taps = calibrate(model, params,
                     calibration_batches(cfg, n_seqs=16, seq_len=128,
                                         batch=4))
    return taps


def layer_cases():
    """-> list of (name, W (d_out, d_in) np, stats) for every transform
    group of every layer (the 'linear layers of the architecture')."""
    cfg, model, params = trained_model()
    taps = calibrated_taps()
    from repro.core.pipeline import layer_groups
    cases = []
    for g in layer_groups(cfg):
        for i in range(cfg.n_layers):
            tap = f"layers.{i}.{g.tap}"
            ws = [np.asarray(params[g.scope][name][i]).T
                  for name in g.weights]          # (d_out, d_in) each
            w = np.concatenate(ws, axis=0)
            cases.append((f"L{i}.{g.tap}", w, taps[tap]))
    return cases


def timer(fn, *args, warmup: int = 1, iters: int = 3):
    for _ in range(warmup):
        fn(*args)
    t0 = time.time()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") else None
    return (time.time() - t0) / iters * 1e6, out  # us/call


def emit(name: str, us: float, derived):
    print(f"{name},{us:.1f},{derived}", flush=True)
