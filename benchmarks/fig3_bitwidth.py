"""Fig. 3: SQNR vs (b_w, b_x) grid — horizontal/vertical 24 dB shifts per
4 bits and the worst-component law (§2.1: overall SQNR tracks min side)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, layer_cases, timer
from repro.core import sqnr as S
from repro.core.quantizers import act_spec, weight_spec


def run() -> dict:
    name, w, stats = layer_cases()[0]
    x = jnp.asarray(stats.sample_matrix()[:1024])
    wj = jnp.asarray(w)
    grid = {}
    for bw in (4, 6, 8):
        for bx in (4, 6, 8):
            grid[(bw, bx)] = float(S.db(S.sqnr_quantized_layer(
                wj, x, weight_spec(bw, range_p=None), act_spec(bx))))
    dbit_w = np.mean([grid[(8, bx)] - grid[(4, bx)] for bx in (8,)])
    dbit_x = np.mean([grid[(bw, 8)] - grid[(bw, 4)] for bw in (8,)])
    return {"grid": {f"W{k[0]}A{k[1]}": v for k, v in grid.items()},
            "shift_w_4bits_db": float(dbit_w),
            "shift_x_4bits_db": float(dbit_x)}


def main() -> None:
    us, out = timer(run, iters=1)
    emit("fig3_bitwidth", us,
         f"W+4b={out['shift_w_4bits_db']:.1f}dB "
         f"A+4b={out['shift_x_4bits_db']:.1f}dB "
         f"W4A4={out['grid']['W4A4']:.1f}dB W8A8={out['grid']['W8A8']:.1f}dB")


if __name__ == "__main__":
    main()
