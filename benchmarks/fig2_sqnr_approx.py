"""Fig. 2: empirical verification of the Theorem 2.4 SQNR approximation.

For every linear layer (× {W4A4, W4A8, W8A8} × {none, hadamard}) compare
measured joint SQNR to the approximation; report mean |gap| dB and the
Pearson correlation (paper claim: accurate for 5-50 dB layers).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, layer_cases, timer
from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.quantizers import act_spec, weight_spec


def run() -> dict:
    cases = layer_cases()
    rows = []
    for use_had in (False, True):
        for bw, bx in ((4, 4), (4, 8), (8, 8)):
            wspec, xspec = weight_spec(bw, range_p=None), act_spec(bx)
            for name, w, stats in cases:
                x = jnp.asarray(stats.sample_matrix()[:1024])
                wj = jnp.asarray(w)
                if use_had:
                    t = T.make_hadamard(w.shape[1],
                                        np.random.default_rng(0))
                    wj = T.fuse_weight(t, wj)
                    x = T.apply(t, x)
                meas = float(S.db(S.sqnr_quantized_layer(wj, x, wspec,
                                                         xspec)))
                appr = float(S.db(S.sqnr_approx_joint(wj, x, wspec, xspec)))
                rows.append((meas, appr))
    rows = np.asarray(rows)
    sel = (rows[:, 0] > 5) & (rows[:, 0] < 50)
    gap = float(np.mean(np.abs(rows[sel, 0] - rows[sel, 1])))
    corr = float(np.corrcoef(rows[sel, 0], rows[sel, 1])[0, 1])
    return {"mean_abs_gap_db": gap, "corr": corr, "n_layers": int(sel.sum())}


def main() -> None:
    us, out = timer(run, iters=1)
    emit("fig2_sqnr_approx", us,
         f"gap={out['mean_abs_gap_db']:.2f}dB corr={out['corr']:.3f} "
         f"n={out['n_layers']}")


if __name__ == "__main__":
    main()
