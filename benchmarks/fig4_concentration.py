"""Fig. 4: concentration of weights/activations per layer under
{none, channel-scale, hadamard, CAT}; reference lines: Normal/Laplace."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, layer_cases, timer
from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.quantizers import act_spec, weight_spec


def _concentrations(w, x):
    cw = float(S.db(S.concentration_weight(w, weight_spec(4, range_p=None))))
    cx = float(S.db(S.concentration_act(x, act_spec(4))))
    return cw, cx


def run() -> dict:
    out = {k: {"cw": [], "cx": []}
           for k in ("none", "channel", "hadamard", "cat")}
    rng = np.random.default_rng(0)
    for name, w, stats in layer_cases():
        x = jnp.asarray(stats.sample_matrix()[:1024])
        wj = jnp.asarray(w)
        sw = wj.T @ wj
        sx = jnp.asarray(stats.sigma, jnp.float32)
        ts = {
            "none": T.Identity(),
            "channel": T.make_smoothquant(
                jnp.asarray(stats.absmax, jnp.float32),
                jnp.max(jnp.abs(wj), axis=0)),
            "hadamard": T.make_hadamard(w.shape[1], rng),
            "cat": T.make_cat_block(sw, sx, k=64, hadamard=True, rng=rng),
        }
        for k, t in ts.items():
            cw, cx = _concentrations(T.fuse_weight(t, wj), T.apply(t, x))
            out[k]["cw"].append(cw)
            out[k]["cx"].append(cx)
    # gaussian reference for d channels: C ≈ E||x||²/E[r²]; r ≈ 2·max|x|
    return {k: {"cw_mean": float(np.mean(v["cw"])),
                "cx_mean": float(np.mean(v["cx"]))} for k, v in out.items()}


def main() -> None:
    us, out = timer(run, iters=1)
    emit("fig4_concentration", us,
         " ".join(f"{k}:cx={v['cx_mean']:.1f}dB/cw={v['cw_mean']:.1f}dB"
                  for k, v in out.items()))


if __name__ == "__main__":
    main()
