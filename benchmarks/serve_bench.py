"""Continuous-batching serving benchmark: the same seeded mixed-prompt
workload drained through the engine with fp, int8, and int4-packed
weights (int8 slot KV cache for the quantized rows). Emits the usual CSV
rows plus a JSON artifact (results/serve_bench.json) with TTFT, tok/s,
and slot-occupancy per variant.

With >= 4 local devices (XLA_FLAGS=--xla_force_host_platform_device_count
on CPU) it also serves the int4-packed variant tensor-parallel — a tp=1
vs tp=4 pair on an MHA smoke config, token-identity checked row-to-row.

On CPU the absolute tok/s is a correctness-path number (interpret-mode
kernels, smoke model); the interesting readouts are the relative weight
bytes and the scheduler metrics (occupancy, queue drain, TTFT spread).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.launch.serve import serve_benchmark

VARIANTS = [
    # name, transform, w_bits, a_bits, kv_bits
    ("fp", "fp", 0, 0, 0),
    ("int8", "cat", 8, 8, 8),
    ("int4_packed", "cat", 4, 4, 8),
]

# tensor-parallel pair: identical MHA config (smoke catlm has
# n_kv_heads=2, which cannot split whole heads over tp=4) served at tp=1
# and on a (1, 4) ("data", "model") mesh.
TP_OVERRIDES = {"n_kv_heads": 4}


def _tp_rows(rows, n_requests, n_slots, gen) -> None:
    import jax

    if len(jax.devices()) < 4:
        emit("serve_int4_tp4", 0.0,
             "skipped=needs-4-devices (XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
        return
    from repro.distributed.compat import make_mesh
    outs = {}
    for name, mesh in (("int4_tp1", None),
                       ("int4_tp4", make_mesh((1, 4), ("data", "model")))):
        out = serve_benchmark(arch="catlm_60m", batch=n_slots, gen=gen,
                              transform="cat", w_bits=4, a_bits=4,
                              kv_bits=8, n_requests=n_requests, mixed=True,
                              seed=0, mesh=mesh, cfg_overrides=TP_OVERRIDES)
        eng = out["engine"]
        outs[name] = out
        rows[name] = {
            "transform": "cat", "w_bits": 4, "kv_bits": 8,
            "mesh": eng["mesh"],
            "ttft_s_mean": eng["ttft_s_mean"],
            "tok_per_s": eng["tok_per_s"],
            "occupancy_mean": eng["occupancy_mean"],
            "n_requests": eng["n_requests"], "n_slots": eng["n_slots"],
        }
        emit(f"serve_{name}", eng["wall_s"] * 1e6,
             f"tok_per_s={eng['tok_per_s']:.1f} "
             f"ttft_ms={eng['ttft_s_mean'] * 1e3:.0f} mesh={eng['mesh']}")
    identical = all(
        (outs["int4_tp1"]["results"][rid].tokens
         == outs["int4_tp4"]["results"][rid].tokens).all()
        for rid in outs["int4_tp1"]["results"])
    rows["int4_tp4"]["token_identical_to_tp1"] = bool(identical)
    emit("serve_tp4_token_identity", 0.0, f"identical={identical}")


def main(n_requests: int = 8, n_slots: int = 3, gen: int = 8,
         out_path: str = "results/serve_bench.json") -> None:
    rows = {}
    for name, transform, w_bits, a_bits, kv_bits in VARIANTS:
        out = serve_benchmark(arch="catlm_60m", batch=n_slots, gen=gen,
                              transform=transform, w_bits=w_bits,
                              a_bits=a_bits, kv_bits=kv_bits,
                              n_requests=n_requests, mixed=True, seed=0)
        eng = out["engine"]
        rows[name] = {
            "transform": transform, "w_bits": w_bits, "kv_bits": kv_bits,
            "ttft_s_mean": eng["ttft_s_mean"],
            "ttft_s_max": eng["ttft_s_max"],
            "tok_per_s": eng["tok_per_s"],
            "occupancy_mean": eng["occupancy_mean"],
            "queue_depth_max": eng["queue_depth_max"],
            "steps": eng["steps"],
            "n_requests": eng["n_requests"],
            "n_slots": eng["n_slots"],
            "quantized_kv": eng["quantized_kv"],
            "weight_bytes": out.get("weight_bytes", 0),
            "packed_int4": out.get("packed_int4", False),
        }
        emit(f"serve_{name}", eng["wall_s"] * 1e6,
             f"tok_per_s={eng['tok_per_s']:.1f} "
             f"ttft_ms={eng['ttft_s_mean'] * 1e3:.0f} "
             f"occ={eng['occupancy_mean']:.2f} "
             f"wbytes={out.get('weight_bytes', 0)}")
    if rows.get("int8") and rows.get("int4_packed"):
        r = rows["int4_packed"]["weight_bytes"] / rows["int8"]["weight_bytes"]
        emit("serve_w4_vs_w8_weight_bytes", 0.0, f"ratio={r:.2f}")
    _tp_rows(rows, n_requests, n_slots, gen)
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(rows, f, indent=2)
    emit("serve_bench_json", 0.0, out_path)


if __name__ == "__main__":
    main()
