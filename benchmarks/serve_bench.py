"""Continuous-batching serving benchmark: the same seeded mixed-prompt
workload drained through the engine with fp, int8, and int4-packed
weights (int8 slot KV cache for the quantized rows). Emits the usual CSV
rows plus a JSON artifact (results/serve_bench.json, stamped with a
``schema_version``) with TTFT, steady-state tok/s, per-step latency
percentiles (ITL p50/p95), and slot-occupancy per variant.

Variant rows are STEADY-STATE (schema v3): each engine drains the
workload twice and only the second, fully-compiled pass is timed —
compilation cost is reported separately as ``compile_s``. (The old
single-pass rows charged jit compilation to tok/s; the quantized
variants trace more distinct XLA programs than fp, so the compile tax
buried exactly the hot-path win this bench exists to show.) Each row
also carries the gap-attribution fields: analytic hot-path HBM
bytes/token (``hot_path_bytes_per_token``, fused vs unfused — see
benchmarks/roofline_report.py), measured ``device_ms_mean`` /
``host_ms_mean`` per step, and ``dispatch_per_step``.

Unified-vs-legacy rows (``schedule_mixed``): a mixed workload of long
prompts among short decodes, drained through the legacy
(prefill-on-admit) engine and the unified token-budget scheduler. The
readout is the ITL tail: legacy admission steps prefill a whole long
prompt before the in-flight decodes run (head-of-line stall -> fat p95),
unified packs at most ``max_batch_tokens`` per step so decode latency
stays flat — with a token-identity check between the two engines.

Paged-vs-slot rows (``kv_paged_50`` / ``kv_paged_100``): the same
workload through the slot cache and the paged pool at ~50% and ~100%
mean sequence occupancy — tok/s, TTFT, and resident KV bytes (allocated
pages vs the slot cache's flat ``n_slots × max_len`` reservation), with
a token-identity check between the two engines.

Prefix-cache rows (``prefix_shared``): a workload of requests sharing a
common system prompt, served with the copy-on-write prefix cache off and
on — TTFT p50/p95, prefill tokens skipped, hit rate, and the resident-KV
dedup ratio, with a token-identity check between the two engines.

Speculative row (``speculative``): self-speculative decoding on the
int4-packed serving config — an int4 draft proposes k tokens per slot
per cycle (one fused k-step scan dispatch), the target verifies all k+1
positions in one ragged step. tok/s and acceptance rate vs the
non-speculative unified baseline at k in {2, 4}, token-identity checked
(greedy acceptance makes identity structural; a false here is a bug and
exits nonzero).

Pipelined row (``pipelined``, schema v4): the depth-1 asynchronous
unified loop (device-resident sampling + one-step-ahead scheduling,
``ServeEngine(pipeline=True)``) vs the synchronous loop on a
decode-heavy workload — tok/s both ways, speedup, ITL p50/p95,
``overlap_frac`` (fraction of host planning/pack/observe time hidden
under device compute), ``host_ms_hidden``, mispredict count, and a
token-identity check. Runs in ``--quick`` too, where ``overlap_frac``
is gated against the recorded artifact like ``dispatch_per_step``.

With >= 4 local devices (XLA_FLAGS=--xla_force_host_platform_device_count
on CPU) it also serves the int4-packed variant tensor-parallel — a tp=1
vs tp=4 pair on an MHA smoke config, token-identity checked row-to-row.

On CPU the absolute tok/s is a correctness-path number (interpret-mode
kernels, smoke model); the interesting readouts are the relative weight /
resident-KV bytes and the scheduler metrics (occupancy, queue drain,
TTFT spread).
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit
from repro.launch.serve import serve_benchmark

VARIANTS = [
    # name, transform, w_bits, a_bits, kv_bits
    ("fp", "fp", 0, 0, 0),
    ("int8", "cat", 8, 8, 8),
    ("int4_packed", "cat", 4, 4, 8),
]

# tensor-parallel pair: identical MHA config (smoke catlm has
# n_kv_heads=2, which cannot split whole heads over tp=4) served at tp=1
# and on a (1, 4) ("data", "model") mesh.
TP_OVERRIDES = {"n_kv_heads": 4}


def _tp_rows(rows, n_requests, n_slots, gen) -> None:
    import jax

    if len(jax.devices()) < 4:
        emit("serve_int4_tp4", 0.0,
             "skipped=needs-4-devices (XLA_FLAGS="
             "--xla_force_host_platform_device_count=8)")
        return
    from repro.distributed.compat import make_mesh
    outs = {}
    for name, mesh in (("int4_tp1", None),
                       ("int4_tp4", make_mesh((1, 4), ("data", "model")))):
        out = serve_benchmark(arch="catlm_60m", batch=n_slots, gen=gen,
                              transform="cat", w_bits=4, a_bits=4,
                              kv_bits=8, n_requests=n_requests, mixed=True,
                              seed=0, mesh=mesh, cfg_overrides=TP_OVERRIDES)
        eng = out["engine"]
        outs[name] = out
        rows[name] = {
            "transform": "cat", "w_bits": 4, "kv_bits": 8,
            "mesh": eng["mesh"],
            "ttft_s_mean": eng["ttft_s_mean"],
            "tok_per_s": eng["tok_per_s"],
            "occupancy_mean": eng["occupancy_mean"],
            "dispatch_per_step": eng["dispatch_per_step"],
            "launches_per_token": eng["launches_per_token"],
            "n_requests": eng["n_requests"], "n_slots": eng["n_slots"],
        }
        emit(f"serve_{name}", eng["wall_s"] * 1e6,
             f"tok_per_s={eng['tok_per_s']:.1f} "
             f"ttft_ms={eng['ttft_s_mean'] * 1e3:.0f} mesh={eng['mesh']}")
    identical = all(
        (outs["int4_tp1"]["results"][rid].tokens
         == outs["int4_tp4"]["results"][rid].tokens).all()
        for rid in outs["int4_tp1"]["results"])
    rows["int4_tp4"]["token_identical_to_tp1"] = bool(identical)
    emit("serve_tp4_token_identity", 0.0, f"identical={identical}")


def _paged_rows(rows, n_requests: int, n_slots: int) -> None:
    """Slot-vs-paged engine over the same model and workload, at ~50% and
    ~100% mean sequence occupancy of max_len (the paged win is resident
    bytes tracking true lengths; at 100% the two converge)."""
    import numpy as np

    from repro.data import request_workload
    from repro.launch.engine import ServeEngine
    from repro.launch.serve import build_served_model

    cfg, model, params, _ = build_served_model(
        "catlm_60m", "cat", 8, 8, 8, smoke=True, seed=0)
    gen, max_len = 8, 48
    for tag, lengths in (("50", (8, 16, 24)), ("100", (40,))):
        reqs = request_workload(cfg, n_requests, gen=gen, lengths=lengths,
                                seed=0)
        slot = ServeEngine(model, params, n_slots=n_slots, max_len=max_len)
        slot_res = slot.run(reqs)
        ss = slot.summary()
        paged = ServeEngine(model, params, n_slots=n_slots, max_len=max_len,
                            paged=True, page_size=8, prefill_chunk=16)
        paged_res = paged.run(reqs)
        ps = paged.summary()
        identical = all((slot_res[r["rid"]].tokens
                         == paged_res[r["rid"]].tokens).all() for r in reqs)
        ratio = ps["resident_kv_bytes_mean"] / ss["kv_capacity_bytes"]
        mean_seq = float(np.mean([len(r["tokens"]) + gen for r in reqs]))
        rows[f"kv_paged_{tag}"] = {
            "mean_seq_occupancy": mean_seq / max_len,
            "slot_kv_bytes": ss["kv_capacity_bytes"],
            # the slot engine reports resident bytes too (== its capacity:
            # every slot reserves max_len rows up front) so the columns
            # compare like for like
            "slot_resident_kv_bytes_mean": ss["resident_kv_bytes_mean"],
            "paged_resident_kv_bytes_mean": ps["resident_kv_bytes_mean"],
            "paged_resident_kv_bytes_peak": ps["resident_kv_bytes_peak"],
            "paged_over_slot_kv_bytes": ratio,
            "page_size": ps["page_size"],
            "prefill_chunk": ps["prefill_chunk"],
            "slot_tok_per_s": ss["tok_per_s"],
            "paged_tok_per_s": ps["tok_per_s"],
            "dispatch_per_step": ps["dispatch_per_step"],
            "launches_per_token": ps["launches_per_token"],
            "slot_ttft_s_mean": ss["ttft_s_mean"],
            "paged_ttft_s_mean": ps["ttft_s_mean"],
            "token_identical": bool(identical),
            "n_requests": n_requests, "n_slots": n_slots,
            "max_len": max_len,
        }
        emit(f"serve_kv_paged_{tag}", ps["wall_s"] * 1e6,
             f"resident_ratio={ratio:.2f} "
             f"tok_per_s={ps['tok_per_s']:.1f} "
             f"identical={identical}")


def _unified_rows(rows, n_slots: int) -> None:
    """Legacy vs unified schedule over the same mixed long-prompt/decode
    workload: long admissions stall legacy's in-flight decodes (ITL p95
    tail), while the unified token budget caps per-step work. Both
    engines must stay token-identical (they are bitwise so)."""
    from repro.data import request_workload
    from repro.launch.engine import ServeEngine
    from repro.launch.serve import build_served_model

    cfg, model, params, _ = build_served_model(
        "catlm_60m", "fp", 0, 0, 8, smoke=True, seed=0)
    gen, max_len, budget = 8, 72, 12
    reqs = request_workload(cfg, 10, gen=gen, lengths=(4, 48), seed=0)
    outs = {}
    # both engines serve the SAME paged pool with chunked prefill (one
    # prefill compile each) so the only variable is the schedule itself:
    # legacy still prefills a whole admission before its decode dispatch,
    # unified packs at most `budget` tokens per step
    for name, kw in (("legacy", dict(paged=True, page_size=8,
                                     prefill_chunk=8)),
                     ("unified", dict(schedule="unified",
                                      max_batch_tokens=budget,
                                      paged=True, page_size=8))):
        eng = ServeEngine(model, params, n_slots=n_slots, max_len=max_len,
                          **kw)
        res = eng.run(reqs)
        outs[name] = (res, eng.summary())
    identical = all((outs["legacy"][0][r["rid"]].tokens
                     == outs["unified"][0][r["rid"]].tokens).all()
                    for r in reqs)
    ls, us = outs["legacy"][1], outs["unified"][1]
    rows["schedule_mixed"] = {
        "workload": "mixed long-prompt (48t) / short (4t), gen 8",
        "max_batch_tokens": budget,
        "legacy_itl_p50_s": ls["itl_p50_s"],
        "legacy_itl_p95_s": ls["itl_p95_s"],
        "unified_itl_p50_s": us["itl_p50_s"],
        "unified_itl_p95_s": us["itl_p95_s"],
        "itl_p95_unified_over_legacy": (us["itl_p95_s"] / ls["itl_p95_s"]
                                        if ls["itl_p95_s"] else 0.0),
        "legacy_tok_per_s": ls["tok_per_s"],
        "unified_tok_per_s": us["tok_per_s"],
        "unified_packed_tokens_max": us["packed_tokens_max"],
        "dispatch_per_step": us["dispatch_per_step"],
        "launches_per_token": us["launches_per_token"],
        "token_identical": bool(identical),
        "n_requests": len(reqs), "n_slots": n_slots,
    }
    emit("serve_schedule_mixed", us["wall_s"] * 1e6,
         f"itl_p95_ms legacy={ls['itl_p95_s'] * 1e3:.1f} "
         f"unified={us['itl_p95_s'] * 1e3:.1f} "
         f"identical={identical}")


def _prefix_rows(rows, n_slots: int, quick: bool = False) -> None:
    """Shared-system-prompt workload through the unified engine with the
    prefix cache off and on: every request repeats the same S-token
    system prompt, so the cache maps those pages read-only (copy-on-write
    past the shared boundary) instead of re-prefilling and re-storing
    them. Readouts: TTFT p50/p95 both ways, prefill tokens skipped, hit
    rate, and the resident-KV dedup ratio — with a token-identity check
    between the two engines. Steady-state (warmup pass): the measured
    pass runs against a warm trie, i.e. a server that has already seen
    the system prompt."""
    import numpy as np

    n_requests, gen, shared = (4, 4, 24) if quick else (8, 8, 48)
    outs = {}
    for name, on in (("off", False), ("on", True)):
        outs[name] = serve_benchmark(
            arch="catlm_60m", batch=n_slots, gen=gen, transform="cat",
            w_bits=4, a_bits=4, kv_bits=8, n_requests=n_requests, seed=0,
            schedule="unified", shared_prefix=shared, prefix_cache=on,
            warmup=1)
    off, on = outs["off"], outs["on"]
    identical = all((off["results"][rid].tokens
                     == on["results"][rid].tokens).all()
                    for rid in off["results"])

    def _pcts(out):
        t = [r.ttft_s for r in out["results"].values()]
        return (float(np.percentile(t, 50)), float(np.percentile(t, 95)))

    eo, en = off["engine"], on["engine"]
    off_p50, off_p95 = _pcts(off)
    on_p50, on_p95 = _pcts(on)
    # peak, not mean: prefix-on admits faster (skipped prefill), so it
    # holds more concurrent sequences per step and time-weighted means
    # aren't like-for-like; at peak both engines run n_slots sequences
    # and the dedup win is the shared pages counted once
    ratio = (en["resident_kv_bytes_peak"] / eo["resident_kv_bytes_peak"]
             if eo["resident_kv_bytes_peak"] else 0.0)
    rows["prefix_shared"] = {
        "workload": (f"{n_requests} reqs sharing a {shared}t system "
                     f"prompt, gen {gen}, unified schedule"),
        "shared_prefix_tokens": shared,
        "off_ttft_s_p50": off_p50, "off_ttft_s_p95": off_p95,
        "on_ttft_s_p50": on_p50, "on_ttft_s_p95": on_p95,
        "prefill_tokens_skipped": en["prefix_hit_tokens"],
        "prefix_hit_rate": en["prefix_hit_rate"],
        "cow_copies": en["cow_copies"],
        "resident_kv_peak_on_over_off": ratio,
        "off_resident_kv_bytes_peak": eo["resident_kv_bytes_peak"],
        "on_resident_kv_bytes_peak": en["resident_kv_bytes_peak"],
        "on_cached_kv_bytes": en["cached_kv_bytes"],
        "off_tok_per_s": eo["tok_per_s"], "on_tok_per_s": en["tok_per_s"],
        "dispatch_per_step": en["dispatch_per_step"],
        "launches_per_token": en["launches_per_token"],
        "token_identical": bool(identical),
        "n_requests": n_requests, "n_slots": n_slots,
    }
    emit("serve_prefix_shared", on["wall_s"] * 1e6,
         f"hit_rate={en['prefix_hit_rate']:.2f} "
         f"skipped={en['prefix_hit_tokens']}t "
         f"ttft_p95_ms off={off_p95 * 1e3:.0f} on={on_p95 * 1e3:.0f} "
         f"kv_ratio={ratio:.2f} identical={identical}")


def _speculative_rows(rows, quick: bool = False) -> None:
    """Self-speculative decoding on the int4-packed serving config: a
    draft pass runs the int4-packed weights fused into one k-step scan
    dispatch, then the target verifies all k+1 positions per slot in a
    single ragged invocation. The workload is decode-heavy (short
    prompts, long gens) because speculation only pays on the decode
    path — prefill is mirrored into the draft KV pool and so costs
    roughly double. Greedy acceptance keeps the output token-identical
    to the non-speculative unified baseline; the row records the check
    and the run fails loudly if it is ever false."""
    import numpy as np

    n_requests, n_slots, prompt, gen = ((4, 2, 8, 16) if quick
                                        else (8, 4, 8, 48))
    common = dict(arch="catlm_60m", batch=n_requests, prompt_len=prompt,
                  gen=gen, transform="cat", w_bits=4, a_bits=8, kv_bits=8,
                  seed=0, n_slots=n_slots, paged=True, schedule="unified",
                  warmup=1)
    base = serve_benchmark(**common)
    row = {
        "workload": (f"{n_requests} reqs, {prompt}t prompt, gen {gen}, "
                     "cat w4a8 kv8 target, int4-packed draft"),
        "baseline_tok_per_s": base["tok_per_s"],
        "dispatch_per_step": base["engine"]["dispatch_per_step"],
        "launches_per_token": base["engine"]["launches_per_token"],
        "n_requests": n_requests, "n_slots": n_slots,
    }
    identical_all = True
    for k in (2, 4):
        spec = serve_benchmark(**common, speculative=k)
        eng = spec["engine"]
        identical = bool(np.array_equal(base["tokens"], spec["tokens"]))
        identical_all = identical_all and identical
        speedup = spec["tok_per_s"] / base["tok_per_s"]
        row[f"k{k}_tok_per_s"] = spec["tok_per_s"]
        row[f"k{k}_speedup"] = speedup
        row[f"k{k}_acceptance_rate"] = eng["spec_acceptance_rate"]
        row[f"k{k}_drafted_tokens"] = eng["spec_drafted_tokens"]
        row[f"k{k}_accepted_tokens"] = eng["spec_accepted_tokens"]
        row[f"k{k}_launches_per_token"] = eng["launches_per_token"]
        emit(f"serve_speculative_k{k}", spec["wall_s"] * 1e6,
             f"tok_per_s={spec['tok_per_s']:.1f} "
             f"speedup={speedup:.2f}x "
             f"acceptance={eng['spec_acceptance_rate']:.2f} "
             f"identical={identical}")
    row["token_identical"] = identical_all
    rows["speculative"] = row


def _pipelined_rows(rows, quick: bool = False) -> None:
    """Sync vs pipelined unified loop on a decode-heavy workload (short
    prompts, long gens — decode cycles are where per-step host latency
    dominates and the overlap pays). Same engine config both ways; the
    only variable is ``pipeline``. Token identity is structural (the
    pipelined loop replays the same per-row numerics one step ahead) and
    the row records the check; the run fails loudly if it breaks."""
    import numpy as np

    n_requests, n_slots, prompt, gen = ((4, 2, 8, 16) if quick
                                        else (8, 4, 8, 48))
    common = dict(arch="catlm_60m", batch=n_requests, prompt_len=prompt,
                  gen=gen, transform="cat", w_bits=4, a_bits=8, kv_bits=8,
                  seed=0, n_slots=n_slots, paged=True, schedule="unified",
                  warmup=1)
    sync = serve_benchmark(**common, pipeline=False)
    pipe = serve_benchmark(**common, pipeline=True)
    es, ep = sync["engine"], pipe["engine"]
    identical = bool(np.array_equal(sync["tokens"], pipe["tokens"]))
    speedup = (pipe["tok_per_s"] / sync["tok_per_s"]
               if sync["tok_per_s"] else 0.0)
    rows["pipelined"] = {
        "workload": (f"{n_requests} reqs, {prompt}t prompt, gen {gen}, "
                     "cat w4a8 kv8, unified schedule (decode-heavy)"),
        "sync_tok_per_s": sync["tok_per_s"],
        "pipelined_tok_per_s": pipe["tok_per_s"],
        "pipelined_speedup": speedup,
        "sync_itl_p50_s": es["itl_p50_s"],
        "sync_itl_p95_s": es["itl_p95_s"],
        "pipelined_itl_p50_s": ep["itl_p50_s"],
        "pipelined_itl_p95_s": ep["itl_p95_s"],
        "overlap_frac": ep["overlap_frac"],
        "host_ms_hidden": ep["host_ms_hidden"],
        "mispredicts": ep["mispredicts"],
        "dispatch_per_step": ep["dispatch_per_step"],
        "launches_per_token": ep["launches_per_token"],
        "token_identical": identical,
        "n_requests": n_requests, "n_slots": n_slots,
    }
    emit("serve_pipelined", pipe["wall_s"] * 1e6,
         f"tok_per_s={pipe['tok_per_s']:.1f} "
         f"speedup={speedup:.2f}x "
         f"overlap={ep['overlap_frac']:.2f} "
         f"hidden_ms={ep['host_ms_hidden']:.2f} "
         f"identical={identical}")


# results/serve_bench.json layout: {"schema_version": N, "rows": {...}}.
# Bump on any row-shape change so downstream readers can dispatch.
# v3: variant rows are steady-state (untimed warmup pass) and carry
# compile_s + the gap-attribution fields (hot_path_kib_per_token,
# device_ms_mean/host_ms_mean, dispatch_per_step, fused). Engine rows
# additionally carry launches_per_token (host dispatches amortized over
# emitted tokens — the serving-level launch-pressure column the
# two-launch decode work moves).
# v4: adds the ``pipelined`` row (sync vs depth-1 asynchronous unified
# loop: tok/s + speedup, ITL percentiles, overlap_frac, host_ms_hidden,
# mispredicts, token_identical), present in --quick artifacts too.
SCHEMA_VERSION = 4


def _dispatch_gate(rows: dict, out_path: str) -> list:
    """--quick regression gate: compare each row's ``dispatch_per_step``
    against the previously recorded artifact at ``out_path`` (same
    schema, same quick-mode workload). A rise above 5% means the engine
    started issuing more device dispatches per step — exactly the
    launch-pressure regression the fused decode path exists to prevent.
    Returns the offending row descriptions (empty = pass / no
    baseline)."""
    try:
        with open(out_path) as f:
            base = json.load(f)
    except (OSError, ValueError):
        return []           # no baseline recorded yet: nothing to gate
    if (base.get("schema_version") != SCHEMA_VERSION
            or not base.get("quick")):
        return []           # full-run baselines use a different workload
    bad = []
    for name, row in rows.items():
        ref = base.get("rows", {}).get(name, {}).get("dispatch_per_step")
        cur = row.get("dispatch_per_step")
        if ref and cur and cur > ref * 1.05:
            bad.append(f"{name}: {cur:.3f} > baseline {ref:.3f}")
    return bad


def _overlap_gate(rows: dict, out_path: str) -> list:
    """--quick regression gate (same pattern as ``_dispatch_gate``): the
    pipelined row's ``overlap_frac`` dropping more than 5% below the
    previously recorded quick artifact means host work stopped hiding
    under device compute — the pipelining win regressing. Returns the
    offending descriptions (empty = pass / no baseline)."""
    try:
        with open(out_path) as f:
            base = json.load(f)
    except (OSError, ValueError):
        return []
    if (base.get("schema_version") != SCHEMA_VERSION
            or not base.get("quick")):
        return []
    ref = base.get("rows", {}).get("pipelined", {}).get("overlap_frac")
    cur = rows.get("pipelined", {}).get("overlap_frac")
    if ref and cur is not None and cur < ref * 0.95:
        return [f"pipelined: overlap_frac {cur:.3f} < baseline "
                f"{ref:.3f} - 5%"]
    return []


def _hot_path_kib(w_bits: int, fused: bool) -> float:
    from repro.configs import get_config

    from benchmarks.roofline_report import hot_path_bytes_per_token
    cfg = get_config("catlm_60m").smoke()
    return hot_path_bytes_per_token(cfg, w_bits=w_bits,
                                    fused=fused)["total"] / 2**10


def main(n_requests: int = 8, n_slots: int = 3, gen: int = 8,
         out_path: str = "results/serve_bench.json",
         quick: bool = False) -> None:
    rows = {}
    for name, transform, w_bits, a_bits, kv_bits in VARIANTS:
        out = serve_benchmark(arch="catlm_60m", batch=n_slots, gen=gen,
                              transform=transform, w_bits=w_bits,
                              a_bits=a_bits, kv_bits=kv_bits,
                              n_requests=n_requests, mixed=True, seed=0,
                              warmup=1 if quick else 3)
        eng = out["engine"]
        rows[name] = {
            "transform": transform, "w_bits": w_bits, "kv_bits": kv_bits,
            "ttft_s_mean": eng["ttft_s_mean"],
            "ttft_s_max": eng["ttft_s_max"],
            "itl_p50_s": eng["itl_p50_s"],
            "itl_p95_s": eng["itl_p95_s"],
            "tok_per_s": eng["tok_per_s"],
            "compile_s": eng["compile_s"],
            "occupancy_mean": eng["occupancy_mean"],
            "queue_depth_max": eng["queue_depth_max"],
            "steps": eng["steps"],
            "n_requests": eng["n_requests"],
            "n_slots": eng["n_slots"],
            "quantized_kv": eng["quantized_kv"],
            "weight_bytes": out.get("weight_bytes", 0),
            "packed_int4": out.get("packed_int4", False),
            # gap attribution: analytic hot-path HBM traffic + measured
            # host/device split and dispatch pressure per step
            "fused": eng["fused"],
            "hot_path_kib_per_token": _hot_path_kib(w_bits, eng["fused"]),
            "device_ms_mean": eng["device_ms_mean"],
            "host_ms_mean": eng["host_ms_mean"],
            "dispatch_per_step": eng["dispatch_per_step"],
            "launches_per_token": eng["launches_per_token"],
        }
        emit(f"serve_{name}", eng["wall_s"] * 1e6,
             f"tok_per_s={eng['tok_per_s']:.1f} "
             f"compile_s={eng['compile_s']:.1f} "
             f"ttft_ms={eng['ttft_s_mean'] * 1e3:.0f} "
             f"occ={eng['occupancy_mean']:.2f} "
             f"wbytes={out.get('weight_bytes', 0)}")
    if rows.get("int8") and rows.get("int4_packed"):
        r = rows["int4_packed"]["weight_bytes"] / rows["int8"]["weight_bytes"]
        emit("serve_w4_vs_w8_weight_bytes", 0.0, f"ratio={r:.2f}")
    for q in ("int8", "int4_packed"):
        if rows.get("fp") and rows.get(q):
            r = rows[q]["tok_per_s"] / rows["fp"]["tok_per_s"]
            emit(f"serve_{q}_vs_fp_steady", 0.0, f"ratio={r:.2f}")
    _prefix_rows(rows, n_slots, quick=quick)
    _speculative_rows(rows, quick=quick)
    _pipelined_rows(rows, quick=quick)
    if not quick:
        _paged_rows(rows, n_requests, n_slots)
        _unified_rows(rows, n_slots)
        _tp_rows(rows, n_requests, n_slots, gen)
    regressed = (_dispatch_gate(rows, out_path)
                 + _overlap_gate(rows, out_path)) if quick else []
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump({"schema_version": SCHEMA_VERSION, "quick": quick,
                   "rows": rows}, f, indent=2)
    emit("serve_bench_json", 0.0, f"{out_path} schema_v{SCHEMA_VERSION}")
    # hard gate, not just a recorded field: any engine pair drifting out
    # of token identity is a correctness bug and must fail the run
    bad = sorted({name for name, row in rows.items()
                  for key, val in row.items()
                  if "token_identical" in key and val is False})
    if bad:
        raise SystemExit(f"token identity violated in rows: {bad}")
    if regressed:
        raise SystemExit("regressed vs the recorded baseline: "
                         f"{regressed}")


if __name__ == "__main__":
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: 2 requests, variant rows plus small "
                         "prefix_shared, speculative and pipelined rows "
                         "(skips the paged/unified/tp sections); exits "
                         "nonzero if any row reports "
                         "token_identical=false, dispatch_per_step "
                         "regresses >5% above, or the pipelined row's "
                         "overlap_frac drops >5% below the previously "
                         "recorded --quick artifact")
    ap.add_argument("--out", default="results/serve_bench.json")
    a = ap.parse_args()
    if a.quick:
        main(n_requests=2, n_slots=2, gen=4, out_path=a.out, quick=True)
    else:
        main(out_path=a.out)
