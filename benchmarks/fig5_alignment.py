"""Fig. 5: alignment per layer under transforms vs the achievable optimum
(eq. 9). Claims: rotations/Hadamard leave alignment EXACTLY unchanged;
CAT(block) approaches the optimum."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, layer_cases, timer
from repro.core import sqnr as S
from repro.core import transforms as T


def run() -> dict:
    rows = {"none": [], "hadamard": [], "channel": [], "cat": [],
            "cat_full": [], "optimal": []}
    rng = np.random.default_rng(0)
    for name, w, stats in layer_cases():
        x = jnp.asarray(stats.sample_matrix()[:1024])
        wj = jnp.asarray(w)
        sw = wj.T @ wj
        sx = jnp.asarray(stats.sigma, jnp.float32)
        rows["none"].append(float(S.db(S.alignment(wj, x))))
        rows["optimal"].append(float(S.db(S.alignment_optimal(wj, sx))))
        ts = {
            "hadamard": T.make_hadamard(w.shape[1], rng),
            "channel": T.make_smoothquant(
                jnp.asarray(stats.absmax, jnp.float32),
                jnp.max(jnp.abs(wj), axis=0)),
            "cat": T.make_cat_block(sw, sx, k=64, hadamard=True, rng=rng),
            "cat_full": T.make_cat_full(sw, sx),
        }
        for k, t in ts.items():
            rows[k].append(float(S.db(S.alignment(
                T.fuse_weight(t, wj), T.apply(t, x)))))
    out = {k: float(np.mean(v)) for k, v in rows.items()}
    out["hadamard_invariance_max_db"] = float(np.max(np.abs(
        np.asarray(rows["hadamard"]) - np.asarray(rows["none"]))))
    out["cat_gain_db"] = out["cat"] - out["none"]
    out["headroom_db"] = out["optimal"] - out["none"]
    return out


def main() -> None:
    us, out = timer(run, iters=1)
    emit("fig5_alignment", us,
         f"none={out['none']:.1f} had={out['hadamard']:.1f} "
         f"cat={out['cat']:.1f} opt={out['optimal']:.1f}dB "
         f"had_inv={out['hadamard_invariance_max_db']:.3f} "
         f"cat_gain={out['cat_gain_db']:.2f}dB")


if __name__ == "__main__":
    main()
