"""Benchmark harness — one entry per paper table/figure + kernels +
roofline readout. Prints ``name,us_per_call,derived`` CSV lines.

    PYTHONPATH=src python -m benchmarks.run [--only fig2_sqnr_approx,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback

SUITES = ["fig2_sqnr_approx", "fig3_bitwidth", "fig4_concentration",
          "fig5_alignment", "fig6_sqnr_layers", "table1_e2e",
          "kernels_bench", "serve_bench", "dryrun_readout"]


def dryrun_readout() -> None:
    """Summarize cached dry-run/roofline artifacts as CSV rows."""
    import json
    import os
    from benchmarks.common import emit
    path = "results/dryrun.json"
    if not os.path.exists(path):
        emit("dryrun_readout", 0.0, "no results/dryrun.json (run "
             "python -m repro.launch.dryrun --all first)")
        return
    data = json.load(open(path))
    ok = [k for k, v in data.items() if "flops" in v]
    skip = [k for k, v in data.items() if "skip" in v]
    fail = [k for k, v in data.items() if "error" in v]
    emit("dryrun_cells", 0.0,
         f"ok={len(ok)} skip={len(skip)} fail={len(fail)}")
    mems = sorted((v["memory"]["argument_size_in_bytes"]
                   + v["memory"]["temp_size_in_bytes"], k)
                  for k, v in data.items() if "flops" in v)
    if mems:
        b, k = mems[-1]
        emit("dryrun_peak_mem", 0.0, f"{k}={b/2**30:.1f}GiB/dev")
    rl = "results/roofline.json"
    if os.path.exists(rl):
        rows = [r for r in json.load(open(rl)) if "error" not in r]
        if rows:
            import numpy as np
            fracs = sorted((r["roofline_fraction"], r["cell"])
                           for r in rows)
            emit("roofline_worst", 0.0,
                 f"{fracs[0][1]}={100*fracs[0][0]:.1f}%")
            emit("roofline_best", 0.0,
                 f"{fracs[-1][1]}={100*fracs[-1][0]:.1f}%")
            emit("roofline_median", 0.0,
                 f"{100*float(np.median([f for f, _ in fracs])):.1f}%")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite names")
    args = ap.parse_args()
    suites = args.only.split(",") if args.only else SUITES
    print("name,us_per_call,derived")
    failures = 0
    for name in suites:
        try:
            if name == "dryrun_readout":
                dryrun_readout()
                continue
            mod = __import__(f"benchmarks.{name}", fromlist=["main"])
            mod.main()
        except Exception:  # noqa: BLE001
            failures += 1
            print(f"{name},nan,ERROR", flush=True)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
