"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference, plus the
jnp serving-path ops that the dry-run lowers. On CPU the interesting
number is the REFERENCE path µs (interpret mode is a correctness
simulator, not a perf proxy); TPU wall-clock comes from the roofline.
Also derives per-op arithmetic intensity for the kernel BlockSpec story.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import emit, timer
from repro.core.hadamard import hadamard_factors
from repro.core.quantizers import pack_int4
from repro.kernels import ops, ref


def main() -> None:
    rng = np.random.default_rng(0)
    toks, d, d_out = 512, 1024, 768
    ha, hb = map(lambda h: jnp.asarray(h, jnp.float32), hadamard_factors(d))
    sign = jnp.asarray(rng.choice([-1.0, 1.0], d), jnp.float32)
    x = jnp.asarray(rng.standard_normal((toks, d)), jnp.float32)

    f = jax.jit(lambda x: ref.hadamard_transform(x, ha, hb, sign))
    us, _ = timer(f, x, warmup=2, iters=10)
    flops = 2 * toks * d * (ha.shape[0] + hb.shape[0])
    emit("kernel_hadamard_ref_jnp", us,
         f"gflops={flops/us/1e3:.2f} d={d} toks={toks}")

    us, _ = timer(lambda x: ops.hadamard(x, ha, hb, sign, interpret=True),
                  x, warmup=1, iters=2)
    emit("kernel_hadamard_pallas_interpret", us, "correctness-path")

    f = jax.jit(lambda x: ref.dynamic_quant(x, bits=4))
    us, _ = timer(f, x, warmup=2, iters=10)
    emit("kernel_dynquant_ref_jnp", us,
         f"gbps={x.size*4/us/1e3:.2f}")

    qx = jnp.asarray(rng.integers(-8, 8, (toks, d)), jnp.int8)
    qw = jnp.asarray(rng.integers(-8, 8, (d, d_out)), jnp.int8)
    sx = jnp.asarray(rng.uniform(0.01, 0.1, (toks, 1)), jnp.float32)
    zx = jnp.zeros((toks, 1), jnp.float32)
    sw = jnp.asarray(rng.uniform(0.01, 0.1, (1, d_out)), jnp.float32)
    f = jax.jit(lambda *a: ref.quant_matmul(*a))
    us, _ = timer(f, qx, sx, zx, qw, sw, warmup=2, iters=10)
    w8_bytes = qw.size * qw.dtype.itemsize
    emit("kernel_qmatmul_ref_jnp", us,
         f"weight_bytes={w8_bytes} gflops={2*toks*d*d_out/us/1e3:.2f}")

    # --- int4-packed weight path: same layer (qw is already int4-range),
    # half the weight bytes vs the int8 baseline above
    qwp = pack_int4(qw, axis=0)
    w4_bytes = qwp.size * qwp.dtype.itemsize
    us4, _ = timer(jax.jit(lambda *a: ref.quant_matmul_w4(*a)),
                   qx, sx, zx, qwp, sw, warmup=2, iters=10)
    emit("kernel_qmatmul_w4_ref_jnp", us4,
         f"weight_bytes={w4_bytes} ratio={w4_bytes/w8_bytes:.2f} "
         f"gflops={2*toks*d*d_out/us4/1e3:.2f}")
    us4p, _ = timer(lambda *a: ops.qmatmul_w4(*a, interpret=True),
                    qx, sx, zx, qwp, sw, warmup=1, iters=2)
    emit("kernel_qmatmul_w4_pallas_interpret", us4p,
         "correctness-path (TPU perf from roofline: half HBM weight traffic)")

    # --- decode-shaped W4A8 GEMV (M<=8 single-token rows): the serving
    # engine's per-step weight traffic is w4_bytes, vs w8_bytes for int8
    usg, _ = timer(jax.jit(lambda *a: ref.quant_gemv_w4(*a)),
                   qx[:4], sx[:4], zx[:4], qwp, sw, warmup=2, iters=10)
    emit("kernel_qgemv_w4_ref_jnp", usg,
         f"m=4 weight_bytes={w4_bytes} gbps={w4_bytes/usg/1e3:.2f}")
    usgp, _ = timer(lambda *a: ops.qgemv_w4(*a, interpret=True),
                    qx[:4], sx[:4], zx[:4], qwp, sw, warmup=1, iters=2)
    emit("kernel_qgemv_w4_pallas_interpret", usgp, "correctness-path")

    blocks = jnp.asarray(rng.standard_normal((d // 64, 64, 64)) / 8,
                         jnp.float32)
    f = jax.jit(lambda x: ref.block_diag_matmul(x, blocks))
    us, _ = timer(f, x, warmup=2, iters=10)
    emit("kernel_blockdiag_ref_jnp", us,
         f"gflops={2*toks*d*64/us/1e3:.2f}")

    # VMEM working-set accounting for the chosen BlockSpecs (DESIGN.md §3)
    tm = 256
    vmem_had = (tm * d * 4 * 2 + ha.size * 4 + hb.size * 4) / 2**20
    vmem_qmm = (256 * 512 + 512 * 256 + 256 * 256 * 4) / 2**20
    emit("kernel_vmem_budget", 0.0,
         f"hadamard={vmem_had:.1f}MiB qmatmul={vmem_qmm:.2f}MiB (<16MiB)")


if __name__ == "__main__":
    main()
