"""Table 1: end-to-end quantized model quality.

{RTN, GPTQ} × {none, SmoothQuant, QuaRot(=Hadamard), CAT(block)} at W4A4
(+ KV8), on the trained bench LM; metric is held-out CE/ppl delta vs fp
(the offline analogue of WikiText ppl — no pretrained weights offline).
Paper structure to confirm: CAT ≤ QuaRot ≤ SmoothQuant ≤ none; GPTQ helps
the weak transforms most.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import bench_cfg, emit, timer, trained_model
from repro.core.pipeline import QuantizeConfig, eval_quantized, \
    quantize_model
from repro.data import calibration_batches, make_batch

TRANSFORMS = ("none", "smoothquant", "hadamard", "cat")


def run(seeds=(0, 1)) -> dict:
    """W4A4 (the paper's headline) is near-lossless on our bench LM for
    every method — the discriminating setting here is W3A3, where the
    transform ordering emerges (reported for both)."""
    cfg, model, params = trained_model()
    out: dict = {}
    for bits in (4, 3):
        for method in ("rtn", "gptq"):
            for tr in TRANSFORMS:
                deltas = []
                for seed in seeds:
                    calib = calibration_batches(cfg, n_seqs=16, seq_len=128,
                                                batch=4)
                    qcfg = QuantizeConfig(w_bits=bits, a_bits=bits,
                                          w_method=method, transform=tr,
                                          cat_block=64, seed=seed)
                    qp = quantize_model(model, params, qcfg, calib)
                    ev = eval_quantized(
                        model, params, qp,
                        [make_batch(cfg, 256, 4, seed=500 + seed)])
                    deltas.append(ev["delta"])
                out[f"w{bits}a{bits}/{method}/{tr}"] = {
                    "ce_delta_mean": float(np.mean(deltas)),
                    "ce_delta_std": float(np.std(deltas)),
                }
    return out


def main() -> None:
    us, out = timer(run, iters=1)
    parts = [f"{k}={v['ce_delta_mean']:+.3f}±{v['ce_delta_std']:.3f}"
             for k, v in out.items()]
    emit("table1_e2e", us, " ".join(parts))


if __name__ == "__main__":
    main()
