"""Fig. 6: joint W4A4 SQNR per layer under {none, channel, hadamard, CAT}
vs the W6A6 no-transform reference (claim: CAT W4A4 rivals W6A6)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, layer_cases, timer
from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.quantizers import act_spec, weight_spec


def _joint(w, x, b=4):
    return float(S.db(S.sqnr_quantized_layer(
        w, x, weight_spec(b, range_p=None), act_spec(b))))


def run() -> dict:
    rows = {k: [] for k in ("none", "channel", "hadamard", "cat", "w6a6")}
    rng = np.random.default_rng(0)
    for name, w, stats in layer_cases():
        x = jnp.asarray(stats.sample_matrix()[:1024])
        wj = jnp.asarray(w)
        sw = wj.T @ wj
        sx = jnp.asarray(stats.sigma, jnp.float32)
        rows["none"].append(_joint(wj, x))
        rows["w6a6"].append(_joint(wj, x, b=6))
        ts = {
            "channel": T.make_smoothquant(
                jnp.asarray(stats.absmax, jnp.float32),
                jnp.max(jnp.abs(wj), axis=0)),
            "hadamard": T.make_hadamard(w.shape[1], rng),
            "cat": T.make_cat_block(sw, sx, k=64, hadamard=True, rng=rng),
        }
        for k, t in ts.items():
            rows[k].append(_joint(T.fuse_weight(t, wj), T.apply(t, x)))
    out = {k: float(np.mean(v)) for k, v in rows.items()}
    out["cat_vs_hadamard_db"] = out["cat"] - out["hadamard"]
    out["cat_vs_w6a6_db"] = out["cat"] - out["w6a6"]
    return out


def main() -> None:
    us, out = timer(run, iters=1)
    emit("fig6_sqnr_layers", us,
         f"none={out['none']:.1f} ch={out['channel']:.1f} "
         f"had={out['hadamard']:.1f} cat={out['cat']:.1f} "
         f"w6a6={out['w6a6']:.1f}dB cat-had={out['cat_vs_hadamard_db']:.2f}")


if __name__ == "__main__":
    main()
