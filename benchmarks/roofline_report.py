"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Terms per (arch × shape) on the single-pod mesh, TPU v5e constants:
    compute    = HLO_FLOPs / (chips · 197e12 FLOP/s)
    memory     = HLO_bytes / (chips · 819e9 B/s)
    collective = collective_bytes / (chips · 50e9 B/s per link)

Scan-body correction: XLA's cost_analysis counts a lax.scan body ONCE.
We therefore lower each cell at L = p and L = 2p layers (p = the arch's
structure period) and extrapolate cost(L) = c(p) + (L/p - 1)·(c(2p)-c(p)).
cost_analysis numbers on the host backend are per-PROGRAM (global);
collective bytes parsed from post-SPMD HLO are per-DEVICE. We normalize
both to per-device terms.

MODEL_FLOPS uses 6·N·D (train) / 2·N·D (serve forward) with N_active for
MoE; the ratio MODEL_FLOPS / HLO_FLOPS flags remat/dispatch overcompute.

Usage: PYTHONPATH=src python -m benchmarks.roofline_report \
           [--dryrun results/dryrun.json] [--measure] [--out results/roofline.json]
`--measure` runs the extra L=p / L=2p lowers (slow); otherwise reads the
cached results/roofline_cells.json produced by an earlier --measure.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # B/s per chip
ICI_BW = 50e9             # B/s per link
CHIPS = 256               # single-pod 16x16

CD_BYTES = 2              # compute dtype (bf16)
ACT_CODE_BYTES = 1        # int8 activation codes


def hot_path_bytes_per_token(cfg, w_bits: int = 4,
                             fused: bool = True) -> dict:
    """Analytic HBM bytes per decoded token of the dense serving hot
    path (§Serving) — the per-layer quantized linears (wq/wk/wv, wo,
    wg/wu, wd) plus the fp LM head. Stream accounting, per linear
    (d_in, d_out), per token:

    weights — the dominant term at batch 1:
      * fused (single-launch kernel OR the ``w_eff``-prepared portable
        path): the stored codes cross HBM once — w_bits/8 B per element
        for the Pallas kernel (nibbles unpack in VMEM), CD_BYTES for the
        prebuilt ``w_eff`` copy. We charge the kernel number; pass
        ``fused=False`` for the pre-PR path.
      * unfused: every step unpacks (int8 write + read, packed only) and
        dequantizes (CD write + matmul read) a fresh weight copy on top
        of reading the stored codes.

    activations — fused reads x once and writes y once; the unfused
    chain makes ~4 extra round trips over x (block-diag out, two
    Hadamard dot stages, quant codes), each an HBM write + read at
    CD_BYTES (codes at 1 B).

    fp weights (w_bits=0) read CD_BYTES per element either way; the
    'fused' savings there are dispatch/activation-traffic only.
    Returns {"weight_bytes", "act_bytes", "total"} per token."""
    d, f = cfg.d_model, cfg.d_ff
    linears = [(d, cfg.q_dim), (d, cfg.kv_dim), (d, cfg.kv_dim),
               (cfg.q_dim, d)]
    linears += [(d, f), (d, f), (f, d)] if cfg.gated_mlp else [(d, f),
                                                               (f, d)]
    w_elem = sum(di * do for di, do in linears) * cfg.n_layers
    if not w_bits:
        w_bytes_per_elem = float(CD_BYTES)
    elif fused:
        w_bytes_per_elem = w_bits / 8.0
    else:
        unpack = 2.0 if w_bits == 4 else 0.0         # int8 write + read
        w_bytes_per_elem = w_bits / 8.0 + unpack + 2.0 * CD_BYTES
    weight_bytes = w_elem * w_bytes_per_elem
    weight_bytes += cfg.d_model * cfg.vocab * CD_BYTES   # fp LM head
    act = 0.0
    for di, do in linears:
        if fused or not w_bits:
            act += (di + do) * CD_BYTES
        else:
            act += di * (7 * CD_BYTES + 2 * ACT_CODE_BYTES) + do * CD_BYTES
    act *= cfg.n_layers
    return {"weight_bytes": weight_bytes, "act_bytes": act,
            "total": weight_bytes + act}


def decode_launches_per_layer(fused_prologue: bool = True) -> dict:
    """Analytic device-launch count per transformer layer per decode
    step on the paged serving path (§Serving). The attention block is
    where the launch pressure lives at batch 1 — each launch is a
    kernel dispatch whose fixed overhead rivals the tiny per-token
    compute:

      * composed (``fused_prologue=False``): the CAT->quant->W4A8 QKV
        GEMV kernel, then an XLA glue program (RoPE rotation + int8 KV
        quantize + paged-pool scatter), then the online-softmax paged
        attention kernel — 3 launches.
      * fused (``fused_prologue=True``): the QKV prologue kernel
        absorbs the transform, activation quant, GEMV, RoPE, KV
        quantize and pool scatter behind one scalar-prefetched grid,
        leaving prologue + paged attention — the two-launch decode.

    The epilogue (o-proj and the MLP) already runs through the fused
    CAT GEMV kernels either way and is listed for the per-layer total.
    HBM bytes/token are unchanged by the fusion (same weights, same KV
    writes — see ``hot_path_bytes_per_token``); the win is launches.
    Returns {"attention", "epilogue", "total"} launches per layer."""
    attention = 2 if fused_prologue else 3
    epilogue = 2                   # o-proj GEMV + fused MLP GEMV chain
    return {"attention": attention, "epilogue": epilogue,
            "total": attention + epilogue}


def decode_launch_table() -> str:
    """Launches per decode layer, composed vs fused-prologue — the
    companion column to ``serve_bytes_table`` (bytes/token identical,
    launch count is the mover)."""
    hdr = (f"{'path':18s} {'attention':>10s} {'epilogue':>9s} "
           f"{'total':>6s}")
    lines = ["device launches per decode layer (paged serving path)",
             hdr, "-" * len(hdr)]
    for name, fused in (("composed", False), ("fused prologue", True)):
        c = decode_launches_per_layer(fused_prologue=fused)
        lines.append(f"{name:18s} {c['attention']:>10d} "
                     f"{c['epilogue']:>9d} {c['total']:>6d}")
    return "\n".join(lines)


def serve_bytes_table(arch: str = "catlm_60m", smoke: bool = True) -> str:
    """Per-token HBM traffic of the serving hot path, fused vs unfused,
    at the bench's weight widths — the roofline context for the
    serve_bench tok/s rows (``python -m benchmarks.roofline_report
    --serve-bytes``)."""
    from repro.configs import get_config
    cfg = get_config(arch)
    if smoke:
        cfg = cfg.smoke()
    hdr = (f"{'variant':14s} {'w_kiB/tok':>10s} {'act_kiB/tok':>12s} "
           f"{'total_kiB':>10s} {'vs fp':>6s}")
    lines = [f"serving hot-path HBM bytes/token — {arch}"
             f"{' (smoke)' if smoke else ''}", hdr, "-" * len(hdr)]
    fp = hot_path_bytes_per_token(cfg, w_bits=0)
    for name, w_bits, fused in (("fp", 0, True),
                                ("int8 unfused", 8, False),
                                ("int8 fused", 8, True),
                                ("int4 unfused", 4, False),
                                ("int4 fused", 4, True)):
        b = hot_path_bytes_per_token(cfg, w_bits=w_bits, fused=fused)
        lines.append(f"{name:14s} {b['weight_bytes'] / 2**10:10.1f} "
                     f"{b['act_bytes'] / 2**10:12.2f} "
                     f"{b['total'] / 2**10:10.1f} "
                     f"{b['total'] / fp['total']:6.2f}")
    return "\n".join(lines)


def model_flops(arch: str, shape: str, n_params: float,
                n_active: float) -> float:
    """6·N·D train, 2·N·D forward-only (D = tokens processed)."""
    from repro.launch.specs import SHAPES
    info = SHAPES[shape]
    if info["kind"] == "train":
        toks = info["batch"] * info["seq"]
        return 6.0 * n_active * toks
    if info["kind"] == "prefill":
        toks = info["batch"] * info["seq"]
        return 2.0 * n_active * toks
    return 2.0 * n_active * info["batch"]  # decode: one token per slot


def param_counts(arch: str):
    import jax
    from repro.configs import get_config
    from repro.models import build
    from repro.models.model import active_param_count, param_count
    cfg = get_config(arch)
    model = build(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    if cfg.n_experts:
        expert = sum(int(np.prod(shapes["layers"][k].shape))
                     for k in ("we_g", "we_u", "we_d"))
        act = n - expert + int(expert * cfg.top_k / cfg.n_experts)
    else:
        act = n
    return n, act


def extrapolate(c_p: dict, c_2p: dict, n_layers: int, period: int) -> dict:
    """cost(L) = c(p) + (L/p - 1)·Δ for flops/bytes/collectives."""
    reps = n_layers / period - 1.0

    def ex(a, b):
        return a + reps * (b - a)

    def mem(c, f):
        return float(c["memory"].get(f, 0))

    out = {
        "flops": ex(c_p["flops"], c_2p["flops"]),
        "bytes_accessed": ex(c_p["bytes_accessed"], c_2p["bytes_accessed"]),
        # fusion-floor traffic: every arg/output crosses HBM once, every
        # temp buffer is written+read (temp extrapolates with L; args are
        # dominated by params, which do NOT scale with our L override for
        # the stacked leaves — they do, actually: stacked (L, ...) leaves
        # scale linearly, so plain extrapolation is right for both)
        "bytes_floor": ex(mem(c_p, "argument_size_in_bytes")
                          + mem(c_p, "output_size_in_bytes")
                          + 2 * mem(c_p, "temp_size_in_bytes"),
                          mem(c_2p, "argument_size_in_bytes")
                          + mem(c_2p, "output_size_in_bytes")
                          + 2 * mem(c_2p, "temp_size_in_bytes")),
        "collective_bytes": {},
    }
    keys = set(c_p["collective_bytes"]) | set(c_2p["collective_bytes"])
    for k in keys:
        out["collective_bytes"][k] = ex(
            c_p["collective_bytes"].get(k, 0.0),
            c_2p["collective_bytes"].get(k, 0.0))
    return out


def measure_cells(out_path: str, archs=None, shapes=None) -> dict:
    """Runs the L=p / L=2p lowers for every runnable cell (single pod)."""
    from repro.launch.dryrun import run_cell
    from repro.launch.specs import ARCHS, SHAPES, cell_config, \
        cell_runnable, layer_period
    cells = {}
    if os.path.exists(out_path):
        cells = json.load(open(out_path))
    for arch in archs or ARCHS:
        for shape in shapes or list(SHAPES):
            ok, _ = cell_runnable(arch, shape)
            if not ok:
                continue
            key = f"{arch}|{shape}"
            if key in cells:
                continue
            cfg = cell_config(arch, shape)
            p = layer_period(cfg)
            try:
                # exact_cost unrolls every scan (layers, attention chunks,
                # GLA chunks, loss chunks) so HLO op counts are exact at
                # these small layer counts — see repro/models/flags.py
                c_p = run_cell(arch, shape, False, n_layers=p,
                               exact_cost=True)
                c_2p = run_cell(arch, shape, False, n_layers=2 * p,
                                exact_cost=True)
                cells[key] = {"p": p, "c_p": c_p, "c_2p": c_2p,
                              "n_layers": cfg.n_layers}
            except Exception as e:  # noqa: BLE001
                cells[key] = {"error": f"{type(e).__name__}: {e}"}
            json.dump(cells, open(out_path, "w"), indent=1, sort_keys=True)
            print(f"measured {key}", flush=True)
    return cells


def build_report(dryrun: dict, cells: dict) -> list:
    rows = []
    pc_cache: dict = {}
    for key, cell in sorted(cells.items()):
        if "error" in cell:
            rows.append({"cell": key, "error": cell["error"]})
            continue
        arch, shape = key.split("|")
        full = dryrun.get(f"{arch}|{shape}|single", {})
        ex = extrapolate(cell["c_p"], cell["c_2p"], cell["n_layers"],
                         cell["p"])
        # cost_analysis flops/bytes and HLO collective shapes are all
        # per-DEVICE (the compiled module is the SPMD per-device program —
        # verified against hand-computed catlm numbers, DESIGN.md §6).
        flops_dev = ex["flops"]
        coll_dev = ex["collective_bytes"].get("total", 0.0)
        t_comp = flops_dev / PEAK_FLOPS
        # memory: cost_analysis bytes ignore fusion (10-20x ceiling); the
        # floor assumes perfect fusion (args+outputs once, temps twice).
        t_mem_hi = ex["bytes_accessed"] / HBM_BW
        t_mem = ex["bytes_floor"] / HBM_BW
        t_coll = coll_dev / ICI_BW
        dominant = max((t_comp, "compute"), (t_mem, "memory"),
                       (t_coll, "collective"))[1]
        if arch not in pc_cache:
            pc_cache[arch] = param_counts(arch)
        n, act = pc_cache[arch]
        mf = model_flops(arch, shape, n, act)
        useful = (mf / CHIPS) / max(flops_dev, 1.0)
        bound = max(t_comp, t_mem, t_coll)
        rows.append({
            "cell": key,
            "t_compute_s": t_comp, "t_memory_s": t_mem,
            "t_memory_nofusion_s": t_mem_hi,
            "t_collective_s": t_coll,
            "dominant": dominant,
            "model_flops": mf, "hlo_flops": ex["flops"],
            "useful_flops_ratio": useful,
            "roofline_fraction": (mf / CHIPS / PEAK_FLOPS) / bound
            if bound > 0 else 0.0,
            "mem_gib_per_dev": (full.get("memory", {})
                                .get("argument_size_in_bytes", 0)
                                + full.get("memory", {})
                                .get("temp_size_in_bytes", 0)) / 2**30,
            "collective_breakdown": ex["collective_bytes"],
        })
    return rows


def fmt_table(rows: list) -> str:
    hdr = (f"{'cell':38s} {'compute':>10s} {'memory':>10s} {'collect':>10s}"
           f" {'bound':>10s} {'useful':>7s} {'roofl%':>7s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        if "error" in r:
            lines.append(f"{r['cell']:38s} ERROR {r['error'][:60]}")
            continue
        lines.append(
            f"{r['cell']:38s} {r['t_compute_s']:10.3e} "
            f"{r['t_memory_s']:10.3e} {r['t_collective_s']:10.3e} "
            f"{r['dominant']:>10s} {r['useful_flops_ratio']:7.2f} "
            f"{100*r['roofline_fraction']:7.1f}")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun.json")
    ap.add_argument("--cells", default="results/roofline_cells.json")
    ap.add_argument("--out", default="results/roofline.json")
    ap.add_argument("--measure", action="store_true")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--serve-bytes", action="store_true",
                    help="print the analytic serving hot-path HBM "
                         "bytes/token table (fused vs unfused) and exit")
    ap.add_argument("--launches", action="store_true",
                    help="print the per-decode-layer device-launch "
                         "table (composed vs fused QKV prologue) and "
                         "exit")
    args = ap.parse_args()

    if args.serve_bytes or args.launches:
        if args.serve_bytes:
            print(serve_bytes_table(args.arch or "catlm_60m"))
        if args.launches:
            print(decode_launch_table())
        return
    if args.measure:
        measure_cells(args.cells,
                      archs=[args.arch] if args.arch else None,
                      shapes=[args.shape] if args.shape else None)
    dryrun = json.load(open(args.dryrun)) if os.path.exists(args.dryrun) \
        else {}
    cells = json.load(open(args.cells)) if os.path.exists(args.cells) else {}
    rows = build_report(dryrun, cells)
    json.dump(rows, open(args.out, "w"), indent=1)
    print(fmt_table(rows))


if __name__ == "__main__":
    main()
