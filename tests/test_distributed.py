"""Distributed substrate tests.

Multi-device cases run in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main test
process keeps seeing exactly one device (dry-run hygiene, DESIGN.md §6).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(body: str):
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax, jax.numpy as jnp, numpy as np
        from repro.distributed.compat import make_mesh, shard_map
        from jax.sharding import PartitionSpec as P
        assert len(jax.devices()) == 8
        mesh = make_mesh((8,), ("dp",))
    """) + textwrap.dedent(body)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    return r.stdout


# ----------------------------------------------------------- single-device

def test_optimizer_descends():
    from repro.optim import AdamW
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(params, grads, state)
    assert float(jnp.max(jnp.abs(params["w"]))) < 1e-2


def test_warmup_cosine_schedule():
    from repro.optim import warmup_cosine
    lr = warmup_cosine(1.0, warmup=10, total=110)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(60)) < 1.0
    assert abs(float(lr(110)) - 0.1) < 1e-2


def test_checkpoint_roundtrip(tmp_path):
    from repro.checkpoint import restore, save, latest_step, prune_old
    params = {"a": jnp.arange(6.0).reshape(2, 3),
              "nest": {"b": jnp.ones((4,), jnp.bfloat16)}}
    opt = {"m": jax.tree.map(jnp.zeros_like, params),
           "v": jax.tree.map(jnp.ones_like, params),
           "step": jnp.int32(7)}
    save(str(tmp_path), 7, params, opt, meta={"arch": "catlm"})
    save(str(tmp_path), 9, params, opt)
    assert latest_step(str(tmp_path)) == 9
    out = restore(str(tmp_path), None, params, opt)
    assert out["step"] == 9
    np.testing.assert_allclose(np.asarray(out["params"]["a"]),
                               np.asarray(params["a"]))
    assert out["opt_state"]["v"]["nest"]["b"].dtype == jnp.bfloat16
    prune_old(str(tmp_path), keep=1)
    assert latest_step(str(tmp_path)) == 9


@pytest.mark.slow
def test_watchdog_fires_and_beats():
    import time
    from repro.distributed.fault_tolerance import StepWatchdog
    fired = []
    wd = StepWatchdog(0.2, lambda: fired.append(1))
    wd.beat()
    time.sleep(0.05)
    wd.beat()          # keep-alive
    time.sleep(0.05)
    assert not fired
    time.sleep(0.4)    # let it expire
    assert fired
    wd.stop()


def test_straggler_monitor_flags_outlier():
    from repro.distributed.fault_tolerance import StragglerMonitor
    mon = StragglerMonitor(warmup_steps=3)
    for s in range(20):
        mon.record(s, 0.1 + 0.001 * np.random.default_rng(s).random())
    assert mon.record(20, 1.5)  # 15x slower step flagged
    assert mon.flagged


@pytest.mark.slow
def test_failure_injection_and_restart_loop(tmp_path):
    from repro.distributed.fault_tolerance import (FailureInjector,
                                                   run_with_restarts)
    inj = FailureInjector(fail_at_steps=[3, 7])
    progressed = []

    def run(resume):
        start = 0 if resume is None else max(progressed, default=0)
        for step in range(start, 10):
            inj.check(step)
            progressed.append(step + 1)
        return 10

    final = run_with_restarts(run, max_restarts=3)
    assert final == 10
    assert inj.tripped == [3, 7]


@pytest.mark.slow
def test_sharding_rules_full_configs():
    """Every full-config param gets a legal spec on an abstract 16x16 mesh
    (divisibility respected; replicate-fallback for odd shapes)."""
    from repro.configs import ARCH_IDS, get_config
    from repro.distributed.compat import abstract_mesh
    from repro.distributed.sharding import params_sharding
    from repro.models import build
    mesh = abstract_mesh((16, 16), ("data", "model"))
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        sh = params_sharding(shapes, mesh)
        for (path, leaf), (_, s) in zip(
                jax.tree_util.tree_leaves_with_path(shapes),
                jax.tree_util.tree_leaves_with_path(sh)):
            spec = s.spec
            for dim, name in enumerate(spec):
                if name == "model":
                    assert leaf.shape[dim] % 16 == 0, (arch, path, leaf.shape)


# ------------------------------------------------------------ multi-device

@pytest.mark.slow
def test_compressed_mean_subprocess():
    _run_subprocess("""
        from repro.distributed.compression import (compressed_mean,
            compressed_mean_with_feedback)
        g = jnp.stack([jnp.full((64,), float(i + 1)) for i in range(8)])
        def f(gs):
            return compressed_mean({"g": gs[0]}, "dp")["g"]
        out = shard_map(f, mesh=mesh, in_specs=P("dp"), out_specs=P(),
                        check_vma=False)(g.reshape(8, 64))
        np.testing.assert_allclose(np.asarray(out), 4.5, rtol=1e-2)

        # error feedback: repeated compression converges (bias -> 0)
        rng = np.random.default_rng(0)
        true = jnp.asarray(rng.standard_normal((8, 256)), jnp.float32)
        def step(gs, es):
            m, e = compressed_mean_with_feedback({"g": gs[0]}, {"g": es[0]},
                                                 "dp")
            return m["g"], e["g"]
        fn = shard_map(step, mesh=mesh, in_specs=(P("dp"), P("dp")),
                       out_specs=(P(), P("dp")), check_vma=False)
        err = jnp.zeros_like(true)
        acc = jnp.zeros((256,))
        for _ in range(30):
            mean, err = fn(true, err)
            acc = acc + mean
        want = 30 * jnp.mean(true, 0)
        rel = float(jnp.linalg.norm(acc - want) / jnp.linalg.norm(want))
        assert rel < 0.02, rel
        print("compression-ok")
    """)


@pytest.mark.slow
def test_ring_matmul_subprocess():
    _run_subprocess("""
        from repro.distributed.overlap import ring_matmul, reference_matmul
        rng = np.random.default_rng(0)
        m, k, n = 32, 64, 24
        x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((k, n)), jnp.float32)
        def ring(xs, ws):
            return ring_matmul(xs, ws, "dp", gather=True)
        def ref(xs, ws):
            return reference_matmul(xs, ws, "dp")
        y_ring = shard_map(ring, mesh=mesh, in_specs=(P(None, "dp"), P("dp")),
                           out_specs=P(), check_vma=False)(x, w)
        y_ref = shard_map(ref, mesh=mesh, in_specs=(P(None, "dp"), P("dp")),
                          out_specs=P(), check_vma=False)(x, w)
        np.testing.assert_allclose(np.asarray(y_ring), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(x @ w),
                                   rtol=1e-4, atol=1e-4)
        print("ring-ok")
    """)


@pytest.mark.slow
def test_pipeline_parallel_subprocess():
    _run_subprocess("""
        from repro.distributed.pipeline_parallel import (pipeline_apply,
                                                         reference_apply)
        rng = np.random.default_rng(1)
        n_stages, mb, d, M = 8, 4, 16, 16
        params = {"w": jnp.asarray(rng.standard_normal((n_stages, d, d))
                                   * 0.2, jnp.float32)}
        def stage(p, x):
            return jnp.tanh(x @ p["w"])
        x = jnp.asarray(rng.standard_normal((M, mb, d)), jnp.float32)
        got = pipeline_apply(stage, mesh, "dp", params, x)
        want = reference_apply(stage, params, x)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        print("pipeline-ok")
    """)


@pytest.mark.slow
def test_elastic_remesh_subprocess(tmp_path):
    _run_subprocess(f"""
        from repro.checkpoint import restore, save
        from repro.distributed.fault_tolerance import surviving_mesh
        from repro.distributed.sharding import params_sharding
        params = {{"layers": {{"wq": jnp.arange(512.0).reshape(1, 8, 64)}}}}
        save(r"{tmp_path}", 5, params)
        # lose 4 devices -> re-mesh to 4 and restore onto it
        mesh2, shape = surviving_mesh(n_lost=4, prefer_model=2)
        assert shape == (2, 2), shape
        sh = params_sharding(params, mesh2)
        out = restore(r"{tmp_path}", None, params,
                      shardings={{"params": sh}})
        got = out["params"]["layers"]["wq"]
        np.testing.assert_allclose(np.asarray(got),
                                   np.arange(512.0).reshape(1, 8, 64))
        assert len(got.sharding.device_set) in (2, 4)
        print("elastic-ok")
    """)
