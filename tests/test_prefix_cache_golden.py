"""Prefix-cache bitwise correctness against the golden fixtures.

Serving a cached prefix page must be indistinguishable from recomputing
it: attention always reads the *stored* (post-quantization) page
content, and identical tokens at identical positions produce identical
codes/scales, so prefix-cache-on decoded tokens must match the
checked-in fixtures **bit for bit** — for all three serving configs
(fp, int8 KV, int4-packed weights), under the legacy paged schedule and
the unified token-budget schedule, single-device and on a tp=4 mesh.

The differential tests then force the sharing machinery to actually
fire: a shared-system-prompt workload (real hits, cache-on vs cache-off
token equality) and an adversarial mid-page divergence pair (the COW
boundary lands inside a page, so a wrong or missing device page copy
changes tokens).
"""
import json

import numpy as np
import pytest

from golden import regenerate

from repro.data import request_workload
from repro.launch.engine import ServeEngine

# every serving schedule the prefix cache rides on; page_size 8 keeps
# the fixture prompts (6/10 tokens) spanning a full + partial page
SCHEDULES = [
    ("legacy", dict(paged=True, page_size=8, prefill_chunk=8)),
    ("legacy_nochunk", dict(paged=True, page_size=8)),
    ("unified", dict(schedule="unified", page_size=8, max_batch_tokens=8)),
]


def _golden(case):
    with open(regenerate.fixture_path(case)) as f:
        return json.load(f)["tokens"]


@pytest.mark.parametrize("case", sorted(regenerate.CASES))
@pytest.mark.parametrize("sched_kw", SCHEDULES,
                         ids=[n for n, _ in SCHEDULES])
def test_prefix_cache_on_matches_golden_bitwise(case, sched_kw):
    """Cache-on output equals the (cache-off, legacy slot-engine) golden
    fixture exactly, for every schedule the cache rides on."""
    _, kw = sched_kw
    got = regenerate.run_case(case, prefix_cache=True, **kw)
    for rid, want in _golden(case).items():
        assert got[rid] == want, (
            f"{case}: prefix-cache-on tokens for rid={rid} diverged from "
            f"the golden fixture under {kw}")


@pytest.mark.parametrize("sched_kw", SCHEDULES,
                         ids=[n for n, _ in SCHEDULES])
def test_shared_prefix_on_vs_off_identical(sched_kw):
    """A workload sharing a 12-token system prompt (full page + mid-page
    partial at page_size 8): the cache really hits AND the decoded
    tokens stay identical to the cache-off engine."""
    _, kw = sched_kw
    cfg, model, params = regenerate.build_case("int8_kv")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS, seed=regenerate.SEED,
                            shared_prefix=12)
    off = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=40, **kw).run(reqs)
    on_eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                         max_len=40, prefix_cache=True, **kw)
    on = on_eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            on[r["rid"]].tokens, off[r["rid"]].tokens,
            err_msg=f"rid={r['rid']} under {kw}")
    stats = on_eng.summary()
    assert stats["prefix_hits"] > 0, "shared-prefix workload never hit"
    assert stats["prefix_hit_tokens"] > 0
    assert on_eng.pool.in_use == on_eng.prefix.resident  # drained to trie


@pytest.mark.parametrize("sched_kw", SCHEDULES,
                         ids=[n for n, _ in SCHEDULES])
def test_midpage_divergence_cow_boundary_exact(sched_kw):
    """Adversarial COW: the second prompt repeats the first for 10 of 16
    tokens, diverging INSIDE the second page (page_size 8). The hit ends
    mid-page, so admission must COW-split that page — a missing or
    misordered device page copy corrupts rows [8, 10) and changes
    tokens. Served one slot at a time so the second admission sees the
    first's registered pages."""
    _, kw = sched_kw
    cfg, model, params = regenerate.build_case("int8_kv")
    rng = np.random.default_rng(5)
    a = rng.integers(0, cfg.vocab, 16).astype(np.int32)
    b = np.concatenate([a[:10],
                        rng.integers(0, cfg.vocab, 6)]).astype(np.int32)
    assert (a[:10] == b[:10]).all() and a[10] != b[10]
    reqs = [{"rid": 0, "tokens": a, "max_new_tokens": 4},
            {"rid": 1, "tokens": b, "max_new_tokens": 4}]
    off = ServeEngine(model, params, n_slots=1, max_len=32, **kw).run(reqs)
    on_eng = ServeEngine(model, params, n_slots=1, max_len=32,
                         prefix_cache=True, **kw)
    on = on_eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            on[r["rid"]].tokens, off[r["rid"]].tokens,
            err_msg=f"rid={r['rid']} under {kw}")
    stats = on_eng.summary()
    assert stats["cow_copies"] >= 1, (
        "mid-page divergence admitted without a COW split")
    assert stats["prefix_hit_tokens"] >= 10


def test_prefix_cache_matches_golden_at_tp4():
    """Shared-prefix workload on a (1, 4) tensor-parallel mesh with the
    cache on vs a single-device cache-off engine: the COW device page
    copy runs over head-sharded pools and must stay token-identical."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 local devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    from repro.models import build

    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4,
                                                 kv_quant_bits=8)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = request_workload(cfg, 5, gen=4, lengths=(6, 10), seed=3,
                            shared_prefix=12)
    solo = ServeEngine(model, params, n_slots=2, max_len=40).run(reqs)
    mesh = make_mesh((1, 4), ("data", "model"))
    on_eng = ServeEngine(model, params, n_slots=2, max_len=40, mesh=mesh,
                         schedule="unified", max_batch_tokens=8,
                         page_size=8, prefix_cache=True)
    on = on_eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(on[r["rid"]].tokens,
                                      solo[r["rid"]].tokens,
                                      err_msg=f"rid={r['rid']}")
    assert on_eng.summary()["prefix_hits"] > 0


def test_prefix_cache_requires_paged():
    cfg, model, params = regenerate.build_case("fp")
    with pytest.raises(ValueError, match="paged"):
        ServeEngine(model, params, n_slots=2, max_len=24,
                    prefix_cache=True)
