"""Pipelined (depth-1 asynchronous) unified serving loop.

The pipelined loop (``ServeEngine(pipeline=True)``, the unified-mode
default) packs and dispatches step N+1 while step N executes on device,
sampling greedily inside the jitted step so only (n_logits,) int32
tokens ever cross D2H. It must be **bitwise token-identical** to the
synchronous loop — and therefore to the legacy golden fixtures — across
every serving configuration it composes with:

  - plain unified (tight and loose budgets, prefill chunking)
  - prefix caching (COW page sharing + one-cycle-late registration)
  - speculative decoding (optimistic verify items, partial-accept
    rollback, deferred full-accept shrink)
  - tensor parallelism (in-shard argmax over replicated logits)

Mispredict rollback: a slot that retires on eos (or a speculative
verify that accepts fewer rows than planned) invalidates its
optimistically dispatched rows in the in-flight next step; the
scheduler must discard them and rewind page state so the trajectory —
tokens AND final pool/refcount state — equals a synchronous run's.

Runs via tests/_hypothesis_shim: property cases when hypothesis is
installed, the seeded deterministic ports always.
"""
import json

import jax
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from golden import regenerate

from repro.data import request_workload
from repro.launch.engine import ServeEngine

needs4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


def _golden(case):
    with open(regenerate.fixture_path(case)) as f:
        return json.load(f)["tokens"]


def _assert_pool_drained(eng):
    """After a drained run, every page is back on the free list and the
    pool invariant holds — optimistic allocation must have been fully
    rewound regardless of how many predictions failed."""
    assert eng.idle and eng._inflight is None
    assert eng.pool.in_use == 0
    assert eng.pool.available + eng.pool.in_use == eng.pool.n_pages - 1
    if eng.draft_pool is not None:
        assert eng.draft_pool.in_use == 0


# ------------------------------------------------------- golden identity

@pytest.mark.parametrize("case", sorted(regenerate.CASES))
@pytest.mark.parametrize("kw", [
    dict(max_batch_tokens=6),                     # tight: chunked admission
    dict(max_batch_tokens=8, prefill_chunk=4),    # chunk cap on top
], ids=["budget6", "budget8chunk4"])
def test_pipelined_matches_golden_bitwise(case, kw):
    got = regenerate.run_case(case, schedule="unified", page_size=8,
                              pipeline=True, **kw)
    golden = _golden(case)
    for rid, want in golden.items():
        assert got[rid] == want, (
            f"{case} {kw}: pipelined tokens for rid={rid} diverged from "
            f"the golden fixture")


def test_pipelined_summary_and_timing_spans():
    """Pipelined summary reports the overlap metrics, and the timing
    spans keep the blocked-loop invariants: one (step_s, device_s) pair
    per OBSERVED step with 0 < device_s <= step_s (device_s is the
    token-fetch wait, a subinterval of the cycle)."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, schedule="unified",
                      page_size=8)
    assert eng.pipeline
    eng.run(reqs)
    s = eng.summary()
    assert s["pipeline"] is True
    assert 0.0 <= s["overlap_frac"] <= 1.0
    assert s["host_ms_hidden"] >= 0.0
    assert s["mispredicts"] == 0          # max_new retirement is predicted
    step_s, dev_s = eng.metrics["step_s"], eng.metrics["device_s"]
    assert len(step_s) == len(dev_s) > 0
    for ss, d in zip(step_s, dev_s):
        assert 0.0 < d <= ss
    _assert_pool_drained(eng)


def test_pipelined_equals_sync_loop():
    """pipeline=True vs pipeline=False on the same config: identical
    tokens, and the sync run reports pipeline=False with zero overlap."""
    a = regenerate.run_case("fp", schedule="unified", page_size=8,
                            max_batch_tokens=6, pipeline=True)
    b = regenerate.run_case("fp", schedule="unified", page_size=8,
                            max_batch_tokens=6, pipeline=False)
    assert a == b


def test_sync_env_var_forces_synchronous(monkeypatch):
    """REPRO_SYNC_STEP=1 flips the unified default to the synchronous
    loop (profiling mode: honest blocked per-step spans)."""
    monkeypatch.setenv("REPRO_SYNC_STEP", "1")
    cfg, model, params = regenerate.build_case("fp")
    eng = ServeEngine(model, params, n_slots=2, max_len=24,
                      schedule="unified", page_size=8)
    assert eng.pipeline is False
    # an explicit pipeline=True still wins over the env default
    eng2 = ServeEngine(model, params, n_slots=2, max_len=24,
                       schedule="unified", page_size=8, pipeline=True)
    assert eng2.pipeline is True


def test_pipeline_needs_unified_schedule():
    cfg, model, params = regenerate.build_case("fp")
    with pytest.raises(ValueError, match="pipeline"):
        ServeEngine(model, params, n_slots=2, max_len=24, pipeline=True)


# -------------------------------------------------- prefix cache compose

def test_pipelined_prefix_cache_matches_golden():
    """Prefix caching under the pipelined loop: identical tokens on the
    cold pass AND on a warm rerun (shared pages + COW splits + the
    one-cycle-late prefix registration of optimistic tail pages)."""
    cfg, model, params = regenerate.build_case("int8_kv")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    golden = _golden("int8_kv")
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, schedule="unified",
                      page_size=8, prefix_cache=True)
    for label in ("cold", "warm"):
        res = eng.run(reqs)
        for r in reqs:
            assert (np.asarray(res[r["rid"]].tokens).tolist()
                    == golden[str(r["rid"])]), (label, r["rid"])
        eng.reset()     # keeps the trie warm, so pass 2 serves from hits


# --------------------------------------------------- speculative compose

@pytest.fixture(scope="module")
def spec_draft():
    from repro.launch.serve import build_draft_model
    return build_draft_model("catlm_60m", True, 0)


@pytest.mark.parametrize("k", [2, 4])
def test_pipelined_speculative_matches_golden(k, spec_draft):
    """Speculative decoding under the pipelined loop (draft base token
    injected from the in-flight target step's device vector; partial
    accepts roll the optimistic next step back): bitwise equal to the
    target-only golden fixture."""
    got = regenerate.run_case("fp", schedule="unified", page_size=8,
                              max_batch_tokens=12, speculative_k=k,
                              draft=spec_draft, pipeline=True)
    golden = _golden("fp")
    for rid, want in golden.items():
        assert got[rid] == want, f"k={k} rid={rid}"


def test_pipelined_speculative_eos_rollback(spec_draft):
    """eos retirement + short speculative accepts both mispredict; the
    pipelined trajectory must still match the synchronous one exactly
    and drain the pools completely."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, 4, gen=6, lengths=(6, 10), seed=11)
    base = ServeEngine(model, params, n_slots=2, max_len=24,
                       schedule="unified", page_size=8, speculative_k=2,
                       draft=spec_draft, pipeline=False).run(reqs)
    # an eos seen mid-stream in the no-eos run forces early retirement
    eos = int(base[0].tokens[base[0].prompt_len])
    runs = []
    for pipeline in (False, True):
        eng = ServeEngine(model, params, n_slots=2, max_len=24,
                          schedule="unified", page_size=8, speculative_k=2,
                          draft=spec_draft, eos_id=eos, pipeline=pipeline)
        runs.append(eng.run(reqs))
        _assert_pool_drained(eng)
    for r in reqs:
        np.testing.assert_array_equal(
            runs[1][r["rid"]].tokens, runs[0][r["rid"]].tokens,
            err_msg=f"rid={r['rid']}: pipelined spec+eos diverged")


# --------------------------------------------------------- tp=4 compose

@needs4
def test_pipelined_tp4_mha_matches_sync_solo():
    """tp=4 mesh (gather mode, MHA head-count override): the in-shard
    argmax runs over replicated logits, so pipelined mesh tokens equal
    the single-device synchronous run bitwise."""
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    from repro.models import build

    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = request_workload(cfg, 6, gen=5, lengths=(6, 10, 14), seed=3)
    solo = ServeEngine(model, params, n_slots=3, max_len=32,
                       schedule="unified", page_size=8,
                       pipeline=False).run(reqs)
    mesh = make_mesh((1, 4), ("data", "model"))
    eng = ServeEngine(model, params, n_slots=3, max_len=32, mesh=mesh,
                      schedule="unified", page_size=8, pipeline=True)
    meshed = eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            meshed[r["rid"]].tokens, solo[r["rid"]].tokens,
            err_msg=f"rid={r['rid']}: tp=4 pipelined diverged")
    assert eng.summary()["pipeline"] is True
    _assert_pool_drained(eng)


# -------------------------------------------------- mispredict rollback

def test_eos_mispredict_rolls_back_to_sync_trajectory():
    """Force mid-stream eos retirements: the optimistic next step was
    already dispatched for the retiring slot, so observe() must mark its
    rows stale, discard their tokens, and release the slot's pages —
    leaving output AND pool state equal to the synchronous run."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, 4, gen=6, lengths=(6, 10), seed=11)
    base = ServeEngine(model, params, n_slots=2, max_len=24,
                       schedule="unified", page_size=8,
                       pipeline=False).run(reqs)
    # the 2nd generated token of request 0 retires it 4 tokens early —
    # its slot is mid-decode, so the next step always has it packed
    eos = int(base[0].tokens[base[0].prompt_len + 1])
    runs, engines = [], []
    for pipeline in (False, True):
        eng = ServeEngine(model, params, n_slots=2, max_len=24,
                          schedule="unified", page_size=8, eos_id=eos,
                          pipeline=pipeline)
        runs.append(eng.run(reqs))
        engines.append(eng)
        _assert_pool_drained(eng)
    sync_eng, pipe_eng = engines
    for r in reqs:
        np.testing.assert_array_equal(
            runs[1][r["rid"]].tokens, runs[0][r["rid"]].tokens,
            err_msg=f"rid={r['rid']}: eos rollback diverged")
    assert pipe_eng.summary()["mispredicts"] > 0
    assert sync_eng.summary()["mispredicts"] == 0
    # generated-token accounting excludes discarded stale outputs
    assert (pipe_eng.metrics["generated_tokens"]
            == sync_eng.metrics["generated_tokens"])


@given(seed=st.integers(0, 40), which=st.integers(0, 3),
       depth=st.integers(0, 2))
@settings(max_examples=12, deadline=None)
def test_property_forced_retirement_equals_sync(seed, which, depth):
    """Property port of the rollback test: for random workloads and a
    random forced-eos choice, the pipelined trajectory (tokens, retire
    events, final pool state) equals the synchronous one."""
    _forced_retirement_case(seed, which, depth)


@pytest.mark.parametrize("seed,which,depth",
                         [(11, 0, 1), (3, 2, 0), (7, 1, 2)])
def test_forced_retirement_equals_sync_seeded(seed, which, depth):
    """Deterministic port of the property case (always runs)."""
    _forced_retirement_case(seed, which, depth)


def _forced_retirement_case(seed, which, depth):
    from test_scheduler_properties import _stub

    rng = np.random.default_rng(seed)
    reqs = [{"rid": i,
             "tokens": rng.integers(0, 64, int(p)).astype(np.int32),
             "max_new_tokens": int(g)}
            for i, (p, g) in enumerate(zip(rng.integers(1, 12, 4),
                                           rng.integers(2, 7, 4)))]
    base = ServeEngine(_stub(), {}, n_slots=2, max_len=24,
                       schedule="unified", page_size=4,
                       pipeline=False).run(reqs)
    rid = int(which) % len(reqs)
    gen = base[rid].tokens[base[rid].prompt_len:]
    eos = int(gen[min(int(depth), len(gen) - 1)])
    runs, engines = [], []
    for pipeline in (False, True):
        eng = ServeEngine(_stub(), {}, n_slots=2, max_len=24,
                          schedule="unified", page_size=4, eos_id=eos,
                          pipeline=pipeline)
        runs.append(eng.run(reqs))
        engines.append(eng)
        _assert_pool_drained(eng)
    for r in reqs:
        np.testing.assert_array_equal(
            runs[1][r["rid"]].tokens, runs[0][r["rid"]].tokens,
            err_msg=f"rid={r['rid']} seed={seed} eos={eos}")
    # every request retires exactly once in both modes (event ORDER may
    # differ: pipelined admission lags one cycle behind a retirement it
    # hasn't observed yet, which can land two retirements in different
    # cycles — tokens and pool state are what must match)
    for eng in engines:
        retires = sorted(e[1] for e in eng.events if e[0] == "retire")
        assert retires == sorted(r["rid"] for r in reqs)


# ------------------------------------------------------ reset mid-flight

def test_reset_mid_flight_refused_then_clean_after_drain():
    """reset() must refuse while a pipelined step is in flight (the
    engine is not idle), and a post-drain reset must clear the in-flight
    slot, the descriptor-ring parity, and the executor's previous-token
    vector so a rerun reproduces the first run exactly."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, schedule="unified",
                      page_size=8)
    for r in reqs:
        eng.submit(r["tokens"], r["max_new_tokens"], rid=r.get("rid"))
    eng.step()
    eng.step()
    assert eng._inflight is not None and not eng.idle
    with pytest.raises(RuntimeError, match="idle"):
        eng.reset()
    while not eng.idle:
        eng.step()
    first = {r["rid"]: np.asarray(eng.results[r["rid"]].tokens).copy()
             for r in reqs}
    eng.reset()
    assert eng._inflight is None
    assert eng.sched._buf_parity == 0
    assert eng.exec._prev is None
    assert eng._host_s == eng._hidden_s == 0.0
    res = eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(res[r["rid"]].tokens,
                                      first[r["rid"]])


# ------------------------------------------- legacy executor regression

def test_legacy_decode_device_argmax_and_d2h_attribution():
    """LegacyExecutor.decode samples on device — the returned array is
    the (n_slots,) int32 token vector, and the (tiny) D2H copy is
    attributed to d2h_s / d2h_ms_mean instead of inflating the engine's
    compute span. Output stays pinned to the golden fixture."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN)
    toks = np.zeros((regenerate.N_SLOTS, 1), np.int32)
    pos = np.zeros((regenerate.N_SLOTS,), np.int32)
    out = eng.exec.decode(toks, pos)
    assert out.shape == (regenerate.N_SLOTS,) and out.dtype == np.int32
    assert eng.exec.d2h_s > 0.0
    res = eng.run(reqs)
    golden = _golden("fp")
    for r in reqs:
        assert (np.asarray(res[r["rid"]].tokens).tolist()
                == golden[str(r["rid"])]), r["rid"]
    s = eng.summary()
    assert "d2h_ms_mean" in s and s["d2h_ms_mean"] > 0.0
    assert s["device_ms_mean"] > 0.0
