"""Two-launch decode: the fused QKV-prologue kernel + its routing.

Covers the PR's pieces end to end:

- ``kernels.decode_layer.decode_qkv_prologue`` (interpret mode) vs the
  eager ``ref.decode_qkv_prologue`` oracle: rope'd q at rtol 1e-5 (XLA
  FMA-contracts the kernel's fused f32 chains, so bitwise is out of
  reach by construction), scattered int8 KV codes **bitwise**, scale
  pools at rtol — packed and unpacked weights, with/without the
  block-CAT stage, multi-tile N and K grids, padded batches (B < 8)
- the in-kernel RoPE + KV-quantize + paged scatter vs the
  ``models.layers`` composition (``rope`` + ``quantize_kv`` +
  ``paged_cache_update_quantized``): bitwise, including ragged last
  pages and rows straddling a page boundary
- null-page parking: padded rows and explicit null-page targets leave
  every real page untouched (page 0 is outside the pool contract)
- the COW write guard: ``SlotPageTables.assert_writable`` rejects
  scatters into refcount>1 shared pages until ``ensure_writable`` splits
  them — the host-side invariant that makes the kernel's in-place pool
  writes safe under prefix caching
- the ``REPRO_PALLAS_INTERPRET`` / ``REPRO_DECODE_FUSED`` env switches
  (``ops.default_interpret`` / ``ops.use_fused_decode``) so kernel tests
  run (not skip) on CPU CI and the fused layer path stays opt-in off-TPU
- model-level routing: with ``REPRO_DECODE_FUSED=1`` every decode layer
  dispatches the prologue exactly once; numerics follow the
  integer-accumulation route (``qlinear`` route 3 == the TPU kernel
  route), so tokens are compared against the route-3 expectation, not
  the portable bf16 path
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import pack_int4
from repro.kernels import ops, ref
from repro.kernels.decode_layer import decode_qkv_prologue

HD = 8          # head_dim
N_Q = 32        # 4 q heads
N_KV = 16       # 2 kv heads
PAGE = 4        # page_size
PAGES = 10      # pool pages (page 0 = null)


def _factor(d):
    a = int(np.sqrt(d))
    while d % a:
        a -= 1
    return a, d // a


def _operands(b, d, seed, n_blocks=0):
    """Random prologue operands + a pre-populated paged pool."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((b, d)), jnp.float32)
    blocks = None
    if n_blocks:
        bk = d // n_blocks
        blocks = jnp.asarray(
            r.standard_normal((n_blocks, bk, bk)) * 0.3 + np.eye(bk),
            jnp.float32)
    a, bb = _factor(d)
    ha = jnp.asarray(r.standard_normal((a, a)) / np.sqrt(a), jnp.float32)
    hb = jnp.asarray(r.standard_normal((bb, bb)) / np.sqrt(bb), jnp.float32)
    sign = jnp.asarray(r.integers(0, 2, d) * 2 - 1, jnp.float32)
    n = N_Q + 2 * N_KV
    qw = jnp.asarray(r.integers(-8, 8, (d, n)), jnp.int8)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    kvh = N_KV // HD
    shape = (PAGES, PAGE, kvh, HD)
    pools = (jnp.asarray(r.integers(-128, 128, shape), jnp.int8),
             jnp.asarray(r.uniform(0.01, 1.0, shape[:-1] + (1,)),
                         jnp.float32),
             jnp.asarray(r.integers(-128, 128, shape), jnp.int8),
             jnp.asarray(r.uniform(0.01, 1.0, shape[:-1] + (1,)),
                         jnp.float32))
    return x, blocks, ha, hb, sign, qw, sw, pools


def _run_both(b, d, seed, n_blocks=0, packed=True, pids=None, rows=None,
              positions=None, **kernel_kw):
    x, blocks, ha, hb, sign, qw, sw, pools = _operands(b, d, seed, n_blocks)
    if pids is None:
        pids = np.arange(1, 1 + b, dtype=np.int32)
    if rows is None:
        rows = np.full(b, 1, np.int32)
    if positions is None:
        positions = np.arange(3, 3 + b, dtype=np.int32)
    pids = jnp.asarray(pids, jnp.int32)
    rows = jnp.asarray(rows, jnp.int32)
    positions = jnp.asarray(positions, jnp.int32)
    qw_store = pack_int4(np.asarray(qw), axis=0) if packed else qw
    kw = dict(n_q=N_Q, head_dim=HD, rope_theta=1e4, kv_bits=8, act_bits=8,
              packed=packed)
    got = decode_qkv_prologue(x, blocks, ha, hb, sign, jnp.asarray(qw_store),
                              sw, *pools, pids, rows, positions,
                              interpret=True, **kw, **kernel_kw)
    want = ref.decode_qkv_prologue(x, blocks, ha, hb, sign,
                                   jnp.asarray(qw_store), sw, *pools,
                                   pids, rows, positions, **kw)
    return got, want, pools, (pids, rows)


def _assert_pools_match(got, want):
    """Pools equal outside the null page: codes bitwise, scales rtol."""
    for g, w, name in ((got[1], want[1], "k"), (got[3], want[3], "v")):
        np.testing.assert_array_equal(np.asarray(g)[1:], np.asarray(w)[1:],
                                      err_msg=f"{name} codes")
    for g, w, name in ((got[2], want[2], "k_scale"),
                       (got[4], want[4], "v_scale")):
        np.testing.assert_allclose(np.asarray(g)[1:], np.asarray(w)[1:],
                                   rtol=1e-5, atol=1e-8, err_msg=name)


@pytest.mark.parametrize("n_blocks", [0, 3])
@pytest.mark.parametrize("packed", [True, False])
def test_kernel_matches_oracle(n_blocks, packed):
    got, want, _, _ = _run_both(8, 24, seed=0, n_blocks=n_blocks,
                                packed=packed)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-6)
    _assert_pools_match(got, want)


@pytest.mark.parametrize("block_n,block_k", [(32, 512), (256, 8), (32, 8)])
def test_kernel_matches_oracle_multi_tile(block_n, block_k):
    """gn > 1 / gk > 1 grids: the accumulator add path and the
    park-until-last-flush pool index maps."""
    got, want, _, _ = _run_both(8, 24, seed=1, n_blocks=3,
                                block_n=block_n, block_k=block_k)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-6)
    _assert_pools_match(got, want)


@pytest.mark.parametrize("b", [1, 3, 8])
def test_padded_batch(b):
    """B < 8 rows are padded internally; padding lands on the null page
    and every real page matches the oracle."""
    got, want, _, _ = _run_both(b, 24, seed=2, n_blocks=3)
    assert got[0].shape == (b, N_Q)
    np.testing.assert_allclose(got[0], want[0], rtol=1e-5, atol=1e-6)
    _assert_pools_match(got, want)


def test_ragged_last_page():
    """Rows on a ragged last page: one row at the final slot of a page,
    one on the first slot of the next — scatter targets stay exact."""
    pids = np.array([1, 2, 3], np.int32)
    rows = np.array([PAGE - 1, 0, 2], np.int32)
    positions = np.array([PAGE - 1, PAGE, 2], np.int32)
    got, want, pools, (jp, jr) = _run_both(
        3, 24, seed=3, n_blocks=3, pids=pids, rows=rows, positions=positions)
    _assert_pools_match(got, want)
    # the targeted rows really changed vs the pre-existing pool content
    kp0 = np.asarray(pools[0])
    kp1 = np.asarray(got[1])
    for p, r in zip(pids, rows):
        assert not np.array_equal(kp1[p, r], kp0[p, r])


def test_untouched_pages_and_null_target():
    """Aliased pool rows the grid never targets keep their content
    bitwise — including when a real row explicitly targets the null
    page (an engine padding row): no real page may change at all."""
    pids = np.array([0, 0, 0], np.int32)     # all rows -> null page
    got, _, pools, _ = _run_both(3, 24, seed=4, n_blocks=3, pids=pids,
                                 rows=np.zeros(3, np.int32))
    for g, orig in ((got[1], pools[0]), (got[2], pools[1]),
                    (got[3], pools[2]), (got[4], pools[3])):
        np.testing.assert_array_equal(np.asarray(g)[1:],
                                      np.asarray(orig)[1:])


def test_oracle_matches_layers_composition():
    """Satellite: the oracle's RoPE + KV-quant + scatter epilogue is
    bitwise identical to the ``models.layers`` composition the composed
    decode path runs (``rope`` + ``quantize_kv`` +
    ``paged_cache_update_quantized``)."""
    from repro.models.layers import (_paged_indices,
                                     paged_cache_update_quantized, rope)

    b, d = 3, 24
    x, blocks, ha, hb, sign, qw, sw, pools = _operands(b, d, seed=5,
                                                       n_blocks=3)
    n_ptab = 3
    table = jnp.asarray(
        np.arange(1, 1 + b * n_ptab, dtype=np.int32).reshape(b, n_ptab))
    pos = jnp.asarray([PAGE - 1, PAGE, 2], jnp.int32)   # ragged last pages
    pids, rows = _paged_indices(table, pos, b, 1, PAGE)
    qw_p = jnp.asarray(pack_int4(np.asarray(qw), axis=0))
    q, kp, ks, vp, vs = ref.decode_qkv_prologue(
        x, blocks, ha, hb, sign, qw_p, sw, *pools, pids, rows, pos,
        n_q=N_Q, head_dim=HD, rope_theta=1e4)

    # the same y rows through the layers composition
    q8, sx, zx = ref.kernel_transform_quant(x, blocks, ha, hb, sign)
    y = ref.quant_matmul(q8, sx, zx, ref.unpack_int4(qw_p, d), sw)
    kvh = N_KV // HD
    k = rope(y[:, N_Q:N_Q + N_KV].reshape(b, 1, kvh, HD), pos[:, None],
             theta=1e4)
    v = y[:, N_Q + N_KV:].reshape(b, 1, kvh, HD)
    kp2, ks2, vp2, vs2 = paged_cache_update_quantized(
        *pools, k, v, table, pos, 8)
    np.testing.assert_array_equal(kp, kp2)
    np.testing.assert_array_equal(vp, vp2)
    np.testing.assert_array_equal(ks, ks2)
    np.testing.assert_array_equal(vs, vs2)


def test_cow_guard_rejects_shared_pages():
    """The kernel scatters in place, so the host-side COW guard is what
    keeps prefix-cache shared pages safe: a slot mapped onto refcount>1
    pages must fail ``assert_writable`` until ``ensure_writable``
    splits, after which the scatter window is accepted."""
    from repro.launch.paged import PagePool, SlotPageTables

    pool = PagePool(n_pages=16, page_size=PAGE)
    tables = SlotPageTables(pool, n_slots=2, n_ptab=4)
    tables.admit(0, 2 * PAGE)                  # slot 0 owns two pages
    shared = [int(p) for p in tables.table[0, :2]]
    for p in shared:
        pool.incref(p)
    tables.admit_prefix(1, shared, 2 * PAGE, 2 * PAGE + 1)
    with pytest.raises(RuntimeError,
                       match="read-only until COW-split"):
        tables.assert_writable(1, 0, PAGE - 1)
    cow = tables.ensure_writable(1, 0)
    assert len(cow) == 1 and cow[0][0] == shared[0]
    tables.assert_writable(1, 0, PAGE - 1)     # now exclusively owned


def test_env_switches(monkeypatch):
    """Satellite: REPRO_PALLAS_INTERPRET forces interpret mode on or off
    regardless of backend; REPRO_DECODE_FUSED opts the fused decode
    layer in/out (default: TPU only)."""
    on_tpu = jax.default_backend() == "tpu"
    monkeypatch.delenv("REPRO_PALLAS_INTERPRET", raising=False)
    monkeypatch.delenv("REPRO_DECODE_FUSED", raising=False)
    assert ops.default_interpret() is (not on_tpu)
    assert ops.use_fused_decode() is on_tpu
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
    assert ops.default_interpret() is True
    monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
    assert ops.default_interpret() is False
    monkeypatch.setenv("REPRO_DECODE_FUSED", "1")
    assert ops.use_fused_decode() is True
    monkeypatch.setenv("REPRO_DECODE_FUSED", "off")
    assert ops.use_fused_decode() is False


@pytest.mark.slow
def test_fused_layer_routing(monkeypatch):
    """REPRO_DECODE_FUSED=1 routes every decode layer through the
    prologue exactly once; pages outside each slot's table (and the
    null page) stay bitwise identical to the composed path's."""
    from repro.launch.serve import build_served_model
    from repro.models import dense

    cfg, model, params, _ = build_served_model("catlm_60m", "cat", 4, 4, 8,
                                               smoke=True, seed=0)
    msp = dense.make_serving_params(cfg, params)
    b, n_ptab = 3, 4
    cache0 = dense.init_paged_cache(cfg, n_pages=32, page_size=PAGE)
    table = jnp.asarray(
        np.arange(1, 1 + b * n_ptab, dtype=np.int32).reshape(b, n_ptab))
    tok = jnp.asarray([[5], [7], [11]], jnp.int32)

    calls = {"n": 0}
    real = ops.decode_qkv_prologue

    def counted(*a, **kw):
        calls["n"] += 1
        return real(*a, **kw)

    monkeypatch.setattr(ops, "decode_qkv_prologue", counted)

    def step(fused):
        monkeypatch.setenv("REPRO_DECODE_FUSED", "1" if fused else "0")
        calls["n"] = 0
        c = dict(cache0)
        c["pos"] = jnp.int32(2)
        c["page_table"] = table
        logits, c = dense.decode(cfg, msp, tok, c, paged_kernel=True,
                                 unroll=True)
        return logits, c, calls["n"]

    logits_c, cache_c, n_c = step(False)
    logits_f, cache_f, n_f = step(True)
    assert n_c == 0 and n_f == cfg.n_layers
    assert bool(jnp.all(jnp.isfinite(logits_f)))
    assert logits_f.shape == logits_c.shape
    # pages owned by no slot stay bitwise equal across the two routes
    used = set(np.asarray(table).ravel().tolist()) | {0}
    mask = np.array([p not in used for p in range(32)])
    for key in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(cache_c[key])[:, mask],
                                      np.asarray(cache_f[key])[:, mask])
