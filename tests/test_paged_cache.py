"""Paged KV cache: equivalence, allocator, and kernel coverage.

- **Paged-vs-contiguous equivalence**: the paged engine (all variants —
  whole-prompt bucketed prefill, chunked prefill, unbucketed) must
  reproduce the checked-in golden token fixtures *bitwise* for fp,
  int8-KV, and w4-packed configs; the gathered logical view is the same
  tensor the slot cache holds, so this is equality, not tolerance. The
  tp=4 mesh variant pins token identity against the solo engine (the
  golden cfg is GQA n_kv_heads=2, which tp=4 correctly rejects — same
  MHA-override convention as tests/test_tp_serve.py).
- **Allocator properties** (``repro.launch.paged``): no double
  allocation, exactly-once free, null page never handed out, and
  fragmentation bounded — any free page satisfies any request, so
  ``available`` pages are always all allocatable. Hypothesis drives
  random op sequences when installed; seeded deterministic ports always
  run (tests/_hypothesis_shim).
- **Paged-attention kernel**: Pallas kernel vs the jnp oracle at rtol
  1e-5 including ragged last pages and null-page table entries; the
  gather fallback matches exactly; ops dispatch routes fp pools to the
  fallback.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st
from golden import regenerate
from repro.data import request_workload
from repro.launch.engine import ServeEngine
from repro.launch.paged import NULL_PAGE, PagePool, SlotPageTables

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

_BUILT = {}


def built(case):
    if case not in _BUILT:
        _BUILT[case] = regenerate.build_case(case)
    return _BUILT[case]


def drain_paged(case, **engine_kw):
    cfg, model, params = built(case)
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, **engine_kw)
    results = eng.run(reqs)
    got = {str(r["rid"]): np.asarray(results[r["rid"]].tokens).tolist()
           for r in reqs}
    return got, eng


def golden_tokens(case):
    with open(regenerate.fixture_path(case)) as f:
        return json.load(f)["tokens"]


# ------------------------------------------------ golden bitwise equivalence

PAGED_VARIANTS = {
    "paged8": dict(paged=True, page_size=8),
    "chunked": dict(paged=True, page_size=4, prefill_chunk=8),
    "unbucketed": dict(paged=True, page_size=8, bucket=False),
}


@pytest.mark.parametrize("case", sorted(regenerate.CASES))
@pytest.mark.parametrize("variant", sorted(PAGED_VARIANTS))
def test_paged_engine_matches_golden(case, variant):
    """Every paged serving variant decodes the exact fixture tokens."""
    got, eng = drain_paged(case, **PAGED_VARIANTS[variant])
    want = golden_tokens(case)
    assert set(got) == set(want)
    for rid in want:
        assert got[rid] == want[rid], (
            f"{case}/{variant}: paged engine diverged from the golden "
            f"fixture for rid={rid}")
    assert eng.pool.in_use == 0, "drained engine must return every page"


def test_paged_resident_bytes_below_slot_cache():
    """The economics: on the mixed-length workload the paged pool's mean
    resident KV bytes sit well under the slot cache's flat allocation."""
    got, eng = drain_paged("int8_kv", paged=True, page_size=4)
    slot_eng = ServeEngine(*built("int8_kv")[1:],
                           n_slots=regenerate.N_SLOTS,
                           max_len=regenerate.MAX_LEN)
    s = eng.summary()
    assert s["paged"] and s["resident_kv_bytes_mean"] > 0
    assert s["resident_kv_bytes_mean"] < slot_eng.resident_kv_bytes()
    assert s["resident_kv_bytes_peak"] <= s["kv_capacity_bytes"]


@needs_mesh
@pytest.mark.parametrize("quantize", [False, True],
                         ids=["int8_kv", "w4_packed"])
def test_paged_mesh_tp4_token_identical(quantize):
    """Paged engine on a (1, 4) tp mesh: sharded page pool (heads on
    'model', pages whole, table replicated) decodes token-identically to
    the single-device slot engine."""
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    from repro.models import build

    base = get_config("catlm_60m").smoke().scaled(n_kv_heads=4)
    model_fp = build(base)
    params = model_fp.init(jax.random.PRNGKey(0))
    if quantize:
        from repro.core.pipeline import QuantizeConfig, quantize_model
        from repro.data import calibration_batches
        params = quantize_model(
            model_fp, params,
            QuantizeConfig(w_bits=4, a_bits=4, transform="cat",
                           cat_block=16),
            calibration_batches(base, n_seqs=2, seq_len=16, batch=2))
    cfg = base.scaled(kv_quant_bits=8)
    model = build(cfg)
    mesh = make_mesh((1, 4), ("data", "model"))
    reqs = request_workload(cfg, 5, gen=4, lengths=(6, 10), seed=3)
    solo = ServeEngine(model, params, n_slots=2, max_len=24).run(reqs)
    meshed = ServeEngine(model, params, n_slots=2, max_len=24, mesh=mesh,
                         paged=True, page_size=8, prefill_chunk=8).run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(meshed[r["rid"]].tokens,
                                      solo[r["rid"]].tokens,
                                      err_msg=f"rid={r['rid']}")


@needs_mesh
def test_paged_mesh_rejects_dp():
    """The page pool is global, so its writes can't shard over 'data' —
    a (2, 2) mesh must fail loudly at construction."""
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    from repro.models import build

    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(NotImplementedError, match="tensor-parallel only"):
        ServeEngine(model, params, n_slots=2, max_len=24, paged=True,
                    mesh=make_mesh((2, 2), ("data", "model")))


def test_paged_kernel_engine_agrees_with_golden():
    """paged_kernel=True streams int8 pages through the Pallas kernel —
    rtol-level numerics, so assert high token agreement, not equality."""
    got, _ = drain_paged("int8_kv", paged=True, page_size=8,
                         paged_kernel=True)
    want = golden_tokens("int8_kv")
    agree = np.mean([np.mean(np.asarray(got[rid]) == np.asarray(want[rid]))
                     for rid in want])
    assert agree >= 0.9, agree


# ------------------------------------------------------ engine validation

def test_paged_engine_validation():
    cfg, model, params = built("int8_kv")
    make = lambda **kw: ServeEngine(model, params, n_slots=2, max_len=24,
                                    **kw)  # noqa: E731
    with pytest.raises(ValueError, match="multiple of"):
        make(paged=True, page_size=8, prefill_chunk=12)
    with pytest.raises(ValueError, match="page_size"):
        make(paged=True, page_size=0)
    with pytest.raises(ValueError, match="prefill_chunk"):
        make(paged=True, page_size=8, prefill_chunk=-8)
    with pytest.raises(ValueError, match="paged=True"):
        make(prefill_chunk=8)
    with pytest.raises(ValueError, match="paged=True"):
        make(paged_kernel=True)
    # a request that could never fit the (shrunken) pool fails at submit
    eng = make(paged=True, page_size=8, n_pages=2)
    with pytest.raises(ValueError, match="pages"):
        eng.submit(np.arange(1, 10), 8)


def test_paged_pool_exhaustion_waits_not_corrupts():
    """With a pool too small for all slots at once, admission head-of-line
    waits (FIFO preserved) and every request still finishes correctly."""
    cfg, model, params = built("fp")
    reqs = request_workload(cfg, 4, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    # 3 allocatable pages of 8: budgets (prompt+gen) need 2 pages each,
    # so at most one request's reservation fits at a time
    eng = ServeEngine(model, params, n_slots=2, max_len=24, paged=True,
                      page_size=8, n_pages=4)
    results = eng.run(reqs)
    assert len(results) == 4
    admits = [e for e in eng.events if e[0] == "admit"]
    assert [e[1] for e in admits] == sorted(e[1] for e in admits), "FIFO"
    assert eng.pool.in_use == 0


# ------------------------------------------------- allocator property tests

def _churn(pool_pages, ops):
    """Deterministic allocator churn: ops drive alloc/free; invariants
    checked after every step."""
    pool = PagePool(pool_pages, page_size=8)
    held = []
    for op in ops:
        if op % 2 == 0 and pool.available:
            page = pool.alloc()
            assert page != NULL_PAGE, "null page must never be allocated"
            assert page not in held, "page handed out twice"
            held.append(page)
        elif held:
            pool.free(held.pop(op % len(held)))
        assert pool.available + pool.in_use == pool.n_pages - 1
        assert pool.in_use == len(held)
    # fragmentation bound: every remaining free page is allocatable
    extra = [pool.alloc() for _ in range(pool.available)]
    assert len(set(extra + held)) == pool.n_pages - 1
    assert pool.available == 0


@pytest.mark.parametrize("seed", range(4))
def test_page_pool_invariants_ports(seed):
    rng = np.random.default_rng(seed)
    _churn(int(rng.integers(2, 20)), rng.integers(0, 97, size=200).tolist())


if HAVE_HYPOTHESIS:
    @given(st.integers(2, 24),
           st.lists(st.integers(0, 96), max_size=300))
    @settings(max_examples=50, deadline=None)
    def test_page_pool_invariants(pool_pages, ops):
        _churn(pool_pages, ops)
else:
    @given()
    def test_page_pool_invariants():
        pass  # skipped via shim


def test_page_pool_double_free_and_foreign_free_raise():
    pool = PagePool(4, 8)
    page = pool.alloc()
    pool.free(page)
    with pytest.raises(RuntimeError, match="double free|not allocated"):
        pool.free(page)
    with pytest.raises(RuntimeError, match="not allocated"):
        pool.free(3)
    with pytest.raises(RuntimeError, match="exhausted"):
        for _ in range(99):
            pool.alloc()


def test_slot_tables_lifecycle():
    pool = PagePool(1 + 2 * 3, page_size=8)
    tables = SlotPageTables(pool, n_slots=2, n_ptab=3)
    tables.admit(0, 9)                      # 2 pages for 9 tokens
    assert tables.n_owned(0) == 2 and pool.in_use == 2
    assert (tables.table[0, :2] > 0).all() and tables.table[0, 2] == 0
    tables.ensure(0, 15)                    # still page 1
    assert tables.n_owned(0) == 2
    tables.ensure(0, 16)                    # crosses into page 2
    assert tables.n_owned(0) == 3
    tables.admit(1, 1)
    assert pool.in_use == 4
    assert set(tables.table[0][tables.table[0] > 0]).isdisjoint(
        tables.table[1][tables.table[1] > 0]), "slots share a page"
    with pytest.raises(RuntimeError, match="exceeds"):
        tables.ensure(0, 24)
    tables.release(0)
    assert pool.in_use == 1 and (tables.table[0] == NULL_PAGE).all()
    tables.release(1)
    assert pool.in_use == 0


# ------------------------------------------------- kernel vs oracle

def _rand_paged(seed, b=3, kvh=2, g=2, hd=16, page=8, n_ptab=3):
    from repro.kernels import ref
    rng = np.random.default_rng(seed)
    n_pages = 1 + b * n_ptab
    q = jnp.asarray(rng.normal(size=(b, kvh, g, hd)).astype(np.float32))
    mk = lambda: jnp.asarray(rng.integers(  # noqa: E731
        -127, 128, size=(n_pages, page, kvh, hd)).astype(np.int8))
    ms = lambda: jnp.asarray(rng.uniform(  # noqa: E731
        0.01, 0.1, size=(n_pages, page, kvh, 1)).astype(np.float32))
    kp, vp, ks, vs = mk(), mk(), ms(), ms()
    table = np.zeros((b, n_ptab), np.int32)
    lengths = np.zeros((b,), np.int32)
    for i in range(b):
        # ragged: lengths deliberately include 1, partial pages, full
        lengths[i] = int(rng.integers(1, n_ptab * page + 1))
        n_owned = -(-int(lengths[i]) // page)
        table[i, :n_owned] = 1 + i * n_ptab + np.arange(n_owned)
    args = (q, kp, ks, vp, vs, jnp.asarray(table), jnp.asarray(lengths))
    return args, ref.paged_attention_decode(*args)


@pytest.mark.parametrize("seed", range(3))
def test_paged_attention_kernel_vs_oracle(seed):
    from repro.kernels import ops
    args, want = _rand_paged(seed)
    got = ops.paged_attention(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_paged_attention_ragged_last_page_exact_zero_weight():
    """A position past lengths[b] must contribute *exactly* nothing:
    poisoning masked rows with huge codes cannot move the output."""
    from repro.kernels import ops
    (q, kp, ks, vp, vs, table, lengths), _ = _rand_paged(7)
    base = ops.paged_attention(q, kp, ks, vp, vs, table, lengths)
    page = kp.shape[1]
    poisoned_k, poisoned_v = np.array(kp), np.array(vp)
    for b in range(q.shape[0]):
        n = int(lengths[b])
        idx, row = n // page, n % page    # first masked position
        if idx < table.shape[1] and int(table[b, idx]) > 0:
            poisoned_k[int(table[b, idx]), row:] = 127
            poisoned_v[int(table[b, idx]), row:] = -127
    got = ops.paged_attention(q, jnp.asarray(poisoned_k), ks,
                              jnp.asarray(poisoned_v), vs, table, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(base))


def test_paged_attention_fp_pool_routes_to_fallback():
    """An fp pool (no scales) through ops dispatch must equal the
    quantized oracle on equivalent inputs: dequantizing the pool outside
    (codes·scale in f32) is the exact same op the oracle runs inside."""
    from repro.kernels import ops
    (q, kp, ks, vp, vs, table, lengths), want = _rand_paged(13)
    kf = kp.astype(jnp.float32) * ks
    vf = vp.astype(jnp.float32) * vs
    got = ops.paged_attention(q, kf, None, vf, None, table, lengths)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------- sharding spec checks

def test_tp_cache_specs_paged_pool():
    """Pool leaves shard heads on 'model' congruently (codes AND scales);
    the page axis stays whole; page_table/pos replicate."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed import sharding as shlib
    from repro.distributed.compat import abstract_mesh

    mesh = abstract_mesh((1, 2), ("data", "model"))
    L, n_pages, G, KV, hd = 2, 9, 8, 4, 16
    cache = {
        "k": jax.ShapeDtypeStruct((L, n_pages, G, KV, hd), jnp.int8),
        "k_scale": jax.ShapeDtypeStruct((L, n_pages, G, KV, 1),
                                        jnp.float32),
        "v": jax.ShapeDtypeStruct((L, n_pages, G, KV, hd), jnp.int8),
        "v_scale": jax.ShapeDtypeStruct((L, n_pages, G, KV, 1),
                                        jnp.float32),
        "page_table": jax.ShapeDtypeStruct((3, 3), jnp.int32),
        "pos": jax.ShapeDtypeStruct((3,), jnp.int32),
    }
    specs = shlib.tp_cache_specs(cache, mesh, axis="model")
    for key in ("k", "k_scale", "v", "v_scale"):
        assert specs[key] == P(None, None, None, "model", None), key
    assert specs["page_table"] == P(None, None)
    assert specs["pos"] == P(None)
    # MQA-ish: heads don't divide -> whole tree replicates (congruent)
    cache["k"] = jax.ShapeDtypeStruct((L, n_pages, G, 3, hd), jnp.int8)
    cache["k_scale"] = jax.ShapeDtypeStruct((L, n_pages, G, 3, 1),
                                            jnp.float32)
    specs = shlib.tp_cache_specs(cache, mesh, axis="model")
    assert specs["k"] == P(None, None, None, None, None)
    assert specs["k_scale"] == P(None, None, None, None, None)
