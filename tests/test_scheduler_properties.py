"""Property tests for the unified token-budget scheduler
(``repro.launch.scheduler.TokenBudgetScheduler``), driven two ways:

1. **Pure-host simulation** — the scheduler is plain python over the page
   allocator, so its plan/observe loop runs without any model: the test
   plays executor, feeding each logit consumer the token a
   position-faithful stub rule predicts. Invariants under random request
   lengths / budgets / slot counts / eos:

   - every step's packed token count <= ``max_batch_tokens`` (and the
     packed arrays really hold that many rows)
   - FIFO admission order is the submission order
   - no slot is both prefilling and decoding in one step
   - every admitted request retires exactly once, with exactly the
     trajectory the per-request simulation predicts (scheduler
     independence: packing must not leak between requests)
   - prefill chunks are contiguous, in-order, and cover each prompt
     exactly once; drained pools return every page

2. **Engine integration** — a ragged-contract stub model through
   ``ServeEngine(schedule="unified")``, asserting the engine reproduces
   the legacy (prefill-on-admit) engine's output exactly.

Runs via tests/_hypothesis_shim: property cases when hypothesis is
installed, the seeded deterministic ports always."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.launch.engine import ServeEngine
from repro.launch.paged import PagePool, SlotPageTables
from repro.launch.scheduler import Request, TokenBudgetScheduler

_V = 64          # stub vocab


def _next_token(tok, pos):
    """Pure next-token rule: mixes token and absolute position so any
    packing bug (wrong offset, leaked row, stale page) changes output."""
    return (tok * 7 + pos * 13 + 1) % _V


def _simulate(prompt, max_new, eos_id):
    """The per-request ground truth the scheduler loop must reproduce."""
    toks = list(prompt)
    tok, pos = int(prompt[-1]), len(prompt) - 1
    for _ in range(max_new):
        tok = _next_token(tok, pos)
        toks.append(tok)
        pos += 1
        if tok == eos_id:
            break
    return toks


def _make_sched(n_slots, max_batch_tokens, max_len, page_size=4,
                prefill_chunk=0, eos_id=None, **kw):
    kv_len = -(-max_len // page_size) * page_size
    n_ptab = kv_len // page_size
    pool = PagePool(1 + n_slots * n_ptab, page_size)
    tables = SlotPageTables(pool, n_slots, n_ptab)
    return TokenBudgetScheduler(n_slots, max_batch_tokens, pool=pool,
                                tables=tables, prefill_chunk=prefill_chunk,
                                eos_id=eos_id, **kw)


def _drive(lengths, budgets, n_slots, max_batch_tokens, eos_id=None,
           prefill_chunk=0):
    """Run the scheduler's plan/observe loop with a python executor;
    returns (scheduler, per-rid token lists, step records)."""
    rng = np.random.default_rng(hash((tuple(lengths), n_slots)) % 2**32)
    reqs = [Request(rid, rng.integers(0, _V, p).astype(np.int32), g)
            for rid, (p, g) in enumerate(zip(lengths, budgets))]
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    sched = _make_sched(n_slots, max_batch_tokens, max_len,
                        prefill_chunk=prefill_chunk, eos_id=eos_id)
    for r in reqs:
        sched.queue.append(r)
    done, steps = {}, []
    slot_rid = {}                       # current occupant per slot
    chunks = {r.rid: [] for r in reqs}  # rid -> [(offset, q_len)]
    guard = 0
    while not sched.idle:
        guard += 1
        assert guard < 10_000, "scheduler failed to drain"
        plan = sched.plan(guard)
        for rid, slot in plan.admitted:
            slot_rid[slot] = rid
        for slot, off, n, _toks in plan.prefill:
            chunks[slot_rid[slot]].append((off, n))
        # ---- invariants checked per plan
        assert plan.n_tokens <= max_batch_tokens
        dec_slots = {s for s, _, _ in plan.decode}
        pre_slots = [s for s, _, _, _ in plan.prefill]
        assert not dec_slots & set(pre_slots), (
            "slot both prefilling and decoding in one step")
        assert len(pre_slots) == len(set(pre_slots)), (
            "slot prefills twice in one step")
        packed = sched.pack(plan)
        assert packed["tokens"].shape == (max_batch_tokens, 1)
        assert packed["n_logits"] == len(plan.logit_consumers) <= n_slots
        # executor stand-in: each logit row's argmax from the stub rule
        toks = []
        for (kind, slot), row in zip(plan.logit_consumers,
                                     packed["logit_rows"]):
            fed = int(packed["tokens"][row, 0])
            pos = int(packed["pos"][row])
            toks.append(_next_token(fed, pos))
        steps.append((plan.n_tokens, sorted(dec_slots), pre_slots,
                      [rid for rid, _ in plan.admitted]))
        for seq in sched.observe(plan, np.asarray(toks), now=0.0):
            assert seq.req.rid not in done, "retired twice"
            done[seq.req.rid] = (list(seq.req.prompt) + seq.generated,
                                 seq.slot)
    return sched, reqs, done, steps, chunks


def _check_invariants(lengths, budgets, n_slots, max_batch_tokens,
                      eos_id=None, prefill_chunk=0):
    sched, reqs, done, steps, chunks = _drive(lengths, budgets, n_slots,
                                              max_batch_tokens, eos_id,
                                              prefill_chunk)
    # exactly-once retirement, FIFO admission order == submission order
    admitted = [rid for *_, rids in steps for rid in rids]
    assert admitted == [r.rid for r in reqs]
    assert sorted(done) == sorted(r.rid for r in reqs)
    # drained: all slots free, every page returned, reservations dropped
    assert sorted(sched.free) == list(range(n_slots))
    assert sched.pool.in_use == 0
    assert sched.tables.reserved_unallocated == 0
    # scheduler independence: trajectories match the per-request sim
    for r in reqs:
        want = _simulate(r.prompt, r.max_new_tokens, eos_id)
        got = done[r.rid][0]
        assert got == want, (r.rid, got, want)
    # the packed-token invariant held on every step (belt & braces: the
    # scheduler's own log agrees with what the driver saw)
    assert [t for t, *_ in sched.plan_log] == [t for t, *_ in steps]
    assert max(t for t, *_ in steps) <= max_batch_tokens
    # prefill chunks are contiguous, in order, and cover each prompt
    # exactly once (the chunked-admission state machine never re-reads
    # or skips prompt tokens)
    for r in reqs:
        offs = chunks[r.rid]
        assert offs[0][0] == 0
        assert sum(n for _, n in offs) == len(r.prompt)
        nxt = 0
        for off, n in offs:
            assert off == nxt and n >= 1
            if prefill_chunk:
                assert n <= prefill_chunk
            nxt = off + n


# --------------------------------------------------------------- property

@settings(max_examples=20, deadline=None)
@given(
    lens_budgets=st.lists(
        st.tuples(st.integers(1, 20), st.integers(1, 6)),
        min_size=1, max_size=12),
    n_slots=st.integers(1, 4),
    budget_extra=st.integers(0, 12),
    eos_id=st.integers(-1, _V - 1),
    prefill_chunk=st.integers(0, 5),
)
def test_property_scheduler_invariants(lens_budgets, n_slots, budget_extra,
                                       eos_id, prefill_chunk):
    lengths = [p for p, _ in lens_budgets]
    budgets = [g for _, g in lens_budgets]
    _check_invariants(lengths, budgets, n_slots, n_slots + budget_extra,
                      eos_id if eos_id >= 0 else None, prefill_chunk)


# ---------------------------------------------- deterministic seeded ports

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5])
@pytest.mark.parametrize("n_slots", [1, 3])
def test_scheduler_invariants_ports(seed, n_slots):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 12))
    lengths = rng.integers(1, 21, n).tolist()
    budgets = rng.integers(1, 7, n).tolist()
    budget = n_slots + int(rng.integers(0, 13))
    eos_id = int(rng.integers(0, _V)) if seed % 2 else None
    chunk = int(rng.integers(0, 6)) if seed % 3 else 0
    _check_invariants(lengths, budgets, n_slots, budget, eos_id, chunk)


def test_tight_budget_still_makes_progress():
    """budget == n_slots: decode saturates the budget whenever all slots
    run, yet prefill always gets through eventually (a prefilling slot
    never decodes, freeing at least one token of headroom)."""
    _check_invariants([12, 12, 12, 12], [5, 5, 5, 5], n_slots=2,
                      max_batch_tokens=2)


def test_undersized_pool_head_of_line_waits_fifo():
    """A pool too small for concurrent admissions must queue the head
    (never skip to a smaller younger request) and still drain with every
    invariant intact."""
    rng = np.random.default_rng(11)
    reqs = [Request(rid, rng.integers(0, _V, p).astype(np.int32), g)
            for rid, (p, g) in enumerate([(8, 4), (8, 4), (2, 2), (8, 4)])]
    page_size = 4
    # 3 allocatable pages: exactly one (8+4)-token request fits at a time
    pool = PagePool(1 + 3, page_size)
    tables = SlotPageTables(pool, n_slots=3, n_ptab=3)
    sched = TokenBudgetScheduler(3, 16, pool=pool, tables=tables)
    for r in reqs:
        sched.queue.append(r)
    admitted, done = [], {}
    for step in range(200):
        if sched.idle:
            break
        plan = sched.plan(step)
        admitted += [rid for rid, _ in plan.admitted]
        packed = sched.pack(plan)
        toks = [_next_token(int(packed["tokens"][row, 0]),
                            int(packed["pos"][row]))
                for row in packed["logit_rows"][:packed["n_logits"]]]
        for seq in sched.observe(plan, np.asarray(toks), now=0.0):
            done[seq.req.rid] = True
    assert sched.idle
    assert admitted == [0, 1, 2, 3], "FIFO broken by head-of-line wait"
    assert sorted(done) == [0, 1, 2, 3]
    assert pool.in_use == 0
    # concurrency really was capped: at most one 12-token resident
    assert pool.peak_in_use <= 3


def test_long_prompt_interleaves_with_decode():
    """A 17-token prompt under budget 5 must take multiple steps while
    the short request decodes alongside — the head-of-line decoupling
    the unified schedule exists for."""
    sched, reqs, done, steps, chunks = _drive([3, 17], [4, 2], n_slots=2,
                                              max_batch_tokens=5)
    assert len(chunks[1]) >= 4          # 17 tokens through <=5/step
    mixed = [s for s in steps if s[1] and s[2]]   # decode AND prefill
    assert mixed, "expected steps mixing decode tokens and prefill chunks"


# ------------------------------------------------- engine integration stub

class _RaggedStubModel:
    """Dense-family stand-in honoring BOTH engine contracts: the legacy
    prefill/decode pair and the unified ragged step (logits at packed
    ``logit_rows``, next token a pure function of the fed token and its
    position). Carries a paged-cache shape so the unified engine's pool
    bookkeeping runs for real."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init_cache(self, batch, max_len):
        return {"k": jnp.zeros((1, batch, max_len, 1, 1), jnp.float32),
                "v": jnp.zeros((1, batch, max_len, 1, 1), jnp.float32),
                "pos": jnp.int32(0)}

    def init_paged_cache(self, n_pages, page_size):
        return {"k": jnp.zeros((1, n_pages, page_size, 1, 1), jnp.float32),
                "v": jnp.zeros((1, n_pages, page_size, 1, 1), jnp.float32)}

    def prefill(self, params, tokens, cache, logits_at=None):
        if logits_at is None:
            logits_at = jnp.int32(tokens.shape[1] - 1)
        import jax
        tok = jax.lax.dynamic_slice_in_dim(tokens, logits_at, 1, axis=1)
        pos = cache["pos"] + logits_at
        nxt = (tok[:, 0] * 7 + pos * 13 + 1) % _V
        import jax.nn
        logits = jax.nn.one_hot(nxt, _V)[:, None, :]
        return logits, dict(cache, pos=pos + 1)

    def decode(self, params, token, cache):
        import jax.nn
        nxt = (token[:, 0] * 7 + cache["pos"] * 13 + 1) % _V
        return (jax.nn.one_hot(nxt, _V)[:, None, :],
                dict(cache, pos=cache["pos"] + 1))

    def ragged_step(self, params, tokens, cache, logit_rows, greedy=False,
                    **kw):
        import jax.nn
        fed = jnp.take(tokens[:, 0], logit_rows)
        pos = jnp.take(cache["pos"], logit_rows)
        nxt = (fed * 7 + pos * 13 + 1) % _V
        if greedy:      # device-resident sampling (models.dense contract)
            return nxt.astype(jnp.int32), dict(cache)
        return (jax.nn.one_hot(nxt, _V)[:, None, :],
                dict(cache))


_STUB = None


def _stub():
    global _STUB
    if _STUB is None:
        from repro.configs import get_config
        _STUB = _RaggedStubModel(get_config("catlm_60m").smoke())
    return _STUB


@pytest.mark.parametrize("budget,chunk", [(3, 0), (8, 0), (5, 4)])
def test_unified_engine_matches_legacy_on_stub(budget, chunk):
    rng = np.random.default_rng(7)
    reqs = [{"rid": i, "tokens": rng.integers(0, _V, p).astype(np.int32),
             "max_new_tokens": g}
            for i, (p, g) in enumerate([(5, 3), (11, 2), (1, 4), (8, 1),
                                        (13, 5)])]
    legacy = ServeEngine(_stub(), {}, n_slots=3, max_len=24)
    lres = legacy.run(reqs)
    uni = ServeEngine(_stub(), {}, n_slots=3, max_len=24,
                      schedule="unified", max_batch_tokens=budget,
                      prefill_chunk=chunk, page_size=4)
    ures = uni.run(reqs)
    for r in reqs:
        assert (lres[r["rid"]].tokens == ures[r["rid"]].tokens).all(), \
            r["rid"]
    # engine-level mirrors of the scheduler invariants
    assert max(t for t, *_ in uni.sched.plan_log) <= budget
    admits = [e[1] for e in uni.events if e[0] == "admit"]
    assert admits == [r["rid"] for r in reqs]
    retires = sorted(e[1] for e in uni.events if e[0] == "retire")
    assert retires == sorted(r["rid"] for r in reqs)
    assert uni.idle and uni.pool.in_use == 0


# --------------------------------------------- hot-loop regression tests

@given(want=st.integers(1, 64), budget=st.integers(1, 64),
       chunk=st.integers(0, 16))
@settings(max_examples=200, deadline=None)
def test_property_chunk_never_zero(want, budget, chunk):
    """Budget-remainder audit: for every (want >= 1, budget >= 1) a
    caller can reach ``_chunk`` with, the sliced chunk is >= 1 — a slot
    can never stall a cycle on a 0-token chunk while budget remains."""
    sched = _make_sched(2, max(budget, 2), 32, prefill_chunk=chunk)
    n = sched._chunk(want, budget)
    assert 1 <= n <= min(want, budget)
    if chunk:
        assert n <= chunk


def test_plan_log_is_a_capped_ring():
    """The per-step plan log must not grow without bound on a long-lived
    engine; the running counters keep reporting over evicted steps."""
    sched = _make_sched(2, 6, 64, plan_log_cap=8)
    assert sched.plan_log.maxlen == 8
    rng = np.random.default_rng(3)
    for rid in range(12):
        sched.queue.append(Request(
            rid, rng.integers(0, _V, 3).astype(np.int32), 2))
    guard = 0
    while not sched.idle:
        guard += 1
        assert guard < 1000
        plan = sched.plan(guard)
        sched.pack(plan)
        toks = np.asarray([1] * len(plan.logit_consumers))
        for seq in sched.observe(plan, toks, now=0.0):
            pass
    assert len(sched.plan_log) <= 8
    assert sched.n_plans == guard > 8          # counted past the cap
    assert 0 < sched.packed_tokens_max <= 6    # tracked outside the ring


def test_pack_reuses_descriptor_buffers():
    """pack() reuses a fixed ring of host descriptor buffers across
    steps (no per-step allocation in the hot loop). The ring is 2 deep —
    the pipelined loop may still hold step N's descriptors (aliased by a
    possibly-unmaterialized ``jnp.asarray``) while step N+1 packs — so
    consecutive packs alternate buffer sets and packs two steps apart
    return the SAME objects, refilled correctly (packing the same plan
    repeatedly gives equal contents)."""
    sched = _make_sched(2, 6, 32)
    rng = np.random.default_rng(5)
    for rid in range(2):
        sched.queue.append(Request(
            rid, rng.integers(0, _V, 4).astype(np.int32), 2))
    plan = sched.plan(0)
    packs = [sched.pack(plan) for _ in range(4)]
    snap = {k: np.array(v, copy=True) for k, v in packs[0].items()
            if isinstance(v, np.ndarray)}

    def _same_buf(a, b):
        return (b.base is not None or b is a or
                b.__array_interface__["data"] ==
                a.__array_interface__["data"])

    for step in (2, 3):         # ring period 2: step k aliases step k-2
        for k, v in packs[step].items():
            if isinstance(v, np.ndarray):
                assert _same_buf(packs[step - 2][k], v), (step, k)
    for k, v in packs[1].items():   # adjacent steps must NOT alias —
        if isinstance(v, np.ndarray) and v.size:    # that is the ring's
            assert (v.__array_interface__["data"][0]  # reason to exist
                    != packs[0][k].__array_interface__["data"][0]), k
    for p in packs:
        for k, v in p.items():
            if isinstance(v, np.ndarray):
                np.testing.assert_array_equal(v, snap[k])


# ------------------------------------------- adaptive speculative depth

def _make_spec_sched(n_slots, max_batch_tokens, max_len, spec_k,
                     adaptive=False, page_size=4):
    kv_len = -(-(max_len + spec_k + 1) // page_size) * page_size
    n_ptab = kv_len // page_size
    pool = PagePool(1 + n_slots * n_ptab, page_size)
    tables = SlotPageTables(pool, n_slots, n_ptab)
    dpool = PagePool(1 + n_slots * n_ptab, page_size)
    dtables = SlotPageTables(dpool, n_slots, n_ptab)
    return TokenBudgetScheduler(n_slots, max_batch_tokens, pool=pool,
                                tables=tables, spec_k=spec_k,
                                draft_tables=dtables,
                                adaptive_spec=adaptive)


def _drive_spec(lengths, budgets, n_slots, max_batch_tokens, spec_k,
                adaptive, accept_p, seed=0):
    """Spec-mode plan/observe loop with a python draft+target executor.
    Drafts are the stub rule's correct continuation with probability
    ``accept_p`` per position (chain-fed: a wrong draft derails the
    rest, like a real draft model). Returns (sched, reqs, done tokens,
    every per-slot k' the planner chose)."""
    rng = np.random.default_rng(seed)
    reqs = [Request(rid, rng.integers(0, _V, p).astype(np.int32), g)
            for rid, (p, g) in enumerate(zip(lengths, budgets))]
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    sched = _make_spec_sched(n_slots, max_batch_tokens, max_len, spec_k,
                             adaptive=adaptive)
    page_size = sched.tables.pool.page_size
    for r in reqs:
        sched.queue.append(r)
    done, k_seen, guard = {}, [], 0
    while not sched.idle:
        guard += 1
        assert guard < 10_000, "spec scheduler failed to drain"
        plan = sched.plan(guard)
        for slot, tok, p in plan.spec:
            kx = plan.spec_k_of[slot]
            k_seen.append(kx)
            # ---- the budget/reservation math k' must never exceed
            assert 1 <= kx <= spec_k
            assert plan.spec_rows(slot) == kx + 1 <= spec_k + 1
            # target pages cover the last verify position p+k', the
            # draft pool the full worst case p+spec_k (the draft scan
            # always runs spec_k steps regardless of k')
            assert sched.tables.table[slot, (p + kx) // page_size] != 0
            assert sched.draft_tables.table[
                slot, (p + spec_k) // page_size] != 0
            fed, drafts = tok, []
            for j in range(spec_k):
                c = _next_token(fed, p + j)
                d = c if rng.random() < accept_p else (c + 1) % _V
                drafts.append(d)
                fed = d
            plan.spec_drafts[slot] = np.asarray(drafts, np.int32)
        assert plan.n_tokens <= max_batch_tokens
        packed = sched.pack(plan)
        toks = [_next_token(int(packed["tokens"][row, 0]),
                            int(packed["pos"][row]))
                for row in packed["logit_rows"][:packed["n_logits"]]]
        for seq in sched.observe(plan, np.asarray(toks), now=0.0):
            done[seq.req.rid] = list(seq.req.prompt) + seq.generated
    assert sched.pool.in_use == 0
    assert sched.draft_tables.pool.in_use == 0
    return sched, reqs, done, k_seen


@settings(max_examples=15, deadline=None)
@given(
    lens_budgets=st.lists(
        st.tuples(st.integers(1, 12), st.integers(1, 8)),
        min_size=1, max_size=6),
    n_slots=st.integers(1, 3),
    spec_k=st.integers(1, 4),
    adaptive=st.booleans(),
    accept_pct=st.integers(0, 100),
)
def test_property_adaptive_spec_invariants(lens_budgets, n_slots, spec_k,
                                           adaptive, accept_pct):
    """Adaptive draft depth never breaks the budget/reservation math
    (asserted inside the drive) and never changes the output: every
    appended token is still a target argmax, so trajectories match the
    per-request simulation at ANY acceptance rate and either mode."""
    lengths = [p for p, _ in lens_budgets]
    budgets = [g for _, g in lens_budgets]
    budget = n_slots * (spec_k + 1) + 2
    _, reqs, done, k_seen = _drive_spec(lengths, budgets, n_slots, budget,
                                        spec_k, adaptive,
                                        accept_pct / 100.0)
    for r in reqs:
        want = _simulate(r.prompt, r.max_new_tokens, None)
        assert done[r.rid] == want, (r.rid, done[r.rid], want)
    if not adaptive:
        assert all(k == spec_k for k in k_seen)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("adaptive", [False, True])
def test_adaptive_spec_invariants_ports(seed, adaptive):
    """Deterministic port of the property (runs without hypothesis)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 7))
    lengths = rng.integers(1, 13, n).tolist()
    budgets = rng.integers(1, 9, n).tolist()
    n_slots = int(rng.integers(1, 4))
    spec_k = int(rng.integers(1, 5))
    accept_p = float(rng.random())
    budget = n_slots * (spec_k + 1) + 2
    _, reqs, done, k_seen = _drive_spec(lengths, budgets, n_slots, budget,
                                        spec_k, adaptive, accept_p,
                                        seed=seed)
    for r in reqs:
        want = _simulate(r.prompt, r.max_new_tokens, None)
        assert done[r.rid] == want, (r.rid, done[r.rid], want)
    if not adaptive:
        assert all(k == spec_k for k in k_seen)


def test_adaptive_spec_depth_tracks_acceptance():
    """Direction check: all-rejected drafts drive a slot's k' down to 1
    after its first cycle; all-accepted drafts keep k' at the cap. A
    fresh occupant of a reused slot starts back at the cap (the EMA is
    cleared on retire — no inherited pessimism)."""
    spec_k = 4
    _, _, _, k_low = _drive_spec([4, 4, 4], [8, 8, 8], 1, 2 * (spec_k + 1),
                                 spec_k, True, accept_p=0.0)
    # slot reuse: each request's FIRST cycle is optimistic (k' = cap),
    # every later cycle has EMA 0 -> k' = 1
    assert k_low.count(spec_k) == 3 and set(k_low) == {1, spec_k}
    _, _, _, k_high = _drive_spec([4, 4], [8, 8], 2, 2 * (spec_k + 1),
                                  spec_k, True, accept_p=1.0)
    assert all(k == spec_k for k in k_high)


def test_scheduler_reset_reuses_engine():
    """reset() returns a drained scheduler to its initial state: a second
    identical workload must produce identical plans and tokens."""
    def drain(sched, reqs):
        for r in reqs:
            sched.queue.append(r)
        toks_out, guard = {}, 0
        while not sched.idle:
            guard += 1
            assert guard < 1000
            plan = sched.plan(guard)
            packed = sched.pack(plan)
            toks = [_next_token(int(packed["tokens"][row, 0]),
                                int(packed["pos"][row]))
                    for _, row in zip(plan.logit_consumers,
                                      packed["logit_rows"])]
            for seq in sched.observe(plan, np.asarray(toks), now=0.0):
                toks_out[seq.req.rid] = list(seq.generated)
        return toks_out

    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, _V, p).astype(np.int32) for p in (5, 3, 9)]
    sched = _make_sched(2, 5, 32)
    first = drain(sched, [Request(i, p, 3)
                          for i, p in enumerate(prompts)])
    sched.reset()
    assert sched.pool.in_use == 0 and not sched.plan_log
    second = drain(sched, [Request(i, p, 3)
                           for i, p in enumerate(prompts)])
    assert first == second
