"""Per-kernel validation: sweep shapes/dtypes, assert_allclose vs the
ref.py pure-jnp oracle (interpret=True executes kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hadamard import hadamard_factors
from repro.kernels import ops, ref
from repro.kernels.block_matmul import block_diag_matmul
from repro.kernels.dynamic_quant import dynamic_quant
from repro.kernels.hadamard import hadamard_transform
from repro.kernels.quant_matmul import quant_matmul


def _rng(seed=0):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------- hadamard

@pytest.mark.parametrize("d", [256, 1024, 96, 2304])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("tokens", [1, 17, 256])
def test_hadamard_kernel_matches_ref(d, dtype, tokens):
    ha, hb = hadamard_factors(d)
    ha = jnp.asarray(ha, jnp.float32)
    hb = jnp.asarray(hb, jnp.float32)
    x = jnp.asarray(_rng(d + tokens).standard_normal((tokens, d)), dtype)
    sign = jnp.asarray(_rng(1).choice([-1.0, 1.0], d), jnp.float32)
    got = hadamard_transform(x, ha, hb, sign, block_tokens=64, interpret=True)
    want = ref.hadamard_transform(x, ha, hb, sign)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol * 10)


def test_hadamard_kernel_orthonormal_roundtrip():
    d = 512
    ha, hb = map(lambda h: jnp.asarray(h, jnp.float32), hadamard_factors(d))
    x = jnp.asarray(_rng(3).standard_normal((8, d)), jnp.float32)
    y = hadamard_transform(x, ha, hb, interpret=True)
    # H orthonormal: ||y|| == ||x|| and H(Hx) with Hᵀ=H for symmetric factors
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-5)


# ------------------------------------------------------------ dynamic quant

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("symmetric", [True, False])
@pytest.mark.parametrize("shape", [(5, 64), (128, 384), (2, 3, 96)])
def test_dynamic_quant_matches_ref(bits, symmetric, shape):
    x = jnp.asarray(_rng(bits + shape[0]).standard_normal(shape) * 3, jnp.float32)
    q, s, z = dynamic_quant(x, bits=bits, symmetric=symmetric,
                            block_tokens=32, interpret=True)
    qr, sr, zr = ref.dynamic_quant(x, bits=bits, symmetric=symmetric)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(qr))
    np.testing.assert_allclose(np.asarray(s), np.asarray(sr), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z), np.asarray(zr), rtol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_dynamic_quant_reconstruction_error(bits):
    x = jnp.asarray(_rng(9).standard_normal((64, 128)), jnp.float32)
    q, s, z = dynamic_quant(x, bits=bits, interpret=True)
    recon = (q.astype(jnp.float32) - z) * s
    step = np.asarray(s)
    assert float(jnp.max(jnp.abs(recon - x))) <= step.max() * 1.01


# -------------------------------------------------------------- quant matmul

@pytest.mark.parametrize("mnk", [(8, 16, 32), (100, 96, 64), (256, 384, 512),
                                 (33, 65, 129)])
def test_quant_matmul_matches_ref(mnk):
    m, n, k = mnk
    r = _rng(m * n)
    qx = jnp.asarray(r.integers(-8, 8, (m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
    sx = jnp.asarray(r.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    zpx = jnp.asarray(r.integers(-8, 8, (m, 1)), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    got = quant_matmul(qx, sx, zpx, qw, sw, block_m=32, block_n=32,
                       block_k=32, interpret=True)
    want = ref.quant_matmul(qx, sx, zpx, qw, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quant_matmul_equals_dequant_matmul():
    """int math identity: kernel == dequantize-then-fp-matmul."""
    r = _rng(5)
    m, k, n = 24, 48, 36
    qx = jnp.asarray(r.integers(-8, 8, (m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
    sx = jnp.asarray(r.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    zpx = jnp.asarray(r.integers(-8, 8, (m, 1)), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    x_fp = (qx.astype(jnp.float32) - zpx) * sx
    w_fp = qw.astype(jnp.float32) * sw
    want = x_fp @ w_fp
    got = quant_matmul(qx, sx, zpx, qw, sw, block_m=8, block_n=16, block_k=16,
                       interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


# --------------------------------------------------------- block-diag matmul

@pytest.mark.parametrize("nk", [(4, 32), (8, 128), (3, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_diag_matmul_matches_ref(nk, dtype):
    n, k = nk
    r = _rng(n * k)
    x = jnp.asarray(r.standard_normal((37, n * k)), dtype)
    blocks = jnp.asarray(r.standard_normal((n, k, k)) / np.sqrt(k), jnp.float32)
    got = block_diag_matmul(x, blocks, block_tokens=16, interpret=True)
    want = ref.block_diag_matmul(x, blocks)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


def test_block_diag_matches_dense_blockdiag():
    import jax.scipy.linalg as jsl
    r = _rng(11)
    n, k = 4, 16
    x = jnp.asarray(r.standard_normal((9, n * k)), jnp.float32)
    blocks = jnp.asarray(r.standard_normal((n, k, k)), jnp.float32)
    dense = jsl.block_diag(*[blocks[i] for i in range(n)])
    want = x @ dense.T
    got = block_diag_matmul(x, blocks, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-4)


# ----------------------------------------------------- fused serving path --

def test_cat_transform_matmul_end_to_end():
    """Kernel composition == oracle composition (the paper's serving layer)."""
    r = _rng(21)
    d, d_out, toks, k = 256, 192, 50, 64
    n = d // k
    ha, hb = map(lambda h: jnp.asarray(h, jnp.float32), hadamard_factors(d))
    sign = jnp.asarray(r.choice([-1.0, 1.0], d), jnp.float32)
    x = jnp.asarray(r.standard_normal((toks, d)), jnp.float32)
    blocks = jnp.asarray(r.standard_normal((n, k, k)) / np.sqrt(k), jnp.float32)
    qw = jnp.asarray(r.integers(-8, 8, (d, d_out)), jnp.int8)
    sw = jnp.asarray(r.uniform(0.01, 0.05, (1, d_out)), jnp.float32)

    got = ops.cat_transform_matmul(x, blocks, ha, hb, sign, qw, sw,
                                   act_bits=4, interpret=True)

    xt = ref.block_diag_matmul(x, blocks)
    xt = ref.hadamard_transform(xt, ha, hb, sign)
    qx, sx, zx = ref.dynamic_quant(xt, bits=4, symmetric=False)
    want = ref.quant_matmul(qx, sx, zx, qw, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
