"""Ragged (mixed q_len) paged-attention kernel vs its jnp oracle.

``kernels.paged_attention.paged_attention_ragged`` generalizes the
q_len=1 decode kernel to per-sequence query *blocks* with a
per-(query, kv) causal mask — the attention shape of a unified
token-budget step. Pins, at rtol 1e-5 against ``kernels.ref``:

- mixed q_len batches (decode singletons next to multi-token chunks)
- ragged last pages (lengths not multiples of page_size)
- padded query rows (qpos = -1) never contaminating real rows
- exact masking: poisoning rows beyond each sequence's causal horizon
  with huge codes cannot move the output
- the q_len=1 degenerate case equals the decode kernel bitwise-ish
  (same math, same rtol band vs the oracle)
- ops dispatch: fp pools (no scales) route to the jnp fallback
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_attention import (paged_attention_decode,
                                           paged_attention_ragged,
                                           paged_attention_ragged_fallback)

RTOL = 1e-5


def _pool(key, n_pages, page_size, kvh, hd):
    kk, ks, kv, kvs = jax.random.split(key, 4)
    k_pages = jax.random.randint(kk, (n_pages, page_size, kvh, hd),
                                 -127, 128, jnp.int8)
    v_pages = jax.random.randint(kv, (n_pages, page_size, kvh, hd),
                                 -127, 128, jnp.int8)
    k_scale = jax.random.uniform(ks, (n_pages, page_size, kvh, 1),
                                 jnp.float32, 0.01, 0.1)
    v_scale = jax.random.uniform(kvs, (n_pages, page_size, kvh, 1),
                                 jnp.float32, 0.01, 0.1)
    return k_pages, k_scale, v_pages, v_scale


def _case(seed, b, nq, kvh, g, hd, page_size, n_ptab, q_lens, lengths):
    """Build a ragged batch: row i holds q_lens[i] real query rows ending
    at position lengths[i]-1, with distinct pages per row."""
    key = jax.random.PRNGKey(seed)
    n_pages = 1 + b * n_ptab
    kq, kp = jax.random.split(key)
    pools = _pool(kp, n_pages, page_size, kvh, hd)
    q = jax.random.normal(kq, (b, nq, kvh, g, hd), jnp.float32)
    table = np.zeros((b, n_ptab), np.int32)
    nxt = 1
    for i in range(b):
        used = -(-int(lengths[i]) // page_size)
        for j in range(used):
            table[i, j] = nxt
            nxt += 1
    qpos = np.full((b, nq), -1, np.int32)
    for i, (ql, ln) in enumerate(zip(q_lens, lengths)):
        qpos[i, :ql] = ln - ql + np.arange(ql)
    return (q, *pools, jnp.asarray(table),
            jnp.asarray(np.asarray(lengths, np.int32)),
            jnp.asarray(qpos))


@pytest.mark.parametrize("seed", [0, 1])
@pytest.mark.parametrize("q_lens,lengths,page_size,n_ptab", [
    # mixed: a decode singleton, a mid-size chunk, a full-block chunk
    ((1, 3, 6), (9, 7, 6), 4, 3),
    # ragged last pages: lengths far from page multiples
    ((2, 5, 1), (11, 5, 13), 4, 4),
    # single page, GQA-free edge
    ((1, 2, 2), (1, 2, 8), 8, 1),
], ids=["mixed", "ragged-pages", "one-page"])
def test_ragged_kernel_matches_oracle(seed, q_lens, lengths, page_size,
                                      n_ptab):
    args = _case(seed, len(q_lens), max(q_lens), kvh=2, g=2, hd=8,
                 page_size=page_size, n_ptab=n_ptab, q_lens=q_lens,
                 lengths=lengths)
    got = paged_attention_ragged(*args, interpret=True)
    want = ref.paged_attention_ragged(*args)
    qpos = np.asarray(args[-1])
    valid = qpos >= 0
    np.testing.assert_allclose(np.asarray(got)[valid],
                               np.asarray(want)[valid],
                               rtol=RTOL, atol=1e-5)


def test_padded_query_rows_do_not_contaminate():
    """Adding padded (qpos=-1) rows must not change the real rows."""
    q_lens, lengths = (2, 1), (6, 3)
    a_small = _case(3, 2, 2, 2, 2, 8, 4, 2, q_lens, lengths)
    out_small = paged_attention_ragged(*a_small, interpret=True)
    # same case embedded in a wider query block
    q, kp, ks, vp, vs, table, ln, qpos = a_small
    pad = 3
    q_wide = jnp.concatenate(
        [q, jax.random.normal(jax.random.PRNGKey(9), (2, pad, 2, 2, 8))],
        axis=1)
    qpos_wide = jnp.concatenate(
        [qpos, jnp.full((2, pad), -1, jnp.int32)], axis=1)
    out_wide = paged_attention_ragged(q_wide, kp, ks, vp, vs, table, ln,
                                      qpos_wide, interpret=True)
    valid = np.asarray(qpos) >= 0
    np.testing.assert_array_equal(np.asarray(out_small)[valid],
                                  np.asarray(out_wide)[:, :2][valid])


def test_causal_horizon_masking_is_exact():
    """Poisoning every kv row past each query's causal horizon (same-
    chunk future tokens, ragged page tails, the null page) with extreme
    codes/scales cannot move the output — masked rows get exactly zero
    weight."""
    q_lens, lengths = (3, 1), (5, 9)
    args = _case(5, 2, 3, 2, 2, 8, 4, 3, q_lens, lengths)
    q, kp, ks, vp, vs, table, ln, qpos = args
    out = paged_attention_ragged(*args, interpret=True)
    # poison: every (page, row) whose logical position exceeds the MAX
    # qpos of its sequence, plus the whole null page
    kp2, vp2 = np.asarray(kp).copy(), np.asarray(vp).copy()
    ks2, vs2 = np.asarray(ks).copy(), np.asarray(vs).copy()
    page_size = kp2.shape[1]
    tbl = np.asarray(table)
    for i in range(2):
        horizon = int(np.max(np.asarray(qpos)[i]))
        for j in range(tbl.shape[1]):
            page = tbl[i, j]
            for r in range(page_size):
                if page == 0 or j * page_size + r > horizon:
                    if page:
                        kp2[page, r] = 127
                        vp2[page, r] = -127
                        ks2[page, r] = 1e8
                        vs2[page, r] = 1e8
    kp2[0], vp2[0], ks2[0], vs2[0] = 127, -127, 1e8, 1e8
    out2 = paged_attention_ragged(q, jnp.asarray(kp2), jnp.asarray(ks2),
                                  jnp.asarray(vp2), jnp.asarray(vs2),
                                  table, ln, qpos, interpret=True)
    valid = np.asarray(qpos) >= 0
    np.testing.assert_array_equal(np.asarray(out)[valid],
                                  np.asarray(out2)[valid])


def test_qlen1_reduces_to_decode_kernel():
    """A batch of q_len=1 rows with qpos = lengths-1 is exactly the
    decode kernel's contract; both must sit in the same rtol band vs
    the shared oracle semantics."""
    b, kvh, g, hd, page_size, n_ptab = 3, 2, 2, 8, 4, 3
    args = _case(7, b, 1, kvh, g, hd, page_size, n_ptab,
                 q_lens=(1, 1, 1), lengths=(5, 12, 1))
    q, kp, ks, vp, vs, table, ln, qpos = args
    ragged = paged_attention_ragged(*args, interpret=True)
    decode = paged_attention_decode(q[:, 0], kp, ks, vp, vs, table, ln,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(ragged[:, 0]),
                               np.asarray(decode), rtol=RTOL, atol=1e-6)


def test_ops_dispatch_fp_pool_falls_back():
    """fp pools (scales None) must route to the jnp fallback and agree
    with a quantized pool dequantized up front."""
    args = _case(11, 2, 3, 2, 2, 8, 4, 2, (3, 2), (7, 4))
    q, kp, ks, vp, vs, table, ln, qpos = args
    k_fp = kp.astype(jnp.float32) * ks
    v_fp = vp.astype(jnp.float32) * vs
    via_ops = ops.ragged_paged_attention(q, k_fp, None, v_fp, None, table,
                                         ln, qpos)
    direct = paged_attention_ragged_fallback(q, k_fp, None, v_fp, None,
                                             table, ln, qpos)
    np.testing.assert_array_equal(np.asarray(via_ops), np.asarray(direct))
    quant = ops.ragged_paged_attention(q, kp, ks, vp, vs, table, ln, qpos)
    valid = np.asarray(qpos) >= 0
    np.testing.assert_allclose(np.asarray(via_ops)[valid],
                               np.asarray(quant)[valid],
                               rtol=1e-4, atol=1e-5)
