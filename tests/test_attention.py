"""Chunked attention unit tests: oracle equivalence, masks, GQA, windows,
softcap, int8-KV dequant path, decode positions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.models.layers import chunked_attention, quantize_kv, softcap


def _naive(q, k, v, q_pos, causal=True, window=None, cap=0.0):
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    kk = jnp.repeat(k, g, axis=2)
    vv = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kk.astype(jnp.float32)) * hd**-0.5
    s = softcap(s, cap)
    kv_pos = jnp.arange(skv)
    mask = jnp.ones((b, sq, skv), bool)
    if causal:
        mask &= q_pos[:, :, None] >= kv_pos[None, None, :]
    if window is not None:
        mask &= (q_pos[:, :, None] - kv_pos[None, None, :]) < window
    s = jnp.where(mask[:, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, vv.astype(jnp.float32))


def _mk(seed, b=2, sq=24, skv=24, h=4, kvh=2, hd=16):
    r = np.random.default_rng(seed)
    q = jnp.asarray(r.standard_normal((b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, skv, kvh, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, skv, kvh, hd)), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("chunk", [4, 8, 64])
@pytest.mark.parametrize("window", [None, 7])
def test_matches_naive(chunk, window):
    q, k, v = _mk(0)
    qp = jnp.broadcast_to(jnp.arange(24), (2, 24))
    got = chunked_attention(q, k, v, q_positions=qp, causal=True,
                            window=window, kv_chunk=chunk)
    want = _naive(q, k, v, qp, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_softcap_matches_naive():
    q, k, v = _mk(1)
    qp = jnp.broadcast_to(jnp.arange(24), (2, 24))
    got = chunked_attention(q, k, v, q_positions=qp, attn_softcap=5.0,
                            kv_chunk=8)
    want = _naive(q, k, v, qp, cap=5.0)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_position_mid_cache():
    """Query at pos 10 in a 24-slot cache: slots >10 (garbage) masked."""
    q, k, v = _mk(2, sq=1)
    k = k.at[:, 11:].set(1e3)  # poison the unwritten region
    v = v.at[:, 11:].set(1e3)
    qp = jnp.full((2, 1), 10)
    got = chunked_attention(q, k, v, q_positions=qp, kv_chunk=8)
    want = _naive(q, k[:, :11], v[:, :11],
                  qp)  # only valid prefix
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_int8_kv_tuple_path():
    q, k, v = _mk(3)
    qp = jnp.broadcast_to(jnp.arange(24), (2, 24))
    kq, ks = quantize_kv(k, 8)
    vq, vs = quantize_kv(v, 8)
    got = chunked_attention(q, (kq, ks), (vq, vs), q_positions=qp,
                            kv_chunk=8)
    want = _naive(k=jnp.asarray(kq, jnp.float32) * ks,
                  v=jnp.asarray(vq, jnp.float32) * vs, q=q, q_pos=qp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)
    # and int8 quant is close to fp attention
    full = _naive(q, k, v, qp)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                               rtol=0.15, atol=0.05)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 9999), chunk=st.sampled_from([3, 5, 16]),
       skv=st.integers(8, 40))
def test_property_chunking_invariance(seed, chunk, skv):
    """Output is invariant to chunk size (incl. non-divisible chunks)."""
    _check_chunking_invariance(seed, chunk, skv)


# Deterministic port of the property above — runs without hypothesis.
@pytest.mark.parametrize("seed,chunk,skv",
                         [(0, 3, 8), (1, 5, 23), (2, 16, 40), (3, 5, 15),
                          (4, 3, 33)])
def test_chunking_invariance_seeded(seed, chunk, skv):
    _check_chunking_invariance(seed, chunk, skv)


def _check_chunking_invariance(seed, chunk, skv):
    q, k, v = _mk(seed, sq=8, skv=skv)
    qp = jnp.broadcast_to(jnp.arange(8) + (skv - 8), (2, 8))
    a = chunked_attention(q, k, v, q_positions=qp, kv_chunk=chunk)
    b = chunked_attention(q, k, v, q_positions=qp, kv_chunk=skv)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-4)
