"""Optional-`hypothesis` shim for the property-test modules.

``hypothesis`` is an optional dev extra. Modules do

    from _hypothesis_shim import HAVE_HYPOTHESIS, given, settings, st

When hypothesis is installed this re-exports the real API. When it is
not, ``@settings(...)`` is a no-op and ``@given(...)`` replaces the test
with a skip (reason: hypothesis not installed) — so the module still
collects cleanly and its deterministic (parametrize) ports keep running.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on the environment
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Accepts any strategy constructor call; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _AnyStrategy()

    def given(*_a, **_k):
        def deco(fn):
            @pytest.mark.skip(
                reason="hypothesis not installed (pip install hypothesis)")
            def skipped():
                pass  # pragma: no cover

            skipped.__name__ = fn.__name__
            skipped.__doc__ = fn.__doc__
            return skipped
        return deco

    def settings(*_a, **_k):
        return lambda fn: fn
