"""Chunked GLA vs the sequential recurrence oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gla


def _inputs(seed, b=2, s=67, h=3, dk=8, dv=16):
    rng = np.random.default_rng(seed)
    r = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, h, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_w = jnp.asarray(-rng.uniform(1e-4, 1.0, (b, s, h, dk)), jnp.float32)
    return r, k, v, log_w


@pytest.mark.parametrize("chunk", [1, 8, 32, 128])
def test_chunked_matches_sequential(chunk):
    r, k, v, log_w = _inputs(0)
    o_chunk, s_chunk = gla.gla_chunked(r, k, v, log_w, chunk=chunk)
    o_ref, s_ref = gla.gla_reference(r, k, v, log_w)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(s_ref),
                               rtol=2e-4, atol=2e-4)


def test_state_carry_across_calls():
    """prefill(s) then continue == one long call (the decode contract)."""
    r, k, v, log_w = _inputs(1, s=64)
    o_full, s_full = gla.gla_chunked(r, k, v, log_w, chunk=16)
    o1, st = gla.gla_chunked(r[:, :40], k[:, :40], v[:, :40], log_w[:, :40],
                             chunk=16)
    o2, s2 = gla.gla_chunked(r[:, 40:], k[:, 40:], v[:, 40:], log_w[:, 40:],
                             state=st, chunk=16)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)


def test_decode_step_matches_chunked():
    r, k, v, log_w = _inputs(2, s=5)
    o_ref, _ = gla.gla_chunked(r, k, v, log_w, chunk=32)
    state = None
    outs = []
    import jax
    state = jnp.zeros((2, 3, 8, 16), jnp.float32)
    for t in range(5):
        o, state = gla.gla_decode_step(r[:, t], k[:, t], v[:, t],
                                       log_w[:, t], state)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(o_ref), rtol=2e-4, atol=2e-4)


def test_no_nan_under_extreme_decay():
    r, k, v, log_w = _inputs(3, s=128)
    log_w = gla.clamp_log_decay(log_w * 1000.0)  # saturates at LOG_W_MIN
    o, s = gla.gla_chunked(r, k, v, log_w, chunk=32)
    assert bool(jnp.all(jnp.isfinite(o))) and bool(jnp.all(jnp.isfinite(s)))


def test_ssd_chunked_matches_broadcast_gla():
    """The factored SSD form == gla_chunked on broadcast r/k + scalar
    decay (the §Perf B1 rewrite is exact)."""
    rng = np.random.default_rng(7)
    b, s, h, dk, dv = 2, 53, 3, 8, 16
    r = jnp.asarray(rng.standard_normal((b, s, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_w = jnp.asarray(-rng.uniform(1e-4, 1.0, (b, s, h)), jnp.float32)
    o, st = gla.ssd_chunked(r, k, v, log_w, chunk=16)
    rb = jnp.broadcast_to(r[:, :, None, :], (b, s, h, dk))
    kb = jnp.broadcast_to(k[:, :, None, :], (b, s, h, dk))
    lwb = jnp.broadcast_to(log_w[..., None], (b, s, h, dk))
    o2, st2 = gla.gla_chunked(rb, kb, v, lwb, chunk=16)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st2), rtol=2e-4,
                               atol=2e-4)


def test_ssd_decode_matches_chunked():
    rng = np.random.default_rng(8)
    b, s, h, dk, dv = 2, 6, 3, 8, 16
    r = jnp.asarray(rng.standard_normal((b, s, dk)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, dk)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, h, dv)), jnp.float32)
    log_w = jnp.asarray(-rng.uniform(1e-4, 1.0, (b, s, h)), jnp.float32)
    o_ref, st_ref = gla.ssd_chunked(r, k, v, log_w, chunk=32)
    st = jnp.zeros((b, h, dk, dv), jnp.float32)
    outs = []
    for t in range(s):
        o, st = gla.ssd_decode_step(r[:, t], k[:, t], v[:, t], log_w[:, t],
                                    st)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(o_ref), rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-4, atol=2e-4)
