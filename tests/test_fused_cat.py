"""Fused CAT→quant→W4A8 serving path.

Covers the PR's hot-path pieces end to end:

- the single-launch Pallas kernel (``kernels/fused_cat_matmul.py``) vs
  the pure-jnp oracle (``ref.fused_cat_matmul_w4``) at rtol 1e-5 —
  packed and unpacked weights, with and without the block-CAT stage,
  odd K (padded nibble) and K not a multiple of the CAT block
- the composed ``ops.cat_transform_matmul`` across the M ∈ {7, 8, 9}
  GEMV-vs-tiled dispatch boundary (``_GEMV_M`` = 8)
- ``ops.fused_transform_operands`` decomposition (Scale folds into the
  Hadamard sign; undecomposable transforms return None)
- the per-shape block-size autotune cache (``kernels/autotune.py``)
- fused-vs-unfused ServeEngine token identity on a quantized smoke model
  (the golden fixtures pin the same property against stored tokens;
  this pins it against a live unfused engine)
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import transforms as T
from repro.core.quantizers import pack_int4
from repro.kernels import autotune, ops, ref
from repro.kernels.fused_cat_matmul import (fused_cat_gemv_w4,
                                            fused_cat_matmul_w4)


def _factor(d):
    """(a, b) with a·b = d, near sqrt — mirrors the Kronecker split."""
    a = int(np.sqrt(d))
    while d % a:
        a -= 1
    return a, d // a


def _operands(m, d, n, seed, n_blocks=0):
    """Random fused-kernel operands. ha/hb are arbitrary Kronecker
    factors (the kernel contract needs no true Hadamard structure and
    arbitrary d — e.g. odd — must work)."""
    r = np.random.default_rng(seed)
    x = jnp.asarray(r.standard_normal((m, d)), jnp.float32)
    blocks = None
    if n_blocks:
        assert d % n_blocks == 0
        bk = d // n_blocks
        blocks = jnp.asarray(
            r.standard_normal((n_blocks, bk, bk)) * 0.3 + np.eye(bk),
            jnp.float32)
    a, b = _factor(d)
    ha = jnp.asarray(r.standard_normal((a, a)) / np.sqrt(a), jnp.float32)
    hb = jnp.asarray(r.standard_normal((b, b)) / np.sqrt(b), jnp.float32)
    sign = jnp.asarray(r.integers(0, 2, d) * 2 - 1, jnp.float32)
    qw = jnp.asarray(r.integers(-8, 8, (d, n)), jnp.int8)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    return x, blocks, ha, hb, sign, qw, sw


def _check_fused_matches_oracle(m, d, n, seed, n_blocks=0, packed=True,
                                act_bits=8, **kw):
    x, blocks, ha, hb, sign, qw, sw = _operands(m, d, n, seed, n_blocks)
    w = pack_int4(qw, axis=0) if packed else qw
    run = fused_cat_gemv_w4 if m <= ops._GEMV_M else fused_cat_matmul_w4
    got = run(x, blocks, ha, hb, sign, w, sw, act_bits=act_bits,
              packed=packed, interpret=True, **kw)
    want = ref.fused_cat_matmul_w4(x, blocks, ha, hb, sign, w, sw,
                                   act_bits=act_bits, packed=packed)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------------- kernel vs oracle

@pytest.mark.parametrize("m", [7, 8, 9])
def test_fused_kernel_gemv_tiled_boundary(m):
    """M ∈ {7, 8, 9} straddles the GEMV/tiled dispatch; both kernels must
    agree with the oracle (with the block-CAT stage active)."""
    _check_fused_matches_oracle(m, 64, 96, seed=m, n_blocks=4)


@pytest.mark.parametrize("packed", [True, False])
@pytest.mark.parametrize("m,d,n,n_blocks", [
    (5, 64, 96, 0),        # no block stage (bare Hadamard transform)
    (17, 96, 80, 6),       # tiled, blocks, K not a multiple of block_k
    (33, 63, 40, 0),       # odd K: padded nibble must stay inert
    (3, 63, 40, 7),        # odd K through the GEMV path, with blocks
])
def test_fused_kernel_matches_oracle(packed, m, d, n, n_blocks):
    _check_fused_matches_oracle(m, d, n, seed=m * 100 + d, packed=packed,
                                n_blocks=n_blocks)


def test_fused_kernel_small_block_sizes():
    """Explicit tiny block sizes force multi-step grids in every dim."""
    _check_fused_matches_oracle(19, 96, 72, seed=3, n_blocks=6,
                                block_m=8, block_n=32, block_k=32)


@pytest.mark.parametrize("act_bits", [4, 8])
def test_fused_kernel_act_bits(act_bits):
    _check_fused_matches_oracle(9, 64, 48, seed=act_bits, n_blocks=4,
                                act_bits=act_bits)


# ----------------------------------------- composed path dispatch boundary

@pytest.mark.parametrize("m", [7, 8, 9])
@pytest.mark.parametrize("d,n,n_blocks", [(64, 96, 4), (63, 40, 0)])
def test_cat_transform_matmul_gemv_boundary(m, d, n, n_blocks):
    """The composed serving linear around the same M boundary, including
    odd K — GEMV and tiled routes must be interchangeable."""
    x, blocks, ha, hb, sign, qw, sw = _operands(m, d, n, seed=m,
                                                n_blocks=n_blocks)
    if blocks is None:
        blocks = jnp.eye(d, dtype=jnp.float32)[None]
    wp = pack_int4(qw, axis=0)
    got = ops.cat_transform_matmul(x, blocks, ha, hb, sign, wp, sw,
                                   act_bits=8, packed_int4=True)
    xf = ref.block_diag_matmul(x.astype(jnp.float32), blocks)
    q, s, zp = ref.fused_hadamard_quant(xf, ha, hb, sign, bits=8)
    want = ref.quant_matmul_w4(q, s, zp, wp, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------- operand decomposition

def test_fused_transform_operands_decomposes_cat():
    r = np.random.default_rng(0)
    t = T.make_cat_block(jnp.eye(64) * 2.0, jnp.eye(64), k=16, rng=r)
    blocks, ha, hb, sign = ops.fused_transform_operands(t)
    assert blocks is not None and blocks.shape[0] == 4
    assert ha.shape[0] * hb.shape[0] == 64
    x = jnp.asarray(r.standard_normal((3, 64)), jnp.float32)
    want = T.apply(t, x)
    got = ref.hadamard_transform(
        ref.block_diag_matmul(x, blocks) * sign[None, :], ha, hb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_fused_transform_operands_folds_scale_into_sign():
    r = np.random.default_rng(1)
    had = T.make_hadamard(32, r)
    s = jnp.asarray(r.uniform(0.5, 2.0, 32), jnp.float32)
    t = T.Compose((T.Scale(s), had))
    blocks, ha, hb, sign = ops.fused_transform_operands(t)
    assert blocks is None
    np.testing.assert_allclose(np.asarray(sign), np.asarray(had.sign * s))
    x = jnp.asarray(r.standard_normal((2, 32)), jnp.float32)
    got = ref.hadamard_transform(x * sign[None, :], ha, hb)
    np.testing.assert_allclose(np.asarray(got), np.asarray(T.apply(t, x)),
                               rtol=1e-5, atol=1e-6)


def test_fused_transform_operands_rejects_dense():
    r = np.random.default_rng(2)
    assert ops.fused_transform_operands(T.make_rotation(16, r)) is None
    assert ops.fused_transform_operands(T.Identity()) is None


# --------------------------------------------------------- autotune cache

def test_autotune_heuristic_fits_budget():
    for m, d, n, packed in [(1, 64, 512, True), (256, 4096, 11008, True),
                            (8, 2048, 2048, False)]:
        tm, tn, tk = autotune.heuristic_blocks(m, d, n, packed)
        assert autotune._fused_working_set(tm, tn, tk, d, packed) \
            <= autotune.VMEM_BUDGET
        assert tm % 8 == 0 and tn % 8 == 0


def test_autotune_pick_memoizes():
    autotune.cache_clear()
    key = ("test", 8, 64, 96, True, True)
    first = autotune.pick(key, 8, 64, 96, True)
    assert autotune.pick(key, 8, 64, 96, True) is first
    assert key in autotune.cache_info()
    autotune.cache_clear()
    assert key not in autotune.cache_info()


# ------------------------------------------------- engine token identity

@pytest.mark.slow
def test_fused_engine_matches_unfused():
    """ServeEngine(fused=True) — QKV/GU concat + w_eff serving params —
    must be token-identical to the unfused engine on a w4-packed CAT
    model (the golden fixtures pin the same tokens against disk)."""
    from repro.data import request_workload
    from repro.launch.engine import ServeEngine
    from repro.launch.serve import build_served_model

    cfg, model, params, _ = build_served_model(
        "catlm_60m", "cat", 4, 4, 8, smoke=True, seed=0)
    reqs = request_workload(cfg, 3, gen=4, lengths=(6, 10), seed=1)
    outs = {}
    for fused in (True, False):
        eng = ServeEngine(model, params, n_slots=2, max_len=24,
                          fused=fused)
        outs[fused] = eng.run(reqs)
        assert eng.summary()["fused"] is fused
    for r in reqs:
        rid = r["rid"]
        np.testing.assert_array_equal(outs[True][rid].tokens,
                                      outs[False][rid].tokens)
