"""Launcher tests: trainer end-to-end (with failure injection + resume),
serving driver, dry-run machinery in a subprocess."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.mark.slow
def test_trainer_loss_decreases(tmp_path):
    from repro.launch.train import train
    final, losses = train(arch="catlm_60m", steps=30, batch=4, seq=64,
                          lr=1e-3, ckpt_dir=str(tmp_path), ckpt_every=10)
    assert final == 30
    assert np.mean(losses[-5:]) < np.mean(losses[:5]), losses


@pytest.mark.slow
def test_trainer_survives_injected_failures(tmp_path):
    from repro import checkpoint as ck
    from repro.launch.train import train
    final, losses = train(arch="catlm_60m", steps=24, batch=2, seq=32,
                          ckpt_dir=str(tmp_path), ckpt_every=8,
                          fail_at=(10, 19))
    assert final == 24
    assert ck.latest_step(str(tmp_path)) == 24
    # restarts resumed from checkpoints: more recorded losses than steps
    assert len(losses) > 24


@pytest.mark.slow
def test_trainer_resume_bit_exact(tmp_path):
    """20 straight steps == 10 steps + checkpoint + restart + 10 steps."""
    from repro.launch.train import train
    _, l_straight = train(arch="catlm_60m", steps=20, batch=2, seq=32,
                          ckpt_dir=None, seed=7)
    d = str(tmp_path)
    train(arch="catlm_60m", steps=10, batch=2, seq=32, ckpt_dir=d,
          ckpt_every=10, seed=7)
    _, l_resumed = train(arch="catlm_60m", steps=20, batch=2, seq=32,
                         ckpt_dir=d, ckpt_every=10, seed=7)
    np.testing.assert_allclose(l_straight[-1], l_resumed[-1], rtol=1e-4)


@pytest.mark.slow
def test_mixed_precision_trainer():
    from repro.launch.train import train
    final, losses = train(arch="catlm_60m", steps=10, batch=2, seq=32,
                          mixed_precision=True)
    assert final == 10 and np.isfinite(losses).all()


@pytest.mark.slow
def test_serve_quantized_generates():
    from repro.launch.serve import serve_benchmark
    out = serve_benchmark(arch="catlm_60m", batch=2, prompt_len=16, gen=8,
                          transform="cat")
    assert out["tokens"].shape == (2, 24)
    assert out["tok_per_s"] > 0


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """The dry-run machinery (512 fake devices, production mesh, lower +
    compile + analyses) on the smallest cell, isolated in a subprocess."""
    out = str(tmp_path / "dr.json")
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "catlm_60m",
         "--shape", "decode_32k", "--mesh", "both", "--out", out],
        capture_output=True, text=True, timeout=560,
        env={**os.environ, "PYTHONPATH": SRC})
    assert r.returncode == 0, r.stdout + r.stderr
    data = json.load(open(out))
    assert len(data) == 2
    for key, rec in data.items():
        assert rec["flops"] > 0, rec
        assert rec["memory"]["argument_size_in_bytes"] > 0
        # quantized serving: per-device int8 weights beat bf16 budget
        assert "collective_bytes" in rec


def test_main_process_still_single_device():
    import jax
    assert len(jax.devices()) == 1
