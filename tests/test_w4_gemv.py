"""Decode-shaped W4A8 GEMV Pallas kernel vs the pure-jnp oracle: seeded
cases + hypothesis property tests over M ∈ [1, 8], odd K, K not a multiple
of the block, and agreement with the tiled matmul kernel."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.core.quantizers import pack_int4
from repro.kernels import ops, ref
from repro.kernels.quant_matmul_w4 import quant_gemv_w4, quant_matmul_w4


def _inputs(m, n, k, seed):
    r = np.random.default_rng(seed)
    qx = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
    sx = jnp.asarray(r.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    zpx = jnp.asarray(r.integers(-8, 8, (m, 1)), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    return qx, sx, zpx, qw, sw


def _check_gemv_matches_ref(m, n, k, seed, block_n=32, block_k=32):
    qx, sx, zpx, qw, sw = _inputs(m, n, k, seed)
    qwp = pack_int4(qw, axis=0)
    got = quant_gemv_w4(qx, sx, zpx, qwp, sw, block_n=block_n,
                        block_k=block_k, interpret=True)
    want = ref.quant_gemv_w4(qx, sx, zpx, qwp, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ----------------------------------------------------------------- seeded

@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("nk", [(16, 32), (65, 129), (96, 64), (7, 3)])
def test_gemv_matches_ref_seeded(m, nk):
    n, k = nk
    _check_gemv_matches_ref(m, n, k, seed=m * 1000 + n + k)


@pytest.mark.parametrize("k,block_k", [(3, 10), (127, 32), (50, 40),
                                       (129, 512)])
def test_gemv_odd_and_non_multiple_k(k, block_k):
    """Odd K (padded nibble) and K not a multiple of the block."""
    _check_gemv_matches_ref(3, 24, k, seed=k, block_k=block_k)


def test_gemv_equals_tiled_matmul_kernel():
    """Blocking is the only difference: GEMV == tiled kernel on one input."""
    qx, sx, zpx, qw, sw = _inputs(8, 48, 96, 17)
    qwp = pack_int4(qw, axis=0)
    got_g = quant_gemv_w4(qx, sx, zpx, qwp, sw, block_n=16, block_k=32,
                          interpret=True)
    got_m = quant_matmul_w4(qx, sx, zpx, qwp, sw, block_m=8, block_n=16,
                            block_k=32, interpret=True)
    np.testing.assert_allclose(np.asarray(got_g), np.asarray(got_m),
                               rtol=1e-6, atol=1e-6)


def test_gemv_rejects_large_m():
    qx, sx, zpx, qw, sw = _inputs(9, 16, 32, 0)
    with pytest.raises(AssertionError):
        quant_gemv_w4(qx, sx, zpx, pack_int4(qw, axis=0), sw,
                      interpret=True)


def test_ops_decode_path_dispatches_to_gemv():
    """cat_transform_matmul serves decode shapes (M<=8) from the packed
    buffer via the GEMV kernel — result equals the int8-code path."""
    from repro.core.hadamard import hadamard_factors
    r = np.random.default_rng(23)
    d, d_out = 64, 48
    ha, hb = map(lambda h: jnp.asarray(h, jnp.float32), hadamard_factors(d))
    sign = jnp.asarray(r.choice([-1.0, 1.0], d), jnp.float32)
    x = jnp.asarray(r.standard_normal((1, d)), jnp.float32)  # decode row
    blocks = jnp.asarray(r.standard_normal((d // 16, 16, 16)) / 4,
                         jnp.float32)
    qw = jnp.asarray(r.integers(-8, 8, (d, d_out)), jnp.int8)
    sw = jnp.asarray(r.uniform(0.01, 0.05, (1, d_out)), jnp.float32)
    y8 = ops.cat_transform_matmul(x, blocks, ha, hb, sign, qw, sw,
                                  act_bits=8, interpret=True)
    y4 = ops.cat_transform_matmul(x, blocks, ha, hb, sign,
                                  pack_int4(qw, axis=0), sw, act_bits=8,
                                  packed_int4=True, interpret=True)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y8),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- property

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 8),
    n=st.integers(1, 64),
    k=st.integers(1, 160),
    block_k=st.sampled_from([10, 32, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_gemv_matches_ref(m, n, k, block_k, seed):
    _check_gemv_matches_ref(m, n, k, seed, block_k=block_k)


# Deterministic ports of the property — run without hypothesis.
@pytest.mark.parametrize("m", [1, 3, 8])
@pytest.mark.parametrize("k,block_k", [(1, 10), (31, 32), (160, 64)])
@pytest.mark.parametrize("seed", [0, 1234])
def test_gemv_matches_ref_ports(m, k, block_k, seed):
    _check_gemv_matches_ref(m, 33, k, seed, block_k=block_k)
