"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step + prefill/decode on CPU; asserts shapes + finiteness.
Also checks decode-vs-forward consistency (the KV-cache contract).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.data import make_batch
from repro.models import build

SMOKE_B, SMOKE_S = 2, 32


def _smoke_model(arch):
    cfg = get_config(arch).smoke()
    return cfg, build(cfg)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_shapes_and_finite(arch):
    cfg, model = _smoke_model(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, SMOKE_S, SMOKE_B, seed=0).items()}
    loss, metrics = jax.jit(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), (arch, float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gn)), arch


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    """decode(t) after prefill(t0..t-1) must match teacher-forced forward."""
    cfg, model = _smoke_model(arch)
    params = model.init(jax.random.PRNGKey(1))
    batch = make_batch(cfg, SMOKE_S, SMOKE_B, seed=1)
    toks = jnp.asarray(batch["tokens"])
    kw = {}
    if cfg.family == "encdec":
        kw["enc_embed"] = jnp.asarray(batch["enc_embed"])
    if cfg.family == "vlm":
        kw["extra_embed"] = jnp.asarray(batch["patch_embed"])

    # teacher-forced logits
    fkw = {}
    if cfg.family == "encdec":
        fkw["enc_embed"] = kw["enc_embed"]
    if cfg.family == "vlm":
        fkw["extra_embed"] = kw["extra_embed"]
    hidden, _, _ = model.forward(params, toks, **fkw)
    full_logits = model.logits(params, hidden)
    if cfg.family == "vlm":
        full_logits = full_logits[:, cfg.n_patches:]

    # prefill on the first half, then decode token by token
    half = SMOKE_S // 2
    cache = model.init_cache(SMOKE_B, SMOKE_S + (cfg.n_patches or 0))
    pkw = dict(kw)
    logits_p, cache = model.prefill(params, toks[:, :half], cache, **pkw)
    np.testing.assert_allclose(
        np.asarray(logits_p[:, 0], np.float32),
        np.asarray(full_logits[:, half - 1], np.float32),
        rtol=5e-2, atol=5e-2)

    logits_d, cache = model.decode(params, toks[:, half:half + 1], cache)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0], np.float32),
        np.asarray(full_logits[:, half], np.float32),
        rtol=5e-2, atol=5e-2)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma2_2b", "granite_moe_1b_a400m",
                                  "rwkv6_7b", "zamba2_7b"])
def test_two_train_steps_reduce_loss_direction(arch):
    """A couple of SGD steps on repeated data shouldn't blow up."""
    cfg, model = _smoke_model(arch)
    params = model.init(jax.random.PRNGKey(2))
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, SMOKE_S, SMOKE_B, seed=2).items()}
    val_grad = jax.jit(jax.value_and_grad(lambda p: model.loss(p, batch)[0]))
    l0, g = val_grad(params)
    params = jax.tree.map(lambda p, gg: p - 0.5 * gg, params, g)
    l1, _ = val_grad(params)
    assert bool(jnp.isfinite(l1))
    assert float(l1) < float(l0) + 0.5  # no explosion


def test_full_configs_exact_shapes():
    """The FULL configs match the published tables (abstract check only —
    params via eval_shape, no allocation)."""
    expect = {
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256_000),
        "mistral_nemo_12b": (40, 5120, 32, 8, 14336, 131_072),
        "granite_34b": (88, 6144, 48, 1, 24576, 49_152),
        "gemma3_12b": (48, 3840, 16, 8, 15360, 262_144),
        "zamba2_7b": (81, 3584, 32, 32, 14336, 32_000),
        "whisper_small": (12, 768, 12, 12, 3072, 51_865),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65_536),
        "granite_moe_1b_a400m": (24, 1024, 16, 8, 512, 49_155),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163_840),
        "paligemma_3b": (18, 2048, 8, 1, 16384, 257_216),
    }
    for arch, (L, D, H, KV, F, V) in expect.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab) == (L, D, H, KV, F, V), arch


def test_full_config_param_counts_sane():
    """eval_shape the FULL models; param counts must be in the right
    ballpark for their names (catches wiring mistakes at zero memory)."""
    from repro.models.model import param_count
    expectations = {  # (min, max) billions
        "gemma2_2b": (2.0, 3.6),
        "mistral_nemo_12b": (11.0, 13.5),
        "granite_34b": (32.0, 36.0),
        "gemma3_12b": (10.5, 14.0),
        "zamba2_7b": (6.0, 8.5),
        "whisper_small": (0.15, 0.45),
        "rwkv6_7b": (6.0, 8.5),
        "granite_moe_1b_a400m": (1.0, 1.7),
        # assigned pool config (48L x 64e x 1408) totals ~28B with ~3.3B
        # active (the "A3B"); see DESIGN.md §5 notes
        "moonshot_v1_16b_a3b": (26.0, 30.0),
        "paligemma_3b": (2.0, 3.5),
    }
    for arch, (lo, hi) in expectations.items():
        cfg = get_config(arch)
        model = build(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
        assert lo * 1e9 <= n <= hi * 1e9, (arch, n / 1e9)
