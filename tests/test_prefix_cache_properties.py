"""Property tests for the refcount/COW prefix-cache layer
(``repro.launch.paged.PagePool`` refcounts, ``SlotPageTables`` COW, and
``PrefixCache``), driven two ways:

1. **Pure-host scheduler drive** — the unified scheduler with a
   ``PrefixCache`` runs its plan/observe loop against a python executor
   (the stub next-token rule), over workloads of requests sharing system
   prompts. Invariants checked after EVERY step:

   - refcount conservation: ``pool.total_refs`` equals slot-table
     mappings plus trie residencies, ``pool.in_use`` equals the distinct
     union of both, and the null page is never mapped or allocated
   - shared-marked pages always carry refcount >= 2 (a page is a
     scatter-write target only at refcount 1)
   - trajectories match the per-request simulation exactly — prefix
     sharing must not change a single token
   - drained: every page returns to the trie or the free heap, slots
     empty, reservations dropped; ``clear()`` then drains the pool to 0

2. **Direct unit/property tests** — pool free-safety (no double free, no
   free while shared), COW split semantics and scatter guards, LRU
   eviction safety, trie longest-prefix lookup against a brute-force
   oracle, and the missed-pages reservation regression (the worst-case
   formula head-of-line blocks cache-hit requests an undersized pool can
   actually serve).

Runs via tests/_hypothesis_shim: property cases when hypothesis is
installed, the seeded deterministic ports always."""
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.launch.paged import (NULL_PAGE, PagePool, PrefixCache,
                                SlotPageTables)
from repro.launch.scheduler import Request, TokenBudgetScheduler

_V = 64          # stub vocab


def _next_token(tok, pos):
    """Pure next-token rule mixing token and absolute position (any
    stale/leaked/mis-copied page changes output)."""
    return (tok * 7 + pos * 13 + 1) % _V


def _simulate(prompt, max_new):
    toks = list(prompt)
    tok, pos = int(prompt[-1]), len(prompt) - 1
    for _ in range(max_new):
        tok = _next_token(tok, pos)
        toks.append(tok)
        pos += 1
    return toks


# ------------------------------------------------------ refcount invariants

def _check_refcounts(pool, tables, prefix, n_slots):
    """The conservation laws that make sharing safe, checked as one
    snapshot: every refcount is accounted for by a live mapping."""
    slot_pages = [p for s in range(n_slots) for p in tables.owned_pages(s)]
    trie_pages = [n.page for n in prefix._walk()]
    assert pool.total_refs == len(slot_pages) + len(trie_pages), (
        "refcount leak: refs != slot mappings + trie residencies")
    assert prefix.resident == len(trie_pages)
    assert pool.in_use == len(set(slot_pages) | set(trie_pages)), (
        "page allocated with no mapping, or mapping to a freed page")
    assert pool.refcount(NULL_PAGE) == 0
    assert NULL_PAGE not in slot_pages and NULL_PAGE not in trie_pages
    assert pool.available + pool.in_use == pool.n_pages - 1
    for s in range(n_slots):
        owned = tables.owned_pages(s)
        for p in tables._shared[s]:
            assert p in owned, "shared-marked page not in the slot's table"
            assert pool.refcount(p) >= 2, (
                "shared-marked page with refcount < 2 — would be treated "
                "as read-only while actually exclusively owned")


def _shared_workload(seed, n_reqs, page_size):
    """Requests over two seeded system prompts: full-prefix repeats,
    mid-page divergence (partial hits -> COW), and unrelated prompts."""
    rng = np.random.default_rng(seed)
    G = page_size
    sys1 = rng.integers(0, _V, 3 * G + 1).astype(np.int32)
    sys2 = rng.integers(0, _V, G).astype(np.int32)
    reqs = []
    for rid in range(n_reqs):
        kind = rng.integers(0, 4)
        if kind == 0:
            head = sys1
        elif kind == 1:
            head = sys1[:G + 1]           # diverges mid-page -> COW
        elif kind == 2:
            head = sys2
        else:
            head = sys1[:0]
        tail = rng.integers(0, _V, int(rng.integers(0, 2 * G + 1)))
        prompt = np.concatenate([head, tail]).astype(np.int32)
        if not len(prompt):
            prompt = np.asarray([int(rng.integers(0, _V))], np.int32)
        reqs.append(Request(rid, prompt, int(rng.integers(1, 6))))
    return reqs


def _drive_prefix(reqs, n_slots, max_batch_tokens, page_size=4,
                  prefill_chunk=0, pool_pages=0):
    """Scheduler plan/pack/observe loop with a PrefixCache, python
    executor; refcount invariants checked after every step."""
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    n_ptab = -(-max_len // page_size)
    n_pages = pool_pages or (1 + n_slots * n_ptab)
    pool = PagePool(n_pages, page_size)
    tables = SlotPageTables(pool, n_slots, n_ptab)
    prefix = PrefixCache(pool, page_size)
    sched = TokenBudgetScheduler(n_slots, max_batch_tokens, pool=pool,
                                 tables=tables,
                                 prefill_chunk=prefill_chunk, prefix=prefix)
    for r in reqs:
        sched.queue.append(r)
    done, guard = {}, 0
    while not sched.idle:
        guard += 1
        assert guard < 20_000, "scheduler failed to drain"
        plan = sched.plan(guard)
        packed = sched.pack(plan)
        toks = [_next_token(int(packed["tokens"][row, 0]),
                            int(packed["pos"][row]))
                for row in packed["logit_rows"][:packed["n_logits"]]]
        for seq in sched.observe(plan, np.asarray(toks), now=0.0):
            assert seq.req.rid not in done, "retired twice"
            done[seq.req.rid] = list(seq.req.prompt) + seq.generated
        _check_refcounts(pool, tables, prefix, n_slots)
    return sched, pool, tables, prefix, done


def _check_prefix_invariants(seed, n_reqs, n_slots, budget_extra,
                             prefill_chunk, page_size, tight_pool):
    reqs = _shared_workload(seed, n_reqs, page_size)
    pool_pages = 0
    if tight_pool:
        # just enough for the single largest request plus one spare:
        # admission must reclaim trie-only pages (LRU eviction) and
        # head-of-line wait on live slots, yet still drain
        max_need = max(-(-(len(r.prompt) + r.max_new_tokens) // page_size)
                       for r in reqs)
        pool_pages = 1 + max_need + 1
    sched, pool, tables, prefix, done = _drive_prefix(
        reqs, n_slots, n_slots + budget_extra, page_size=page_size,
        prefill_chunk=prefill_chunk, pool_pages=pool_pages)
    # prefix sharing must not change a single generated token
    for r in reqs:
        assert done[r.rid] == _simulate(r.prompt, r.max_new_tokens), r.rid
    # drained: slots free, reservations dropped, every live page is
    # trie-resident; clear() then returns the pool to empty
    assert sorted(sched.free) == list(range(n_slots))
    assert tables.reserved_unallocated == 0
    assert pool.in_use == prefix.resident
    prefix.clear()
    assert pool.in_use == 0 and pool.total_refs == 0
    assert pool.available == pool.n_pages - 1
    assert pool.allocs == pool.frees


# --------------------------------------------------------------- property

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**16),
    n_reqs=st.integers(1, 8),
    n_slots=st.integers(1, 3),
    budget_extra=st.integers(0, 10),
    prefill_chunk=st.integers(0, 5),
    page_size=st.sampled_from([2, 4]),
    tight_pool=st.booleans(),
)
def test_property_prefix_refcount_invariants(seed, n_reqs, n_slots,
                                             budget_extra, prefill_chunk,
                                             page_size, tight_pool):
    _check_prefix_invariants(seed, n_reqs, n_slots, budget_extra,
                             prefill_chunk, page_size, tight_pool)


# ---------------------------------------------- deterministic seeded ports

@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4, 5, 6, 7])
def test_prefix_refcount_invariants_ports(seed):
    rng = np.random.default_rng(seed ^ 0xC0)
    _check_prefix_invariants(
        seed=seed, n_reqs=int(rng.integers(2, 9)),
        n_slots=int(rng.integers(1, 4)),
        budget_extra=int(rng.integers(0, 11)),
        prefill_chunk=int(rng.integers(0, 6)) if seed % 2 else 0,
        page_size=4 if seed % 3 else 2,
        tight_pool=bool(seed % 2))


def test_shared_prefix_workload_actually_hits():
    """Non-vacuousness: identical prompts served sequentially hit the
    cache (and still token-match the simulation, checked by the drive)."""
    prompt = np.arange(9, dtype=np.int32) % _V
    reqs = [Request(rid, prompt, 3) for rid in range(4)]
    _, pool, _, prefix, _ = _drive_prefix(reqs, n_slots=1,
                                          max_batch_tokens=6)
    assert prefix.hits >= 3          # every admission after the first
    assert prefix.hit_tokens > 0
    assert 0.0 < prefix.hit_rate <= 1.0


# --------------------------------------------------------- pool free-safety

def test_pool_no_double_free_and_no_free_while_shared():
    pool = PagePool(4, 2)
    p = pool.alloc()
    pool.incref(p)                       # rc 2 (a second mapping)
    with pytest.raises(RuntimeError, match="still shared"):
        pool.free(p)                     # exclusive free needs rc == 1
    assert not pool.decref(p)            # rc 2 -> 1: not freed
    assert pool.decref(p)                # rc 1 -> 0: freed
    with pytest.raises(RuntimeError, match="double free|not allocated"):
        pool.decref(p)
    with pytest.raises(RuntimeError, match="double free|not allocated"):
        pool.free(p)
    with pytest.raises(RuntimeError):
        pool.incref(p)                   # can't re-share a freed page
    assert pool.in_use == 0 and pool.total_refs == 0


@given(ops=st.lists(st.integers(0, 2), max_size=60))
@settings(max_examples=50, deadline=None)
def test_property_pool_refcount_conservation(ops):
    """Random alloc/incref/decref interleavings: conservation holds and
    a page is never freed while a mapping remains (mirror refcounts)."""
    pool = PagePool(6, 2)
    mirror = {}                          # page -> expected refcount
    for op in ops:
        if op == 0 and pool.available:
            p = pool.alloc()
            assert p not in mirror, "page handed out twice"
            assert p != NULL_PAGE
            mirror[p] = 1
        elif op == 1 and mirror:
            p = min(mirror)
            pool.incref(p)
            mirror[p] += 1
        elif op == 2 and mirror:
            p = max(mirror)
            freed = pool.decref(p)
            mirror[p] -= 1
            assert freed == (mirror[p] == 0)
            if not mirror[p]:
                del mirror[p]
        assert pool.total_refs == sum(mirror.values())
        assert pool.in_use == len(mirror)
        assert {p: pool.refcount(p) for p in mirror} == mirror


# ------------------------------------------------------- COW split semantics

def _cached_prompt(pool, tables, prefix, prompt, slot=0):
    """Prefill ``prompt`` into ``slot``, register it, release: the trie
    keeps the full pages alive at refcount 1."""
    tables.admit(slot, len(prompt), budget_tokens=len(prompt))
    prefix.register(prompt, tables.owned_pages(slot))
    tables.release(slot)


def test_cow_split_on_partial_shared_page():
    G = 4
    pool = PagePool(8, G)
    tables = SlotPageTables(pool, n_slots=2, n_ptab=4)
    prefix = PrefixCache(pool, G)
    prompt = np.arange(8, dtype=np.int32)
    _cached_prompt(pool, tables, prefix, prompt)
    hit, pages = prefix.lookup(prompt)
    assert hit == 7                      # capped at len - 1: partial page
    tables.admit_prefix(1, pages, hit, 8, budget_tokens=12)
    p_full, p_part = pages
    assert pool.refcount(p_full) == pool.refcount(p_part) == 2
    # the partial shared page is read-only: both write guards fire
    with pytest.raises(RuntimeError, match="read-only|shared"):
        tables.assert_writable(1, hit, hit)
    with pytest.raises(RuntimeError, match="shared"):
        tables.ensure(1, hit)
    cow = tables.ensure_writable(1, hit)
    assert len(cow) == 1
    src, dst = cow[0]
    assert src == p_part and dst not in pages
    assert tables.table[1, 1] == dst and pool.refcount(dst) == 1
    assert pool.refcount(p_part) == 1    # trie's mapping only
    tables.assert_writable(1, hit, hit)  # now exclusively owned
    assert tables.ensure_writable(1, hit) == []   # idempotent
    # full shared page stays shared and guarded
    with pytest.raises(RuntimeError, match="read-only|shared"):
        tables.assert_writable(1, 0, 3)
    tables.release(1)
    assert pool.refcount(p_full) == pool.refcount(p_part) == 1
    prefix.clear()
    assert pool.in_use == 0


def test_page_aligned_hit_needs_no_cow():
    """A hit ending exactly on a page boundary leaves no partial shared
    page: first write lands on a fresh page, no COW pair."""
    G = 4
    pool = PagePool(8, G)
    tables = SlotPageTables(pool, n_slots=2, n_ptab=4)
    prefix = PrefixCache(pool, G)
    _cached_prompt(pool, tables, prefix, np.arange(8, dtype=np.int32))
    long = np.concatenate([np.arange(8), 50 + np.arange(4)]).astype(np.int32)
    hit, pages = prefix.lookup(long)
    assert hit == 8 and len(pages) == 2
    tables.admit_prefix(1, pages, hit, 12, budget_tokens=12)
    assert tables.ensure_writable(1, hit) == []
    tables.assert_writable(1, hit, 11)
    tables.release(1)
    prefix.clear()
    assert pool.in_use == 0


# ------------------------------------ missed-pages reservation (regression)

def test_reservation_counts_only_missed_pages():
    """Regression for the PR-4 worst-case formula: a cache-hit request
    whose missed pages fit must admit. Old formula: need =
    pages_for(budget) = 3 > 2 available -> permanent head-of-line block
    on a pool the request can actually be served from (1 COW replacement
    + 1 decode page)."""
    G = 4
    pool = PagePool(1 + 4, G)            # 4 allocatable pages
    tables = SlotPageTables(pool, n_slots=1, n_ptab=3)
    prefix = PrefixCache(pool, G)
    prompt = np.arange(8, dtype=np.int32)
    _cached_prompt(pool, tables, prefix, prompt)
    assert pool.available == 2           # trie holds the prompt's 2 pages
    hit, pages = prefix.lookup(prompt)
    assert hit == 7
    budget = 8 + 4                       # prompt + gen -> 3 pages worst case
    assert pool.available < tables.pages_for(budget), (
        "scenario broken: the old worst-case formula must NOT fit")
    assert tables.can_admit(budget, hit_tokens=hit), (
        "missed-pages formula must admit: 2 shared pages already "
        "allocated, COW + decode need exactly the 2 available")
    # ...and the admission really is serviceable end to end
    tables.admit_prefix(0, pages, hit, 8, budget_tokens=budget)
    assert len(tables.ensure_writable(0, hit)) == 1
    for pos in range(hit, budget):       # prefill tail + every decode write
        tables.ensure(0, pos)
        tables.assert_writable(0, pos, pos)
    assert pool.available == 0           # sized exactly
    tables.release(0)
    prefix.clear()
    assert pool.in_use == 0


def test_reservation_includes_pending_cow_page():
    """Between admit_prefix (partial hit) and ensure_writable, the COW
    replacement page is reserved — a concurrent admission cannot steal
    the last page out from under the pending split."""
    G = 4
    pool = PagePool(1 + 3, G)
    tables = SlotPageTables(pool, n_slots=2, n_ptab=3)
    prefix = PrefixCache(pool, G)
    _cached_prompt(pool, tables, prefix, np.arange(8, dtype=np.int32))
    hit, pages = prefix.lookup(np.arange(8, dtype=np.int32))
    tables.admit_prefix(0, pages, hit, 8, budget_tokens=8)
    assert tables._cow_pending[0] == 1
    assert tables.reserved_unallocated == 1    # the pending COW page
    assert not tables.can_admit(4), "last page is spoken for"
    tables.ensure_writable(0, hit)
    assert tables._cow_pending[0] == 0
    assert tables.reserved_unallocated == 0


# ----------------------------------------------------------- trie lookup

def _brute_force_hit(query, registered, G):
    """Oracle: best over registered prompts of the common prefix, capped
    at that prompt's full-page coverage (partial last pages are never
    cached) and at len(query) - 1 (one token must really prefill)."""
    cap = len(query) - 1
    best = 0
    for q in registered:
        c = 0
        for x, y in zip(query, q):
            if x != y:
                break
            c += 1
        best = max(best, min(c, (len(q) // G) * G, cap))
    return best


def _lookup_case(seed, n_register, n_query, G):
    rng = np.random.default_rng(seed)
    pool = PagePool(512, G)
    tables = SlotPageTables(pool, n_slots=1, n_ptab=64)
    prefix = PrefixCache(pool, G)
    base = rng.integers(0, 4, 3 * G).astype(np.int32)   # tiny alphabet:
    registered = []                                     # heavy overlap
    for _ in range(n_register):
        k = int(rng.integers(0, 3 * G))
        tail = rng.integers(0, 4, int(rng.integers(1, 2 * G)))
        p = np.concatenate([base[:k], tail]).astype(np.int32)
        _cached_prompt(pool, tables, prefix, p)
        registered.append([int(t) for t in p])
    for _ in range(n_query):
        k = int(rng.integers(0, 3 * G))
        tail = rng.integers(0, 4, int(rng.integers(1, 2 * G)))
        query = [int(t) for t in np.concatenate([base[:k], tail])]
        hit, pages = prefix.lookup(query)
        want = _brute_force_hit(query, registered, G)
        assert hit == want, (query, hit, want)
        assert len(pages) == -(-hit // G)
        assert all(pool.refcount(p) >= 1 for p in pages)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**16), n_register=st.integers(0, 8),
       n_query=st.integers(1, 8), G=st.sampled_from([2, 4]))
def test_property_trie_lookup_is_longest_prefix(seed, n_register, n_query,
                                                G):
    _lookup_case(seed, n_register, n_query, G)


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 4])
def test_trie_lookup_is_longest_prefix_ports(seed):
    rng = np.random.default_rng(seed + 17)
    _lookup_case(seed, int(rng.integers(1, 9)), int(rng.integers(1, 9)),
                 4 if seed % 2 else 2)


def test_trie_partial_match_picks_best_child():
    """Two cached prompts diverging mid-page: lookup must take the child
    with the longer common run, not the first inserted."""
    G = 4
    pool = PagePool(32, G)
    tables = SlotPageTables(pool, n_slots=1, n_ptab=8)
    prefix = PrefixCache(pool, G)
    a = np.asarray([1, 2, 3, 4, 5, 6, 7, 8], np.int32)
    b = np.asarray([1, 2, 3, 4, 5, 6, 9, 9], np.int32)
    _cached_prompt(pool, tables, prefix, a)
    _cached_prompt(pool, tables, prefix, b)
    hit, pages = prefix.lookup([1, 2, 3, 4, 5, 6, 9, 0])
    assert hit == 7                      # b's child matches 3, a's only 2
    assert len(pages) == 2


# ----------------------------------------------------------- LRU eviction

def test_evict_skips_referenced_and_protected_pages():
    G = 2
    pool = PagePool(32, G)
    tables = SlotPageTables(pool, n_slots=2, n_ptab=8)
    prefix = PrefixCache(pool, G)
    a = np.asarray([1, 2, 3, 4, 9], np.int32)
    b = np.asarray([5, 6, 7, 8, 9], np.int32)
    _cached_prompt(pool, tables, prefix, a)
    _cached_prompt(pool, tables, prefix, b)
    assert prefix.resident == 4
    # map a's run into a live slot: its pages are pinned (refcount 2)
    hit, pages = prefix.lookup(a)
    tables.admit_prefix(0, pages, hit, 5, budget_tokens=5)
    protect = set()
    hit_b, pages_b = prefix.lookup(b)
    protect.update(pages_b[:1])          # protect b's first page
    freed = prefix.evict(10, protect=frozenset(protect))
    assert freed == 1                    # only b's second page was free
    assert all(pool.refcount(p) >= 2 for p in pages)
    assert pool.refcount(pages_b[0]) == 1
    _check_refcounts(pool, tables, prefix, 2)
    # retire the slot: a's pages become evictable again, leaves first
    tables.release(0)
    assert prefix.evict(10) == 3
    assert prefix.resident == 0 and pool.in_use == 0


def test_evict_leaves_first_keeps_paths_contiguous():
    """LRU evicts leaf nodes only, so every surviving root-to-node path
    stays walkable — a lookup never dead-ends below a hole."""
    G = 2
    pool = PagePool(32, G)
    tables = SlotPageTables(pool, n_slots=1, n_ptab=8)
    prefix = PrefixCache(pool, G)
    p = np.asarray([1, 2, 3, 4, 5, 6, 9], np.int32)
    _cached_prompt(pool, tables, prefix, p)
    assert prefix.resident == 3
    assert prefix.evict(1) == 1
    hit, _ = prefix.lookup(p)
    assert hit == 4                      # the two inner pages survive
    for node in prefix._walk():
        parent = node.parent
        while parent is not None:        # every ancestor still present
            assert parent.key in (parent.parent.children
                                  if parent.parent is not None
                                  else prefix._root())
            parent = parent.parent
    prefix.clear()
    assert pool.in_use == 0
