"""Property tests for the serve engine's slot allocator, driven through a
deterministic stub model (next token is a pure function of the fed token
and its position, so every request's full trajectory is computable in
python without running a transformer). Invariants under random request
lengths / decode budgets / eos positions:

  - FIFO admission order is the submission order
  - no slot is double-booked; every slot returns to the free list
  - every request retires exactly once, with exactly the tokens the
    position-faithful python simulation predicts (scheduler independence:
    batching/slot reuse must not leak between requests)

Runs via tests/_hypothesis_shim: property cases when hypothesis is
installed, the seeded deterministic ports always."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_shim import given, settings, st

from repro.launch.engine import ServeEngine

_V = 64          # stub vocab


def _next_token(tok, pos):
    """Pure next-token rule: mixes token and absolute position so any
    cache-position bug (wrong slot offset, stale row) changes output."""
    return (tok * 7 + pos * 13 + 1) % _V


class _StubModel:
    """Dense-family stand-in honoring the engine's model contract:
    prefill predicts from the last prompt token at position P-1; decode
    predicts from the fed token at its (per-slot) cache position."""

    def __init__(self, cfg):
        self.cfg = cfg

    def init_cache(self, batch, max_len):
        return {"k": jnp.zeros((1, batch, max_len, 1, 1), jnp.float32),
                "v": jnp.zeros((1, batch, max_len, 1, 1), jnp.float32),
                "pos": jnp.int32(0)}

    def prefill(self, params, tokens, cache, logits_at=None):
        # honor the engine's bucketing contract: logits (and the predicted
        # next token) come from the row at ``logits_at`` — rows past it
        # are padding a real model would causally ignore
        if logits_at is None:
            logits_at = jnp.int32(tokens.shape[1] - 1)
        tok = jax.lax.dynamic_slice_in_dim(tokens, logits_at, 1, axis=1)
        pos = cache["pos"] + logits_at
        nxt = _next_token(tok[:, 0], pos)
        logits = jax.nn.one_hot(nxt, _V)[:, None, :]
        return logits, dict(cache, pos=pos + 1)

    def decode(self, params, token, cache):
        nxt = _next_token(token[:, 0], cache["pos"])   # pos: (B,) per slot
        return (jax.nn.one_hot(nxt, _V)[:, None, :],
                dict(cache, pos=cache["pos"] + 1))


_STUB = None


def _stub() -> _StubModel:
    """One shared instance so jitted_model_fns' lru_cache is hit across
    cases (hypothesis-safe: no pytest fixture inside @given)."""
    global _STUB
    if _STUB is None:
        from repro.configs import get_config
        _STUB = _StubModel(get_config("catlm_60m").smoke())
    return _STUB


def _simulate(prompt, max_new, eos_id):
    """The per-request ground truth the engine must reproduce."""
    toks = list(prompt)
    tok, pos = int(prompt[-1]), len(prompt) - 1
    for _ in range(max_new):
        tok = (tok * 7 + pos * 13 + 1) % _V
        toks.append(tok)
        pos += 1
        if tok == eos_id:
            break
    return toks


def _check_invariants(lengths, budgets, n_slots, eos_id):
    reqs = []
    rng = np.random.default_rng(hash((tuple(lengths), n_slots)) % 2**32)
    for rid, (p, g) in enumerate(zip(lengths, budgets)):
        reqs.append({"rid": rid,
                     "tokens": rng.integers(0, _V, p).astype(np.int32),
                     "max_new_tokens": g})
    max_len = max(len(r["tokens"]) + r["max_new_tokens"] for r in reqs) + 1
    engine = ServeEngine(_stub(), {}, n_slots=n_slots, max_len=max_len,
                         eos_id=eos_id)
    results = engine.run(reqs)

    # exactly-once retirement, FIFO admission
    admits = [e for e in engine.events if e[0] == "admit"]
    retires = [e for e in engine.events if e[0] == "retire"]
    assert [a[1] for a in admits] == [r["rid"] for r in reqs]
    assert sorted(r[1] for r in retires) == sorted(r["rid"] for r in reqs)
    assert sorted(results) == sorted(r["rid"] for r in reqs)

    # no double-booking; every slot freed
    occupied = set()
    for kind, _rid, slot, _step in engine.events:
        if kind == "admit":
            assert slot not in occupied, f"slot {slot} double-booked"
            occupied.add(slot)
        else:
            occupied.discard(slot)
    assert not occupied
    assert engine.idle
    assert sorted(engine._free) == list(range(n_slots))

    # scheduler independence: engine tokens == per-request simulation
    for r in reqs:
        want = _simulate(r["tokens"], r["max_new_tokens"], eos_id)
        got = results[r["rid"]].tokens.tolist()
        assert got == want, (r["rid"], got, want)


# --------------------------------------------------------------- property

@settings(max_examples=15, deadline=None)
@given(
    lens_budgets=st.lists(
        st.tuples(st.integers(1, 12), st.integers(1, 6)),
        min_size=1, max_size=10),
    n_slots=st.integers(1, 4),
    eos_id=st.integers(-1, _V - 1),
)
def test_property_slot_allocator_invariants(lens_budgets, n_slots, eos_id):
    lengths = [p for p, _ in lens_budgets]
    budgets = [g for _, g in lens_budgets]
    _check_invariants(lengths, budgets, n_slots,
                      eos_id if eos_id >= 0 else None)


# ---------------------------------------------- deterministic seeded ports

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("n_slots", [1, 3])
def test_slot_allocator_invariants_ports(seed, n_slots):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 10))
    lengths = rng.integers(1, 13, n).tolist()
    budgets = rng.integers(1, 7, n).tolist()
    # eos drawn from the small stub vocab so some requests genuinely stop
    # early and others never see it
    eos_id = int(rng.integers(0, _V)) if seed % 2 else None
    _check_invariants(lengths, budgets, n_slots, eos_id)


def test_eos_on_prefill_token_retires_without_decode():
    """A request whose very first (prefill-emitted) token is eos must
    retire before ever joining a decode batch."""
    prompt = np.asarray([3, 5], np.int32)
    first = _simulate(prompt, 1, None)[-1]
    engine = ServeEngine(_stub(), {}, n_slots=2, max_len=16,
                         eos_id=first)
    out = engine.run([{"rid": 0, "tokens": prompt, "max_new_tokens": 5}])
    assert out[0].tokens.tolist() == [3, 5, first]
    assert engine.metrics["decode_steps"] == 0
