"""Tensor-parallel W4A8 kernel paths: the K-sharded shard_map wrappers
(`ops.qmatmul_w4_tp` / `ops.qgemv_w4_tp`, psum on the contracted model
axis) must match the single-device kernels at rtol 1e-5, and
`ops.cat_transform_matmul` called inside shard_map must keep routing
packed decode shapes (M <= 8) to the GEMV kernel.

In-process cases need >= 4 local devices — they run under the CI mesh job
(XLA_FLAGS=--xla_force_host_platform_device_count=8) and skip otherwise;
the subprocess case runs everywhere (slow)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import pack_int4
from repro.kernels import ops
from repro.kernels.quant_matmul_w4 import quant_matmul_w4

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


def _inputs(m, n, k, seed):
    r = np.random.default_rng(seed)
    qx = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
    sx = jnp.asarray(r.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    zpx = jnp.asarray(r.integers(-8, 8, (m, 1)), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    return qx, sx, zpx, qw, sw


@pytest.fixture(scope="module")
def tp_mesh():
    from repro.distributed.compat import make_mesh
    return make_mesh((4,), ("model",))


# ------------------------------------------------------- sharded kernels

@needs_mesh
@pytest.mark.parametrize("m,n,k", [(5, 48, 64), (16, 33, 128), (1, 7, 96)])
def test_qmatmul_w4_tp_matches_single_device(tp_mesh, m, n, k):
    qx, sx, zpx, qw, sw = _inputs(m, n, k, seed=m + n + k)
    qwp = pack_int4(qw, axis=0)
    want = quant_matmul_w4(qx, sx, zpx, qwp, sw, interpret=True)
    got = ops.qmatmul_w4_tp(qx, sx, zpx, qwp, sw, mesh=tp_mesh, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@needs_mesh
@pytest.mark.parametrize("m", [1, 3, 8])
def test_qgemv_w4_tp_matches_single_device(tp_mesh, m):
    qx, sx, zpx, qw, sw = _inputs(m, 24, 64, seed=100 + m)
    qwp = pack_int4(qw, axis=0)
    want = quant_matmul_w4(qx, sx, zpx, qwp, sw, interpret=True)
    got = ops.qgemv_w4_tp(qx, sx, zpx, qwp, sw, mesh=tp_mesh, block_k=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@needs_mesh
def test_tp_kernels_reject_unsplittable_k(tp_mesh):
    """K must split into whole packed bytes per shard: 36 / (2*4) != int."""
    qx, sx, zpx, qw, sw = _inputs(2, 8, 36, seed=0)
    with pytest.raises(AssertionError):
        ops.qmatmul_w4_tp(qx, sx, zpx, pack_int4(qw, axis=0), sw,
                          mesh=tp_mesh)


# ------------------------------------- cat_transform_matmul under a mesh

def _cat_inputs(m, d, d_out, seed):
    from repro.core.hadamard import hadamard_factors
    r = np.random.default_rng(seed)
    ha, hb = map(lambda h: jnp.asarray(h, jnp.float32), hadamard_factors(d))
    sign = jnp.asarray(r.choice([-1.0, 1.0], d), jnp.float32)
    x = jnp.asarray(r.standard_normal((m, d)), jnp.float32)
    blocks = jnp.asarray(r.standard_normal((d // 16, 16, 16)) / 4,
                         jnp.float32)
    qw = jnp.asarray(r.integers(-8, 8, (d, d_out)), jnp.int8)
    sw = jnp.asarray(r.uniform(0.01, 0.05, (1, d_out)), jnp.float32)
    return x, blocks, ha, hb, sign, qw, sw


def _cat_tp(mesh, x, blocks, ha, hb, sign, qwp, sw):
    """cat_transform_matmul from INSIDE shard_map: x replicated (the
    transform + per-token act scales span the full d), packed weight
    K-sharded, partial outputs psummed over 'model'."""
    from jax.sharding import PartitionSpec as P

    from repro.distributed.compat import shard_map

    def body(x, blocks, ha, hb, sign, qw, sw):
        return ops.cat_transform_matmul(x, blocks, ha, hb, sign, qw, sw,
                                        act_bits=8, packed_int4=True,
                                        axis_name="model", interpret=True)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(None, None), P(), P(), P(), P(),
                  P("model", None), P(None, None)),
        out_specs=P(None, None), check_vma=False,
    )(x, blocks, ha, hb, sign, qwp, sw)


@needs_mesh
@pytest.mark.parametrize("m,routed", [(1, "qgemv_w4"), (8, "qgemv_w4"),
                                      (9, "qmatmul_w4")])
def test_cat_transform_dispatch_under_mesh(tp_mesh, monkeypatch, m, routed):
    """Packed decode shapes (M <= 8) must still route to the GEMV kernel
    inside shard_map — K sharding never changes M — and match the
    single-device packed path at rtol 1e-5."""
    x, blocks, ha, hb, sign, qw, sw = _cat_inputs(m, 64, 40, seed=7 * m)
    qwp = pack_int4(qw, axis=0)
    want = ops.cat_transform_matmul(x, blocks, ha, hb, sign, qwp, sw,
                                    act_bits=8, packed_int4=True,
                                    interpret=True)
    calls = []
    for name in ("qgemv_w4", "qmatmul_w4"):
        real = getattr(ops, name)
        monkeypatch.setattr(
            ops, name,
            lambda *a, _real=real, _n=name, **k: calls.append(_n)
            or _real(*a, **k))
    got = _cat_tp(tp_mesh, x, blocks, ha, hb, sign, qwp, sw)
    assert routed in calls and len(set(calls)) == 1, calls
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------- dense_tp replicated fallback

@needs_mesh
def test_dense_tp_replicated_row_weight_fallback(tp_mesh):
    """When tp_param_specs left a row weight replicated (K doesn't divide
    the axis), dense_tp must compute the contraction whole instead of
    slicing + psumming tp identical copies (which would scale the output
    by tp)."""
    from jax.sharding import PartitionSpec as P

    from repro.core import transforms as T
    from repro.core.qlinear import QLinear, dense, dense_tp
    from repro.core.quantizers import pack_int4
    from repro.distributed.compat import shard_map

    r = np.random.default_rng(5)
    k, n = 52, 16        # 26 packed rows: not divisible by tp=4
    codes = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
    p = QLinear(pack_int4(codes, axis=0),
                jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32),
                T.Scale(jnp.ones((k,), jnp.float32)), act_bits=0, w_bits=4,
                d_in=k)
    x = jnp.asarray(r.standard_normal((3, k)), jnp.float32)
    want = dense(p, x)

    def body(xl, pl):
        return dense_tp(pl, xl, "model")

    pl_specs = QLinear(P(None, None), P(None, None), T.Scale(P()),
                       act_bits=0, w_bits=4, d_in=k)
    got = shard_map(body, mesh=tp_mesh,
                    in_specs=(P(None, "model"), pl_specs),
                    out_specs=P(None, None), check_vma=False)(x, p)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# ------------------------------------------------- subprocess (any host)

@pytest.mark.slow
def test_tp_kernels_subprocess():
    """Same coverage on a forced-host mesh so plain tier-1 runs it."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax.numpy as jnp
        from repro.core.quantizers import pack_int4
        from repro.distributed.compat import make_mesh
        from repro.kernels import ops
        from repro.kernels.quant_matmul_w4 import quant_matmul_w4
        r = np.random.default_rng(0)
        m, k, n = 5, 64, 48
        qx = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
        qw = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
        sx = jnp.asarray(r.uniform(0.01, 0.1, (m, 1)), jnp.float32)
        zpx = jnp.asarray(r.integers(-8, 8, (m, 1)), jnp.float32)
        sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
        qwp = pack_int4(qw, axis=0)
        mesh = make_mesh((4,), ("model",))
        want = quant_matmul_w4(qx, sx, zpx, qwp, sw, interpret=True)
        for fn in (ops.qmatmul_w4_tp, ops.qgemv_w4_tp):
            got = fn(qx, sx, zpx, qwp, sw, mesh=mesh, block_k=16)
            np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                       rtol=1e-5, atol=1e-5)
        print("tp-kernels-ok")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=300,
                       env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "tp-kernels-ok" in r.stdout
