"""Int4-packed quantization path: pack/unpack round trips, the W4A8
Pallas kernel vs the pure-jnp oracle, awkward shapes, QLinear dispatch
equivalence, and packed-checkpoint save/restore."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.quantizers import pack_int4, unpack_int4
from repro.kernels import ref
from repro.kernels.quant_matmul_w4 import quant_matmul_w4


def _rng(seed=0):
    return np.random.default_rng(seed)


# ------------------------------------------------------------ pack/unpack --

def test_roundtrip_exact_all_16_nibbles():
    q = jnp.asarray(np.arange(-8, 8, dtype=np.int8).reshape(16, 1))
    p = pack_int4(q, axis=0)
    assert p.shape == (8, 1) and p.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(unpack_int4(p, 16, axis=0)),
                                  np.asarray(q))


@pytest.mark.parametrize("shape,axis", [((64, 32), 0), ((8, 33, 16), -2),
                                        ((7, 5), 0), ((2, 9, 4), 1)])
def test_roundtrip_random_shapes(shape, axis):
    q = jnp.asarray(_rng(sum(shape)).integers(-8, 8, shape), jnp.int8)
    p = pack_int4(q, axis=axis)
    n = shape[axis]
    assert p.shape[axis] == (n + 1) // 2   # bytes halved (rounded up)
    np.testing.assert_array_equal(np.asarray(unpack_int4(p, n, axis=axis)),
                                  np.asarray(q))


def test_nibble_layout_even_low_odd_high():
    # byte = (q[2i] & 0xF) | (q[2i+1] << 4), documented storage contract
    q = jnp.asarray([[-8], [7]], jnp.int8)
    p = np.asarray(pack_int4(q, axis=0)).astype(np.uint8)
    assert p[0, 0] == (8 | (7 << 4))  # -8 -> 0x8 low, 7 -> 0x7 high


def test_ref_unpack_matches_quantizer_unpack():
    q = jnp.asarray(_rng(3).integers(-8, 8, (40, 24)), jnp.int8)
    p = pack_int4(q, axis=0)
    np.testing.assert_array_equal(np.asarray(ref.unpack_int4(p, 40)),
                                  np.asarray(q))


# ---------------------------------------------------------------- kernel --

def _qmm_inputs(m, n, k, seed):
    r = _rng(seed)
    qx = jnp.asarray(r.integers(-128, 128, (m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-8, 8, (k, n)), jnp.int8)
    sx = jnp.asarray(r.uniform(0.01, 0.1, (m, 1)), jnp.float32)
    zpx = jnp.asarray(r.integers(-8, 8, (m, 1)), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 0.1, (1, n)), jnp.float32)
    return qx, sx, zpx, qw, sw


@pytest.mark.parametrize("mnk", [(8, 16, 32), (100, 96, 64),
                                 (256, 384, 512), (33, 65, 129)])
def test_quant_matmul_w4_matches_ref(mnk):
    m, n, k = mnk
    qx, sx, zpx, qw, sw = _qmm_inputs(m, n, k, m * n)
    qwp = pack_int4(qw, axis=0)
    got = quant_matmul_w4(qx, sx, zpx, qwp, sw, block_m=32, block_n=32,
                          block_k=32, interpret=True)
    want = ref.quant_matmul_w4(qx, sx, zpx, qwp, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_w4_kernel_equals_int8_kernel_on_same_codes():
    """Packing is storage only: W4 kernel == int8 kernel on identical codes."""
    from repro.kernels.quant_matmul import quant_matmul
    qx, sx, zpx, qw, sw = _qmm_inputs(24, 36, 48, 5)
    got4 = quant_matmul_w4(qx, sx, zpx, pack_int4(qw, axis=0), sw,
                           block_m=8, block_n=16, block_k=16, interpret=True)
    got8 = quant_matmul(qx, sx, zpx, qw, sw, block_m=8, block_n=16,
                        block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(got4), np.asarray(got8),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("k", [3, 7, 127])
def test_odd_k_and_non_multiple_blocks(k):
    qx, sx, zpx, qw, sw = _qmm_inputs(11, 13, k, k)
    qwp = pack_int4(qw, axis=0)
    want = ref.quant_matmul(qx, sx, zpx, qw, sw)
    got = quant_matmul_w4(qx, sx, zpx, qwp, sw, block_m=8, block_n=8,
                          block_k=10, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ops_wrapper_and_fused_path():
    from repro.core.hadamard import hadamard_factors
    from repro.kernels import ops
    r = _rng(21)
    d, d_out, toks, kb = 128, 96, 18, 32
    ha, hb = map(lambda h: jnp.asarray(h, jnp.float32), hadamard_factors(d))
    sign = jnp.asarray(r.choice([-1.0, 1.0], d), jnp.float32)
    x = jnp.asarray(r.standard_normal((toks, d)), jnp.float32)
    blocks = jnp.asarray(r.standard_normal((d // kb, kb, kb)) / np.sqrt(kb),
                         jnp.float32)
    qw = jnp.asarray(r.integers(-8, 8, (d, d_out)), jnp.int8)
    qwp = pack_int4(qw, axis=0)
    sw = jnp.asarray(r.uniform(0.01, 0.05, (1, d_out)), jnp.float32)
    y8 = ops.cat_transform_matmul(x, blocks, ha, hb, sign, qw, sw,
                                  act_bits=4, interpret=True)
    y4 = ops.cat_transform_matmul(x, blocks, ha, hb, sign, qwp, sw,
                                  act_bits=4, packed_int4=True,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(y4), np.asarray(y8),
                               rtol=1e-5, atol=1e-5)


# ------------------------------------------------- QLinear + checkpoint --

def test_qlinear_packed_dense_matches_unpacked():
    from repro.core.qlinear import QLinear, dense, num_weight_bytes
    from repro.core import transforms as T
    r = _rng(9)
    d_in, d_out = 64, 48
    codes = jnp.asarray(r.integers(-8, 8, (d_in, d_out)), jnp.int8)
    scale = jnp.asarray(r.uniform(0.01, 0.1, (1, d_out)), jnp.float32)
    x = jnp.asarray(r.standard_normal((5, d_in)), jnp.float32)
    flat = QLinear(codes, scale, T.Identity(), act_bits=0, w_bits=4)
    packed = QLinear(pack_int4(codes, axis=-2), scale, T.Identity(),
                     act_bits=0, w_bits=4, d_in=d_in)
    np.testing.assert_array_equal(np.asarray(dense(packed, x)),
                                  np.asarray(dense(flat, x)))
    assert num_weight_bytes(packed) < num_weight_bytes(flat)


@pytest.mark.slow
def test_pipeline_packs_int4_and_preserves_logits(tiny_cfg, tiny_model,
                                                  tiny_params, tiny_calib):
    from repro.core.pipeline import QuantizeConfig, quantize_model
    from repro.core.qlinear import QLinear, unpacked_qweight
    from repro.data import make_batch
    qc = QuantizeConfig(w_bits=4, a_bits=4, transform="cat", cat_block=16)
    qp = quantize_model(tiny_model, tiny_params, qc, tiny_calib)
    qf = quantize_model(tiny_model, tiny_params,
                        __import__("dataclasses").replace(qc, pack_int4=False),
                        tiny_calib)
    lp = [l for l in jax.tree.leaves(
        qp, is_leaf=lambda x: isinstance(x, QLinear)) if isinstance(l, QLinear)]
    lf = [l for l in jax.tree.leaves(
        qf, is_leaf=lambda x: isinstance(x, QLinear)) if isinstance(l, QLinear)]
    assert lp and all(l.packed and l.w_bits == 4 for l in lp)
    # packed codes unpack to exactly the flat codes; buffers are ~half size
    for a, b in zip(lp, lf):
        np.testing.assert_array_equal(np.asarray(unpacked_qweight(a)),
                                      np.asarray(b.qweight))
        assert a.qweight.size * 2 >= b.qweight.size
    toks = jnp.asarray(make_batch(tiny_cfg, 16, 2, seed=4)["tokens"])
    l1, _ = tiny_model.prefill(qp, toks, tiny_model.init_cache(2, 24))
    l2, _ = tiny_model.prefill(qf, toks, tiny_model.init_cache(2, 24))
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


@pytest.mark.slow
def test_packed_checkpoint_roundtrip(tmp_path, tiny_model, tiny_quantized):
    import json
    import os
    from repro import checkpoint as ck
    ck.save(str(tmp_path), 1, tiny_quantized, meta={"quant": "w4a4-cat"})
    man = json.load(open(os.path.join(str(tmp_path), "step_00000001",
                                      "manifest.json")))
    assert man["meta"]["packed_int4"] is True
    assert man["meta"]["packed_int4_layers"]
    out = ck.restore(str(tmp_path), None, tiny_quantized)
    for a, b in zip(jax.tree.leaves(tiny_quantized),
                    jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_serve_weight_memory_report():
    from repro.core import transforms as T
    from repro.core.qlinear import QLinear
    from repro.launch.serve import weight_memory_report
    r = _rng(13)
    codes = jnp.asarray(r.integers(-8, 8, (32, 16)), jnp.int8)
    scale = jnp.ones((1, 16), jnp.float32)
    params = {"a": QLinear(pack_int4(codes, axis=-2), scale, T.Identity(),
                           act_bits=4, w_bits=4, d_in=32),
              "b": jnp.zeros((8, 8), jnp.float32)}
    rep = weight_memory_report(params)
    assert rep == {"qlinear_layers": 1,
                   "weight_bytes": 16 * 16 + 16 * 4,
                   "packed_int4": True}
