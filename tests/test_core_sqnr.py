"""Tests for the SQNR / Concentration / Alignment framework (paper §2).

These validate the paper's *claims*:
  - Theorem 2.4 approximation tracks measured SQNR (Fig. 2)
  - alignment is rotation-invariant (eq. 4)
  - +1 bit ≈ +6 dB (§2.1)
  - optimal alignment bound (eq. 9) upper-bounds any invertible transform
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.quantizers import act_spec, weight_spec


def _layer(seed, n=512, d_in=128, d_out=96, outliers=True):
    rng = np.random.default_rng(seed)
    # correlated activations with heavy-tailed channels (LLM-like)
    mix = rng.standard_normal((d_in, d_in)) / np.sqrt(d_in)
    x = rng.standard_normal((n, d_in)) @ mix
    if outliers:
        hot = rng.choice(d_in, size=3, replace=False)
        x[:, hot] *= 20.0
    w = rng.standard_normal((d_out, d_in)) / np.sqrt(d_in)
    return jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32)


def test_theorem_2_4_tracks_measured_sqnr():
    """Fig. 2: approximation within a few dB for 5-50 dB layers."""
    for bits in [(4, 4), (4, 8), (8, 8)]:
        bw, bx = bits
        for seed in range(5):
            w, x = _layer(seed)
            wspec, xspec = weight_spec(bw, range_p=None), act_spec(bx)
            meas = float(S.db(S.sqnr_quantized_layer(w, x, wspec, xspec)))
            appr = float(S.db(S.sqnr_approx_joint(w, x, wspec, xspec)))
            if 5.0 < meas < 50.0:
                assert abs(meas - appr) < 3.0, (bits, seed, meas, appr)


def test_lemma_2_1_parallel_combination():
    w, x = _layer(0)
    wspec, xspec = weight_spec(4, range_p=None), act_spec(4)
    joint = S.sqnr_quantized_layer(w, x, wspec, xspec)
    combo = S.parallel(S.sqnr_act_only(w, x, xspec), S.sqnr_weight_only(w, x, wspec))
    assert abs(float(S.db(joint)) - float(S.db(combo))) < 1.5


def test_alignment_rotation_invariant():
    """Eq. 4: A(Rx, WRᵀ) = A(x, W) for any orthogonal R."""
    w, x = _layer(1)
    rng = np.random.default_rng(2)
    rot = T.make_rotation(x.shape[1], rng)
    a0 = float(S.alignment(w, x))
    xr = T.apply(rot, x)
    wr = T.fuse_weight(rot, w)
    a1 = float(S.alignment(wr, xr))
    np.testing.assert_allclose(a0, a1, rtol=1e-4)


def test_alignment_hadamard_invariant():
    w, x = _layer(3)
    had = T.make_hadamard(x.shape[1], np.random.default_rng(0))
    a0 = float(S.alignment(w, x))
    a1 = float(S.alignment(T.fuse_weight(had, w), T.apply(had, x)))
    np.testing.assert_allclose(a0, a1, rtol=1e-4)


def test_six_db_per_bit():
    """§2.1: each extra (joint) bit adds ≈6 dB."""
    w, x = _layer(4, outliers=False)
    dbs = []
    for b in (4, 5, 6, 7, 8):
        dbs.append(float(S.db(S.sqnr_quantized_layer(
            w, x, weight_spec(b, range_p=None), act_spec(b)))))
    deltas = np.diff(dbs)
    assert np.all(deltas > 4.0) and np.all(deltas < 8.0), dbs


def test_alignment_bounded_by_optimum():
    from repro.core import cat as C
    w, x = _layer(5)
    sigma_x = jnp.asarray(np.asarray(x, np.float64).T @ np.asarray(x, np.float64)
                          / x.shape[0], jnp.float32)
    a_star = float(S.alignment_optimal(w, sigma_x))
    a_now = float(S.alignment_from_cov(w, sigma_x))
    assert a_now <= a_star * (1 + 1e-3)
    # random invertible transforms cannot beat the bound either
    rng = np.random.default_rng(6)
    for _ in range(3):
        m = jnp.asarray(rng.standard_normal((x.shape[1], x.shape[1]))
                        + 3 * np.eye(x.shape[1]), jnp.float32)
        wt = w @ jnp.linalg.inv(m)
        st_ = m @ sigma_x @ m.T
        assert float(S.alignment_from_cov(wt, st_)) <= a_star * (1 + 1e-3)


def test_alignment_from_cov_matches_empirical():
    w, x = _layer(7)
    sigma_x = x.T @ x / x.shape[0]
    np.testing.assert_allclose(float(S.alignment(w, x)),
                               float(S.alignment_from_cov(w, sigma_x)), rtol=1e-3)


def test_concentration_extremes():
    """Collapsed distribution -> C large; single non-zero value -> sym C=1/4."""
    spec = act_spec(4)
    x_spike = jnp.zeros((4, 64)).at[:, 0].set(1.0)
    sym = S.concentration_act(x_spike, weight_spec(4, range_p=None).__class__(
        bits=4, symmetric=True, per="token"))
    np.testing.assert_allclose(float(sym), 0.25, rtol=1e-5)


def _check_scale_invariance(seed):
    w, x = _layer(seed)
    spec = act_spec(4)
    c1 = float(S.concentration_act(x, spec))
    c2 = float(S.concentration_act(x * 37.5, spec))
    np.testing.assert_allclose(c1, c2, rtol=1e-4)
    a1 = float(S.alignment(w, x))
    a2 = float(S.alignment(w * 0.01, x * 100.0))
    np.testing.assert_allclose(a1, a2, rtol=1e-3)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000))
def test_property_concentration_scale_invariant(seed):
    _check_scale_invariance(seed)


# Deterministic ports — run without hypothesis.
@pytest.mark.parametrize("seed", [0, 17, 256, 4097])
def test_concentration_scale_invariant_seeded(seed):
    _check_scale_invariance(seed)


@pytest.mark.parametrize("bw,bx,seed", [(4, 4, 0), (4, 8, 1), (8, 8, 2)])
def test_sqnr_decomposition_tracks_measured_seeded(bw, bx, seed):
    """Theorem 2.4 port: the C·A decomposition approximates measured SQNR
    within a few dB on correlated, outlier-heavy layers."""
    w, x = _layer(seed)
    wspec, xspec = weight_spec(bw, range_p=None), act_spec(bx)
    meas = float(S.db(S.sqnr_quantized_layer(w, x, wspec, xspec)))
    appr = float(S.db(S.sqnr_approx_joint(w, x, wspec, xspec)))
    if 5.0 < meas < 50.0:
        assert abs(meas - appr) < 3.0, (bw, bx, seed, meas, appr)
