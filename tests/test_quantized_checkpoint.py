"""Quantized-model checkpointing + serving round trips: QLinear pytrees
(int8 codes + scales + transform leaves) survive save/restore bit-exactly,
and the restored model serves identical logits."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ck
from repro.configs import get_config
from repro.core.pipeline import QuantizeConfig, quantize_model
from repro.data import calibration_batches, make_batch
from repro.models import build


@pytest.mark.slow
def test_qlinear_checkpoint_roundtrip(tmp_path):
    cfg = get_config("catlm_60m").smoke()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat", cat_block=16)
    qparams = quantize_model(model, params, qcfg,
                             calibration_batches(cfg, n_seqs=4, seq_len=32,
                                                 batch=2))
    ck.save(str(tmp_path), 1, qparams, meta={"quant": "w4a4-cat"})
    out = ck.restore(str(tmp_path), None, qparams)
    rq = out["params"]

    # bit-exact codes + scales
    a = jax.tree.leaves(qparams)
    b = jax.tree.leaves(rq)
    assert len(a) == len(b)
    for la, lb in zip(a, b):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    # identical serving logits
    toks = jnp.asarray(make_batch(cfg, 16, 2, seed=4)["tokens"])
    c1 = model.init_cache(2, 24)
    c2 = model.init_cache(2, 24)
    l1, _ = model.prefill(qparams, toks, c1)
    l2, _ = model.prefill(rq, toks, c2)
    np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))


def test_act_sharding_noop_without_mesh():
    from repro.distributed.act_sharding import constrain_batch, constrain_seq
    x = jnp.ones((4, 8, 16))
    assert constrain_seq(x) is x
    assert constrain_batch(x) is x


def test_exact_cost_mode_preserves_numerics():
    """Unrolled scans are a lowering detail — results must be identical."""
    from repro.models.flags import exact_cost_mode
    cfg = get_config("catlm_60m").smoke()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(1))
    batch = {k: jnp.asarray(v) for k, v in make_batch(cfg, 32, 2,
                                                      seed=5).items()}
    l0, _ = model.loss(params, batch)
    with exact_cost_mode():
        l1, _ = model.loss(params, batch)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
