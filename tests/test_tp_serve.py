"""Tensor-parallel serving: ServeEngine on a ("data", "model") device
mesh — sharded int4-packed weights, sharded (quantized) KV cache — must
drain the seeded mixed-prompt workload with **token-identical** output to
the single-device engine for fp, int8-KV, and int4-packed configs (which
is itself token-identical to solo greedy_generate, PR 2's contract).

In-process cases need >= 4 local devices — they run under the CI mesh job
(XLA_FLAGS=--xla_force_host_platform_device_count=8) and skip otherwise;
the subprocess case runs everywhere (slow)."""
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.data import request_workload
from repro.launch.engine import ServeEngine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs >= 4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

GEN = 5
MAX_LEN = 14 + GEN + 8


@pytest.fixture(scope="module")
def mesh():
    from repro.distributed.compat import make_mesh
    return make_mesh((1, 4), ("data", "model"))


@pytest.fixture(scope="module")
def mha_cfg():
    """tp=4-friendly smoke config: every head count divides the mesh
    (the GQA smoke default has n_kv_heads=2, which tp=4 must reject —
    see test_mesh_rejects_unsplittable_heads)."""
    from repro.configs import get_config
    return get_config("catlm_60m").smoke().scaled(n_kv_heads=4)


@pytest.fixture(scope="module")
def mha_params(mha_cfg):
    from repro.models import build
    return build(mha_cfg).init(jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def mha_quantized(mha_cfg, mha_params):
    from repro.core.pipeline import QuantizeConfig, quantize_model
    from repro.data import calibration_batches
    from repro.models import build
    qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat", cat_block=16)
    return quantize_model(build(mha_cfg), mha_params, qcfg,
                          calibration_batches(mha_cfg, n_seqs=2,
                                              seq_len=16, batch=2))


def _drain_both(cfg, params, mesh, n_requests=6, n_slots=3, **mesh_kw):
    from repro.models import build
    model = build(cfg)
    reqs = request_workload(cfg, n_requests, gen=GEN, lengths=(6, 10, 14),
                            seed=3)
    solo = ServeEngine(model, params, n_slots=n_slots,
                       max_len=MAX_LEN).run(reqs)
    eng = ServeEngine(model, params, n_slots=n_slots, max_len=MAX_LEN,
                      mesh=mesh, **mesh_kw)
    meshed = eng.run(reqs)
    return reqs, solo, meshed, eng


def _assert_identical(reqs, solo, meshed):
    for r in reqs:
        np.testing.assert_array_equal(
            meshed[r["rid"]].tokens, solo[r["rid"]].tokens,
            err_msg=f"rid={r['rid']}")


# ---------------------------------------------------------- token identity

@needs_mesh
def test_mesh_engine_fp_token_identical(mha_cfg, mha_params, mesh):
    reqs, solo, meshed, eng = _drain_both(mha_cfg, mha_params, mesh)
    assert not eng.quantized_kv
    _assert_identical(reqs, solo, meshed)
    assert eng.summary()["mesh"] == {"data": 1, "model": 4}


@needs_mesh
def test_mesh_engine_int8_kv_token_identical(mha_cfg, mha_params, mesh):
    cfg = mha_cfg.scaled(kv_quant_bits=8)
    reqs, solo, meshed, eng = _drain_both(cfg, mha_params, mesh)
    assert eng.quantized_kv
    _assert_identical(reqs, solo, meshed)


@needs_mesh
def test_mesh_engine_w4_packed_token_identical(mha_cfg, mha_quantized,
                                               mesh):
    """The headline case: int4-packed weights + int8 KV cache, sharded."""
    from repro.core.qlinear import iter_qlinear
    assert any(l.packed for _, l in iter_qlinear(mha_quantized))
    cfg = mha_cfg.scaled(kv_quant_bits=8)
    reqs, solo, meshed, eng = _drain_both(cfg, mha_quantized, mesh)
    assert eng.quantized_kv
    _assert_identical(reqs, solo, meshed)


@needs_mesh
def test_mesh_engine_psum_mode_agrees(mha_cfg, mha_quantized, mesh):
    """True row-parallel (psum) mode is rtol-level, not bitwise: the
    drained workload must still produce near-identical trajectories
    (greedy tokens only flip on bf16-ulp logit ties)."""
    cfg = mha_cfg.scaled(kv_quant_bits=8)
    reqs, solo, meshed, _ = _drain_both(cfg, mha_quantized, mesh,
                                        n_requests=4, tp_mode="psum")
    agree = np.mean([
        float(np.mean(meshed[r["rid"]].tokens == solo[r["rid"]].tokens))
        for r in reqs])
    assert agree >= 0.9, agree


@needs_mesh
def test_mesh_engine_untied_embeddings_token_identical(mesh):
    """tie_embeddings=False serves through a separate unembed, which must
    stay replicated (vocab-sharded logits under a replicated out_spec
    with check_vma=False would silently decode from a vocab slice)."""
    from repro.configs import get_config
    from repro.models import build
    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4,
                                                 tie_embeddings=False,
                                                 kv_quant_bits=8)
    params = build(cfg).init(jax.random.PRNGKey(2))
    reqs, solo, meshed, _ = _drain_both(cfg, params, mesh, n_requests=4)
    _assert_identical(reqs, solo, meshed)


@needs_mesh
def test_mesh_engine_dp_tp_token_identical(mha_params):
    """(2, 2) mesh: the decode batch (slot axis) and the per-slot pos
    vector shard over 'data' while heads shard over 'model' — still
    token-identical to single device."""
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4,
                                                 kv_quant_bits=8)
    mesh22 = make_mesh((2, 2), ("data", "model"))
    reqs, solo, meshed, eng = _drain_both(cfg, mha_params, mesh22,
                                          n_requests=4, n_slots=2)
    _assert_identical(reqs, solo, meshed)
    assert eng.summary()["mesh"] == {"data": 2, "model": 2}


# ------------------------------------------------------------- validation

@needs_mesh
def test_mesh_rejects_unsplittable_heads(mesh):
    """GQA smoke default (n_kv_heads=2) cannot split whole heads over
    tp=4 — the engine must fail loudly at construction."""
    from repro.configs import get_config
    from repro.models import build
    cfg = get_config("catlm_60m").smoke()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="n_kv_heads"):
        ServeEngine(model, params, n_slots=2, max_len=MAX_LEN, mesh=mesh)


@needs_mesh
def test_mesh_rejects_moe(mesh):
    from repro.configs import get_config
    from repro.models import build
    cfg = get_config("granite_moe_1b_a400m").smoke()
    model = build(cfg)
    with pytest.raises(NotImplementedError):
        ServeEngine(model, None, n_slots=1, max_len=16, mesh=mesh)


# ------------------------------------------------- subprocess (any host)

@pytest.mark.slow
def test_mesh_engine_subprocess_token_identity():
    """fp + int4-packed mesh-vs-solo equality on a forced-host 4-device
    tp mesh, runnable from the default single-device tier-1 suite."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax
        from repro.configs import get_config
        from repro.core.pipeline import QuantizeConfig, quantize_model
        from repro.data import calibration_batches, request_workload
        from repro.distributed.compat import make_mesh
        from repro.launch.engine import ServeEngine
        from repro.models import build

        mesh = make_mesh((1, 4), ("data", "model"))
        cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4)
        model = build(cfg)
        params = model.init(jax.random.PRNGKey(0))
        qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat",
                              cat_block=16)
        qparams = quantize_model(model, params, qcfg,
                                 calibration_batches(cfg, n_seqs=2,
                                                     seq_len=16, batch=2))
        cfg8 = cfg.scaled(kv_quant_bits=8)
        for tag, c, p in (("fp", cfg, params),
                          ("w4", cfg8, qparams)):
            m = build(c)
            reqs = request_workload(c, 5, gen=4, lengths=(6, 10), seed=3)
            solo = ServeEngine(m, p, n_slots=2, max_len=24).run(reqs)
            meshed = ServeEngine(m, p, n_slots=2, max_len=24,
                                 mesh=mesh).run(reqs)
            for r in reqs:
                np.testing.assert_array_equal(meshed[r["rid"]].tokens,
                                              solo[r["rid"]].tokens)
            print(tag + "-ok")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=540,
                       env={**os.environ, "PYTHONPATH": os.path.abspath(SRC)})
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr}"
    assert "fp-ok" in r.stdout and "w4-ok" in r.stdout
