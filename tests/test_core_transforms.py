"""Tests for transforms + CAT construction (paper §3-4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import cat as C
from repro.core import sqnr as S
from repro.core import transforms as T
from repro.core.hadamard import hadamard_matrix
from repro.core.quantizers import act_spec, weight_spec


def _layer(seed, n=1024, d_in=128, d_out=96):
    rng = np.random.default_rng(seed)
    mix = rng.standard_normal((d_in, d_in)) / np.sqrt(d_in)
    scales = np.exp(rng.standard_normal(d_in))  # per-channel spread
    x = (rng.standard_normal((n, d_in)) @ mix) * scales
    x[:, rng.choice(d_in, 2, replace=False)] *= 15.0
    w = rng.standard_normal((d_out, d_in)) / np.sqrt(d_in)
    w *= np.exp(0.5 * rng.standard_normal(d_in))[None, :]
    return jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32)


def _sigma(x):
    x64 = np.asarray(x, np.float64)
    return jnp.asarray(x64.T @ x64 / x.shape[0], jnp.float32)


def _sigma_w(w):
    return jnp.asarray(np.asarray(w, np.float64).T @ np.asarray(w, np.float64),
                       jnp.float32)


# ----------------------------------------------------------------- fusion --

@pytest.mark.parametrize("kind", ["scale", "hadamard", "rotation", "block",
                                  "cat_full", "cat_block", "cat_block_h"])
def test_function_preservation(kind):
    """(W T⁻¹)(T x) == W x for every transform kind."""
    w, x = _layer(0)
    rng = np.random.default_rng(1)
    sw, sx = _sigma_w(w), _sigma(x)
    t = {
        "scale": T.Scale(jnp.asarray(rng.uniform(0.5, 2.0, x.shape[1]), jnp.float32)),
        "hadamard": T.make_hadamard(x.shape[1], rng),
        "rotation": T.make_rotation(x.shape[1], rng),
        "block": T.make_cat_block(sw, sx, k=32, hadamard=False),
        "cat_full": T.make_cat_full(sw, sx),
        "cat_block": T.make_cat_block(sw, sx, k=32, hadamard=False),
        "cat_block_h": T.make_cat_block(sw, sx, k=32, hadamard=True, rng=rng),
    }[kind]
    y0 = x @ w.T
    y1 = T.apply(t, x) @ T.fuse_weight(t, w).T
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1), rtol=2e-3, atol=2e-3)


def test_fuse_cov_consistent_with_apply():
    w, x = _layer(2)
    sx = _sigma(x)
    for t in (T.make_hadamard(x.shape[1], np.random.default_rng(0)),
              T.make_cat_block(_sigma_w(w), sx, k=16, hadamard=True,
                               rng=np.random.default_rng(1))):
        xt = T.apply(t, x)
        direct = _sigma(xt)
        fused = T.fuse_cov(t, sx)
        np.testing.assert_allclose(np.asarray(direct), np.asarray(fused),
                                   rtol=5e-3, atol=5e-3)


def test_hadamard_apply_equals_dense_matrix():
    d = 96  # 96 = 8 * 12 exercises the Paley path
    x = jnp.asarray(np.random.default_rng(0).standard_normal((4, d)), jnp.float32)
    t = T.make_hadamard(d, np.random.default_rng(1))
    dense = T.as_dense_matrix(t, d)
    np.testing.assert_allclose(np.asarray(T.apply(t, x)), np.asarray(x @ dense.T),
                               rtol=1e-4, atol=1e-5)
    # orthonormality
    np.testing.assert_allclose(np.asarray(dense @ dense.T), np.eye(d), atol=1e-4)


# --------------------------------------------------------------- CAT math --

def test_cat_optimal_achieves_bound():
    """A(M̂x, WM̂⁻¹) == A* = Σλ²/(Σλ)² (eq. 9)."""
    w, x = _layer(3)
    sw, sx = _sigma_w(w), _sigma(x)
    m = C.cat_optimal(sw, sx)
    wt = w @ jnp.linalg.inv(m)
    st_ = m @ sx @ m.T
    a = float(S.alignment_from_cov(wt, st_))
    a_star = float(S.alignment_optimal(w, sx))
    np.testing.assert_allclose(a, a_star, rtol=1e-3)


def test_cat_eq8_identity():
    """M̂ Σx M̂ = M̂⁻¹ Σw M̂⁻¹ = (Σx^-1/2 Σw Σx^-1/2)^1/2 (eq. 8)."""
    w, x = _layer(4, d_in=64, d_out=48)
    sw, sx = _sigma_w(w), _sigma(x)
    m = C.cat_optimal(sw, sx)
    minv = jnp.linalg.inv(m)
    lhs = m @ sx @ m
    rhs = minv @ sw @ minv
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=2e-2,
                               atol=2e-2 * float(jnp.max(jnp.abs(lhs))))
    # The balanced value is G = (Σx^{1/2} Σw Σx^{1/2})^{1/2} conjugated back:
    # M̂ Σx M̂ = Σx^{-1/2} (Σx^{1/2} Σw Σx^{1/2})^{1/2} Σx^{1/2}-similar form;
    # we verify via the trace identity Tr(M̂ Σx M̂) = Tr(G) which pins the
    # eigenvalue content (the paper's printed Σx^{-1/2} form is a typo).
    xh = C.spd_power(sx, 0.5)
    g = C.spd_power(xh @ sw @ xh, 0.5)
    np.testing.assert_allclose(float(jnp.trace(lhs)), float(jnp.trace(g)),
                               rtol=2e-2)


def test_geometric_mean_properties():
    rng = np.random.default_rng(5)
    a_ = rng.standard_normal((32, 32))
    b_ = rng.standard_normal((32, 32))
    a = jnp.asarray(a_ @ a_.T + 32 * np.eye(32), jnp.float32)
    b = jnp.asarray(b_ @ b_.T + 32 * np.eye(32), jnp.float32)
    g1 = C.geometric_mean(a, b)
    g2 = C.geometric_mean(b, a)  # symmetry
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=5e-3,
                               atol=5e-3 * float(jnp.max(jnp.abs(g1))))
    # scalar sanity: aI # bI = sqrt(ab) I
    g = C.geometric_mean(4.0 * jnp.eye(8), 9.0 * jnp.eye(8))
    np.testing.assert_allclose(np.asarray(g), 6.0 * np.eye(8), rtol=1e-4)


def test_cat_diagonal_matches_cat_optimal_on_diagonal_inputs():
    rng = np.random.default_rng(6)
    dw = jnp.asarray(np.diag(rng.uniform(0.5, 4.0, 32)), jnp.float32)
    dx = jnp.asarray(np.diag(rng.uniform(0.5, 4.0, 32)), jnp.float32)
    md = C.cat_diagonal(dw, dx)
    mo = C.cat_optimal(dw, dx)
    np.testing.assert_allclose(np.asarray(md), np.asarray(mo), rtol=1e-3, atol=1e-4)


def test_cat_block_stacked_matches_dense_blockdiag():
    w, x = _layer(7, d_in=64)
    sw, sx = _sigma_w(w), _sigma(x)
    stacked = C.cat_block_stacked(sw, sx, k=16)
    dense = C.cat_block(sw, sx, k=16)
    np.testing.assert_allclose(np.asarray(C.blocks_to_dense(stacked)),
                               np.asarray(dense), rtol=1e-4, atol=1e-5)


# ------------------------------------------------- paper's ordering claims --

def _joint_sqnr_db(w, x, t):
    wt = T.fuse_weight(t, w)
    xt = T.apply(t, x)
    return float(S.db(S.sqnr_quantized_layer(wt, xt, weight_spec(4, range_p=None),
                                             act_spec(4))))


def test_transform_sqnr_ordering():
    """CAT(block)+H ≥ Hadamard ≥ none (joint W4A4 SQNR), on outlier-heavy
    misaligned layers (paper Fig. 6)."""
    gains_h, gains_cat = [], []
    for seed in range(4):
        w, x = _layer(seed)
        sw, sx = _sigma_w(w), _sigma(x)
        base = _joint_sqnr_db(w, x, T.Identity())
        had = _joint_sqnr_db(w, x, T.make_hadamard(x.shape[1],
                                                   np.random.default_rng(seed)))
        catb = _joint_sqnr_db(w, x, T.make_cat_block(
            sw, sx, k=32, hadamard=True, rng=np.random.default_rng(seed)))
        gains_h.append(had - base)
        gains_cat.append(catb - had)
    assert np.mean(gains_h) > 0.0, gains_h       # Hadamard helps concentration
    assert np.mean(gains_cat) > 0.0, gains_cat   # CAT adds alignment on top


def test_cat_improves_alignment_hadamard_does_not():
    w, x = _layer(9)
    sw, sx = _sigma_w(w), _sigma(x)
    a0 = float(S.alignment(w, x))
    had = T.make_hadamard(x.shape[1], np.random.default_rng(0))
    a_h = float(S.alignment(T.fuse_weight(had, w), T.apply(had, x)))
    catb = T.make_cat_block(sw, sx, k=32, hadamard=True,
                            rng=np.random.default_rng(0))
    a_c = float(S.alignment(T.fuse_weight(catb, w), T.apply(catb, x)))
    np.testing.assert_allclose(a_h, a0, rtol=1e-3)   # rotation-invariance
    assert a_c > a0                                   # CAT improves alignment
    a_star = float(S.alignment_optimal(w, _sigma(x)))
    assert a_c <= a_star * (1 + 1e-3)


def test_smoothquant_balances_ranges():
    w, x = _layer(10)
    t = T.make_smoothquant(jnp.max(jnp.abs(x), 0), jnp.max(jnp.abs(w), 0))
    xt, wt = T.apply(t, x), T.fuse_weight(t, w)
    # activation outlier severity reduced
    ratio0 = float(jnp.max(jnp.abs(x)) / jnp.mean(jnp.abs(x)))
    ratio1 = float(jnp.max(jnp.abs(xt)) / jnp.mean(jnp.abs(xt)))
    assert ratio1 < ratio0
    np.testing.assert_allclose(np.asarray(x @ w.T), np.asarray(xt @ wt.T),
                               rtol=1e-4, atol=1e-4)


def _check_block_cat_function_preserving(seed, k):
    w, x = _layer(seed, n=256, d_in=64, d_out=32)
    t = T.make_cat_block(_sigma_w(w), _sigma(x), k=k, hadamard=False)
    y0 = np.asarray(x @ w.T)
    y1 = np.asarray(T.apply(t, x) @ T.fuse_weight(t, w).T)
    np.testing.assert_allclose(y0, y1, rtol=5e-3, atol=5e-3)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.sampled_from([1, 8, 16, 32, 64]))
def test_property_block_cat_function_preserving(seed, k):
    _check_block_cat_function_preserving(seed, k)


# Deterministic port — runs without hypothesis.
@pytest.mark.parametrize("seed,k", [(0, 1), (1, 8), (2, 16), (3, 32),
                                    (4, 64)])
def test_block_cat_function_preserving_seeded(seed, k):
    _check_block_cat_function_preserving(seed, k)


def test_session_fixture_transforms_function_preserving(
        hadamard_transform_128, cat_transform_128):
    """The shared session fixtures (conftest.py) are valid transforms:
    (W T⁻¹)(T x) == W x for both."""
    rng = np.random.default_rng(42)
    x = jnp.asarray(rng.standard_normal((16, 128)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((96, 128)) / np.sqrt(128),
                    jnp.float32)
    y0 = x @ w.T
    for t in (hadamard_transform_128, cat_transform_128):
        y1 = T.apply(t, x) @ T.fuse_weight(t, w).T
        np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                                   rtol=2e-3, atol=2e-3)


def test_online_flops_accounting():
    d = 128
    t = T.make_cat_block(jnp.eye(d), jnp.eye(d), k=32, hadamard=True)
    fl = T.online_flops(t, d)
    assert 0 < fl < 2 * d * d  # cheaper than a full dense transform
