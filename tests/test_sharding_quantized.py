"""Quantization-aware sharding specs: ``cache_sharding`` must treat the
quantized KV cache pytree (int8 codes + per-token scales) congruently —
name-pinned head axis, not the old shape heuristic that misreads a scale
(or short-T cache) as an SSM state — and ``tp_param_specs`` must shard
packed int4 row weights in packed units, scales with their column
weights, and transforms never."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.qlinear import QLinear
from repro.distributed.compat import abstract_mesh
from repro.distributed.sharding import (cache_sharding, tp_cache_specs,
                                        tp_param_specs)


@pytest.fixture(scope="module")
def mesh():
    return abstract_mesh((2, 2), ("data", "model"))


@pytest.fixture(scope="module")
def tp4_mesh():
    return abstract_mesh((1, 4), ("data", "model"))


def _cache_shapes(cfg, batch, max_len):
    from repro.models import build
    return jax.eval_shape(lambda: build(cfg).init_cache(batch, max_len))


# ------------------------------------------------------------ cache forms

def test_cache_sharding_fp_form(tiny_cfg, mesh):
    cache = _cache_shapes(tiny_cfg, 4, 32)
    assert set(cache) == {"k", "v", "pos"}
    sh = cache_sharding(cache, mesh)
    assert sh["k"].spec == sh["v"].spec
    assert sh["pos"].spec == P()


def test_cache_sharding_quantized_form_congruent(tiny_cfg, mesh):
    """codes and per-token scales must land on identical specs — a
    mismatch would dequantize codes against the wrong scale rows."""
    cfg = tiny_cfg.scaled(kv_quant_bits=8)
    cache = _cache_shapes(cfg, 4, 32)
    assert set(cache) == {"k", "k_scale", "v", "v_scale", "pos"}
    sh = cache_sharding(cache, mesh)
    assert sh["k"].spec == sh["k_scale"].spec
    assert sh["v"].spec == sh["v_scale"].spec
    assert sh["pos"].spec == P()
    # tiny smoke has n_kv_heads=2, model=2: heads shard on the head axis
    assert sh["k"].spec[3] == "model"


def test_cache_sharding_short_t_not_misread_as_state(tiny_cfg, mesh):
    """Adversarial shape: max_len < n_kv_heads broke the old T>KV
    heuristic (scale leaves have hd=1, so T>hd always 'looked like' a
    cache while short-T codes looked like SSM state). Names pin it."""
    cfg = tiny_cfg.scaled(n_kv_heads=4, n_heads=4, kv_quant_bits=8)
    cache = _cache_shapes(cfg, 4, 2)   # T=2 < KV=4
    sh = cache_sharding(cache, mesh)
    assert sh["k"].spec == sh["k_scale"].spec
    assert sh["k"].spec[3] == "model"  # heads, NOT the T axis
    assert sh["k"].spec[2] is None


def test_tp_cache_specs_head_axis_and_pos(tiny_cfg, tp4_mesh):
    cfg = tiny_cfg.scaled(n_kv_heads=4, kv_quant_bits=8)
    cache = _cache_shapes(cfg, 4, 32)
    specs = tp_cache_specs(cache, tp4_mesh)
    for key in ("k", "v", "k_scale", "v_scale"):
        assert specs[key] == P(None, None, None, "model", None), key
    assert specs["pos"] == P()
    # non-divisible heads replicate (never split head_dim)
    specs2 = tp_cache_specs(_cache_shapes(tiny_cfg.scaled(kv_quant_bits=8),
                                          4, 32), tp4_mesh)
    assert specs2["k"] == P(None, None, None, None, None)
    # dp axis shards the slot axis when it divides
    specs3 = tp_cache_specs(cache, abstract_mesh((2, 2), ("data", "model")),
                            dp_axis="data")
    assert specs3["k"][1] == "data"


# ------------------------------------------------------------- tp params

def _qlinear(d_in, d_out, packed, layers=2):
    from repro.core.quantizers import pack_int4
    codes = jnp.zeros((layers, d_in, d_out), jnp.int8)
    qw = pack_int4(codes, axis=-2) if packed else codes
    t = {"s": jnp.ones((d_in,))}   # smoothquant-shaped transform leaf
    return QLinear(qw, jnp.ones((layers, 1, d_out)), t, act_bits=4,
                   w_bits=4 if packed else 8, d_in=d_in if packed else 0)


def test_tp_param_specs_packed_row_shards_packed_units(tp4_mesh):
    params = {"layers": {"wo": _qlinear(128, 64, packed=True)}}
    specs = tp_param_specs(params, tp4_mesh, row_mode="psum")
    wo = specs["layers"]["wo"]
    # packed axis (128/2=64 rows) splits 4-ways in packed units
    assert wo.qweight == P(None, "model", None)
    assert wo.scale == P(None, None, None)          # row scale replicates
    assert wo.transform["s"] == P()
    # gather mode replicates the row weight entirely
    specs_g = tp_param_specs(params, tp4_mesh, row_mode="gather")
    assert specs_g["layers"]["wo"].qweight == P(None, None, None)


def test_tp_param_specs_col_shards_scale_with_weight(tp4_mesh):
    params = {"layers": {"wu": _qlinear(128, 64, packed=True)}}
    specs = tp_param_specs(params, tp4_mesh)
    wu = specs["layers"]["wu"]
    assert wu.qweight == P(None, None, "model")
    assert wu.scale == P(None, None, "model")
    assert wu.transform["s"] == P()


def test_tp_param_specs_odd_packed_k_replicates(tp4_mesh):
    """65 packed rows don't split 4-ways -> whole-byte fallback."""
    params = {"layers": {"wo": _qlinear(130, 64, packed=True)}}
    specs = tp_param_specs(params, tp4_mesh, row_mode="psum")
    assert specs["layers"]["wo"].qweight == P(None, None, None)


def test_tp_param_specs_head_boundaries_group_rule(tp4_mesh, tiny_cfg):
    """With cfg given, the attention projections shard as a GROUP: tiny
    smoke has n_heads=4 (divides tp=4) but n_kv_heads=2 (doesn't), so
    wq must replicate along with wk/wv — a head-sharded wq next to
    replicated kv projections would scramble the GQA q->kv pairing."""
    params = {"layers": {"wq": jnp.zeros((2, 128, 128)),
                         "wk": jnp.zeros((2, 128, 64)),
                         "wu": jnp.zeros((2, 128, 256))}}
    specs = tp_param_specs(params, tp4_mesh, cfg=tiny_cfg)
    assert specs["layers"]["wq"] == P(None, None, None)
    assert specs["layers"]["wk"] == P(None, None, None)
    assert specs["layers"]["wu"] == P(None, None, "model")
    free = tp_param_specs(params, tp4_mesh)   # no cfg: dim rule only
    assert free["layers"]["wk"] == P(None, None, "model")


def test_tp_param_specs_unembed_replicates(tp4_mesh):
    """unembed (and embed) stay whole: the engine's shard_map out_specs
    declare logits replicated, so a vocab-sharded unembed would silently
    emit wrong tokens for untied configs."""
    params = {"embed": jnp.zeros((512, 128)),
              "unembed": jnp.zeros((2, 128, 512))}
    specs = tp_param_specs(params, tp4_mesh)
    assert specs["embed"] == P(None, None)
    assert specs["unembed"] == P(None, None, None)


def test_tp_param_specs_spec_tree_matches_param_tree(tiny_quantized,
                                                     tp4_mesh):
    """The spec tree must flatten exactly like the (quantized) params —
    shard_map in_specs and device_put both require it."""
    specs = tp_param_specs(tiny_quantized, tp4_mesh)
    ps = jax.tree_util.tree_structure(tiny_quantized)
    ss = jax.tree_util.tree_structure(
        jax.tree.map(lambda s: 0, specs, is_leaf=lambda x: isinstance(x, P)))
    assert ps == ss
