"""Unit + property tests for repro.core.quantizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import quantizers as Q

jax.config.update("jax_enable_x64", False)


def test_symmetric_roundtrip_exact_levels():
    spec = Q.QuantSpec(bits=4, symmetric=True, per="tensor")
    # Values exactly on the grid (max|x| = qmax*scale) must be preserved.
    scale = 0.1
    grid = jnp.arange(-spec.qmax, spec.qmax + 1) * scale
    out = Q.fake_quant(grid, spec)
    np.testing.assert_allclose(out, grid, atol=1e-6)


def test_asymmetric_handles_shifted_data():
    spec_s = Q.QuantSpec(bits=4, symmetric=True, per="tensor")
    spec_a = Q.QuantSpec(bits=4, symmetric=False, per="tensor")
    x = jnp.linspace(10.0, 11.0, 256)  # strongly shifted
    err_s = jnp.mean((Q.fake_quant(x, spec_s) - x) ** 2)
    err_a = jnp.mean((Q.fake_quant(x, spec_a) - x) ** 2)
    assert err_a < err_s / 10.0  # asymmetric drastically better (paper §2.1)


def test_per_token_independent_scales():
    spec = Q.act_spec(8)
    x = jnp.stack([jnp.ones(64) * 1e-3, jnp.ones(64) * 1e3])
    out = Q.fake_quant(x, spec)
    np.testing.assert_allclose(out, x, rtol=1e-2)  # each token gets own scale


def test_per_channel_weight_scales():
    spec = Q.weight_spec(8, range_p=None)
    w = jnp.stack([jnp.linspace(-1e-3, 1e-3, 64), jnp.linspace(-1e3, 1e3, 64)])
    out = Q.fake_quant(w, spec)
    np.testing.assert_allclose(out, w, rtol=1e-1, atol=1e-5)


def test_lp_range_beats_absmax_with_outlier():
    rng = np.random.default_rng(0)
    w = rng.standard_normal((8, 512)).astype(np.float32)
    w[:, 0] *= 50.0  # heavy outlier per row
    spec_mm = Q.QuantSpec(bits=4, symmetric=True, per="channel")
    spec_lp = Q.QuantSpec(bits=4, symmetric=True, per="channel", range_p=2.4)
    err_mm = float(jnp.mean((Q.fake_quant(jnp.asarray(w), spec_mm) - w) ** 2))
    err_lp = float(jnp.mean((Q.fake_quant(jnp.asarray(w), spec_lp) - w) ** 2))
    assert err_lp < err_mm


def test_int_codes_in_range():
    spec = Q.QuantSpec(bits=4, symmetric=True, per="channel")
    w = jnp.asarray(np.random.default_rng(1).standard_normal((16, 32)), jnp.float32)
    q, scale, zp = Q.quantize(w, spec)
    assert q.dtype == jnp.int8
    assert int(q.min()) >= spec.qmin and int(q.max()) <= spec.qmax


def _check_error_bounded_by_half_step(bits, symmetric, seed):
    """|x - Q(x)| <= scale/2 for in-range values (uniform quantizer invariant)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(256) * rng.uniform(0.1, 10), jnp.float32)
    spec = Q.QuantSpec(bits=bits, symmetric=symmetric, per="tensor")
    scale, zp = Q.compute_scale_zp(x, spec)
    out = Q.fake_quant(x, spec, scale, zp)
    # zero-point rounding in asymmetric mode costs at most one extra step;
    # 1% slack covers float32 rounding at the clip boundary.
    bound = (0.5 + (0.0 if symmetric else 0.5)) * float(scale.max()) * 1.01 + 1e-6
    assert float(jnp.max(jnp.abs(out - x))) <= bound


@settings(max_examples=30, deadline=None)
@given(
    bits=st.integers(2, 8),
    symmetric=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_quant_error_bounded_by_half_step(bits, symmetric, seed):
    _check_error_bounded_by_half_step(bits, symmetric, seed)


# Deterministic ports of the properties — run without hypothesis.
@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("symmetric", [True, False])
@pytest.mark.parametrize("seed", [0, 1234])
def test_quant_error_bounded_by_half_step_seeded(bits, symmetric, seed):
    _check_error_bounded_by_half_step(bits, symmetric, seed)


def _check_more_bits_less_error(seed, bits):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((16, 64)), jnp.float32)
    spec_lo = Q.act_spec(bits)
    spec_hi = Q.act_spec(bits + 1)
    err_lo = float(jnp.mean((Q.fake_quant(x, spec_lo) - x) ** 2))
    err_hi = float(jnp.mean((Q.fake_quant(x, spec_hi) - x) ** 2))
    assert err_hi <= err_lo + 1e-12


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), bits=st.integers(3, 8))
def test_property_more_bits_less_error(seed, bits):
    _check_more_bits_less_error(seed, bits)


@pytest.mark.parametrize("seed", [0, 7, 99])
@pytest.mark.parametrize("bits", [3, 5, 7])
def test_more_bits_less_error_seeded(seed, bits):
    _check_more_bits_less_error(seed, bits)


@pytest.mark.parametrize("bits", [2, 4, 8])
@pytest.mark.parametrize("symmetric", [True, False])
def test_fake_quant_idempotent(bits, symmetric):
    """Q(Q(x)) == Q(x): fake-quant output lies exactly on the grid."""
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.standard_normal((8, 64)) * 3, jnp.float32)
    spec = Q.QuantSpec(bits=bits, symmetric=symmetric, per="tensor")
    once = Q.fake_quant(x, spec)
    twice = Q.fake_quant(once, spec)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               rtol=1e-6, atol=1e-6)


def test_quant_range_definitions():
    x = jnp.asarray([[1.0, -2.0, 3.0]])
    sym = Q.QuantSpec(bits=4, symmetric=True, per="token")
    asym = Q.QuantSpec(bits=4, symmetric=False, per="token")
    np.testing.assert_allclose(Q.quant_range(x, sym), [6.0])   # 2*max|x|
    np.testing.assert_allclose(Q.quant_range(x, asym), [5.0])  # max - min
