"""Golden engine-output fixtures: seeded greedy token sequences for the
three serving configs (fp, int8-KV, int4-packed weights + int8 KV) on the
small catlm config, checked into ``tests/golden/*.json``.

``tests/test_golden_outputs.py`` diffs live engine output against these
files, so a kernel/engine refactor that silently changes decoded tokens
fails loudly instead of drifting. When a change *intentionally* alters
numerics (new quantizer, different accumulation), regenerate with

    PYTHONPATH=src python tests/golden/regenerate.py

and commit the diff with an explanation. Fixtures are a function of the
pinned CI jax version (bf16 matmul accumulation order is backend
numerics); regenerate under the same pin CI uses (see ci.yml).
"""
from __future__ import annotations

import json
import os

import numpy as np

GOLDEN_DIR = os.path.dirname(os.path.abspath(__file__))

# (kv_quant_bits, quantize-weights) per case — small enough that all
# three run in the not-slow suite.
CASES = {
    "fp": dict(kv_bits=0, quantize=False),
    "int8_kv": dict(kv_bits=8, quantize=False),
    "w4_packed": dict(kv_bits=8, quantize=True),
}
N_REQUESTS, GEN, LENGTHS, N_SLOTS, MAX_LEN, SEED = 4, 4, (6, 10), 2, 24, 9


def build_case(name: str):
    """-> (cfg, model, params) for a golden case, fully seeded."""
    import jax

    from repro.configs import get_config
    from repro.models import build

    spec = CASES[name]
    base = get_config("catlm_60m").smoke()
    model_fp = build(base)
    params = model_fp.init(jax.random.PRNGKey(0))
    if spec["quantize"]:
        from repro.core.pipeline import QuantizeConfig, quantize_model
        from repro.data import calibration_batches
        qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat",
                              cat_block=16)
        params = quantize_model(model_fp, params, qcfg,
                                calibration_batches(base, n_seqs=2,
                                                    seq_len=16, batch=2))
    cfg = base.scaled(kv_quant_bits=spec["kv_bits"])
    return cfg, build(cfg), params


def run_case(name: str, **engine_kw) -> dict:
    """Drain the seeded workload through the engine -> {rid: [tokens]}.

    ``engine_kw`` forwards to ``ServeEngine`` so the bitwise tests can
    replay the same fixture workload through every serving configuration
    (paged, chunked, unified token-budget, mesh) — the fixtures
    themselves are always regenerated with the default (legacy, slot
    cache) engine."""
    from repro.data import request_workload
    from repro.launch.engine import ServeEngine

    cfg, model, params = build_case(name)
    reqs = request_workload(cfg, N_REQUESTS, gen=GEN, lengths=LENGTHS,
                            seed=SEED)
    engine = ServeEngine(model, params, n_slots=N_SLOTS, max_len=MAX_LEN,
                         **engine_kw)
    results = engine.run(reqs)
    return {str(r["rid"]): np.asarray(results[r["rid"]].tokens).tolist()
            for r in reqs}


def fixture_path(name: str) -> str:
    return os.path.join(GOLDEN_DIR, f"{name}.json")


def main() -> None:
    for name in CASES:
        tokens = run_case(name)
        with open(fixture_path(name), "w") as f:
            json.dump({"case": name, "arch": "catlm_60m-smoke",
                       "n_requests": N_REQUESTS, "gen": GEN,
                       "lengths": list(LENGTHS), "seed": SEED,
                       "tokens": tokens}, f, indent=1)
        print(f"wrote {fixture_path(name)}")


if __name__ == "__main__":
    main()
