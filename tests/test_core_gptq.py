"""GPTQ tests: error-compensated rounding beats RTN on the calibration
objective ||(W-Ŵ)X||²."""
import jax.numpy as jnp
import numpy as np

from repro.core import gptq as G
from repro.core.quantizers import weight_spec


def _setup(seed, n=2048, d_in=96, d_out=64):
    rng = np.random.default_rng(seed)
    mix = rng.standard_normal((d_in, d_in)) / np.sqrt(d_in)
    x = rng.standard_normal((n, d_in)) @ mix
    w = rng.standard_normal((d_out, d_in)) / np.sqrt(d_in)
    sigma = x.T @ x / n
    return (jnp.asarray(w, jnp.float32), jnp.asarray(x, jnp.float32),
            jnp.asarray(sigma, jnp.float32))


def _obj(w, what, x):
    return float(jnp.mean(jnp.sum(((x @ (w - what).T)) ** 2, axis=-1)))


def test_gptq_beats_rtn_on_calibration_objective():
    spec = weight_spec(4, range_p=None)
    wins = 0
    for seed in range(4):
        w, x, sigma = _setup(seed)
        qg, sg = G.gptq_quantize(w, sigma, spec)
        qr, sr = G.rtn_quantize(w, spec)
        eg = _obj(w, G.gptq_dequant(qg, sg), x)
        er = _obj(w, G.gptq_dequant(qr, sr), x)
        if eg < er:
            wins += 1
    assert wins >= 3, wins


def test_gptq_codes_in_range_and_shape():
    spec = weight_spec(4, range_p=None)
    w, x, sigma = _setup(0, d_in=32, d_out=16)
    q, s = G.gptq_quantize(w, sigma, spec)
    assert q.shape == w.shape and s.shape == (16, 1)
    assert int(q.min()) >= spec.qmin and int(q.max()) <= spec.qmax


def test_gptq_reduces_to_rtn_with_identity_hessian():
    """With Σ = I (uncorrelated inputs), GPTQ ~ RTN (no cross-column
    compensation gain; first column identical)."""
    spec = weight_spec(4, range_p=None)
    w, _, _ = _setup(1, d_in=24, d_out=12)
    sigma = jnp.eye(24)
    qg, sg = G.gptq_quantize(w, sigma, spec, damp=1e-6)
    qr, sr = G.rtn_quantize(w, spec)
    np.testing.assert_allclose(np.asarray(sg), np.asarray(sr), rtol=1e-6)
    # Σ=I ⇒ U diagonal ⇒ zero propagation ⇒ identical codes
    np.testing.assert_array_equal(np.asarray(qg), np.asarray(qr))


def test_gptq_high_bits_near_lossless():
    spec = weight_spec(8, range_p=None)
    w, x, sigma = _setup(2, d_in=48, d_out=24)
    q, s = G.gptq_quantize(w, sigma, spec)
    rel = _obj(w, G.gptq_dequant(q, s), x) / float(
        jnp.mean(jnp.sum((x @ w.T) ** 2, -1)))
    assert rel < 1e-3
