"""Speculative decoding in the unified step: correctness properties.

The draft/verify cycle (``--speculative k``) proposes k tokens per
decoding slot from the int4-packed draft model, then the target verifies
all k+1 positions per slot in one ragged invocation with greedy
acceptance. Because every token that ``observe`` appends is a row of the
TARGET's argmax — accepted drafts merely matched it, the first mismatch
row is the target's correction, and the bonus row is the target's too —
the output is bitwise identical to target-only greedy decode regardless
of draft quality. These tests pin that identity against the golden
fixtures across k, quant configs, prefix-cache modes, and a tp=4 mesh,
plus the KV-rewind invariant (page tables and refcounts after a
rejection match a never-drafted run) and the retirement/timing edges the
feature exposed (``_finished`` guards, device-time attribution, TTFT
monotonicity).
"""
import json

import numpy as np
import pytest

from golden import regenerate

from repro.data import request_workload
from repro.launch.engine import ServeEngine
from repro.launch.paged import PagePool, SlotPageTables
from repro.launch.scheduler import Request, SeqState, TokenBudgetScheduler
from repro.launch.serve import build_draft_model

_DRAFTS = {}


def _draft(key=None, seed=0, **overrides):
    """Module-cached int4-packed draft (model, params) — quantizing the
    draft checkpoint is the slow part, and the same draft serves every
    target config with the same architecture shape."""
    if key not in _DRAFTS:
        _DRAFTS[key] = build_draft_model(
            "catlm_60m", True, seed, cfg_overrides=overrides or None)
    return _DRAFTS[key]


def _golden(case):
    with open(regenerate.fixture_path(case)) as f:
        return json.load(f)["tokens"]


@pytest.mark.parametrize("case", sorted(regenerate.CASES))
@pytest.mark.parametrize("k", [1, 2, 4])
def test_speculative_matches_golden_bitwise(case, k):
    """Accepted+corrected output == the target-only golden fixture for
    every quant config and draft depth (identity is structural — the
    draft only changes how many verify rows get accepted per cycle)."""
    got = regenerate.run_case(case, schedule="unified", page_size=8,
                              max_batch_tokens=12, speculative_k=k,
                              draft=_draft())
    golden = _golden(case)
    for rid, want in golden.items():
        assert got[rid] == want, (
            f"{case} k={k}: speculative tokens for rid={rid} diverged "
            f"from the target-only golden fixture")


def test_adaptive_spec_matches_golden_bitwise():
    """adaptive_spec only changes how many drafts each slot packs per
    cycle — acceptance still appends target-argmax rows only, so the
    output must stay bitwise equal to the golden fixture."""
    case = sorted(regenerate.CASES)[0]
    got = regenerate.run_case(case, schedule="unified", page_size=8,
                              max_batch_tokens=12, speculative_k=4,
                              draft=_draft(), adaptive_spec=True)
    golden = _golden(case)
    for rid, want in golden.items():
        assert got[rid] == want, (
            f"{case} adaptive: tokens for rid={rid} diverged")


@pytest.mark.parametrize("prefix_cache", [False, True],
                         ids=["prefix_off", "prefix_on"])
def test_speculative_shared_prefix_identity(prefix_cache):
    """Random shared-prefix workload: speculative output must equal the
    non-speculative unified engine's, with the prefix cache off and on —
    and with it on, every page still live after the drain must be held
    by the prefix trie (no verify-row growth may leak past a
    rejection); the draft pool, which never shares prefix pages, drains
    to zero."""
    cfg, model, params = regenerate.build_case("int8_kv")
    reqs = request_workload(cfg, 6, gen=5, lengths=(6, 10), seed=11,
                            shared_prefix=6)
    kw = dict(n_slots=2, max_len=24, schedule="unified",
              max_batch_tokens=12, page_size=8, prefix_cache=prefix_cache)
    base_eng = ServeEngine(model, params, **kw)
    base = base_eng.run(reqs)
    spec_eng = ServeEngine(model, params, speculative_k=3, draft=_draft(),
                           **kw)
    spec = spec_eng.run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(
            spec[r["rid"]].tokens, base[r["rid"]].tokens,
            err_msg=f"rid={r['rid']} prefix_cache={prefix_cache}")
    # (the two engines' pools are sized differently — spec_k pads
    # _kv_len — so absolute retention can differ via LRU eviction; what
    # must hold is that nothing BUT the trie keeps pages alive)
    trie = spec_eng.sched.prefix
    if prefix_cache:
        assert spec_eng.pool.in_use == trie.resident, \
            "pages leaked past the prefix trie after the drain"
        assert spec_eng.draft_pool.in_use == 0
    else:
        assert spec_eng.pool.in_use == 0
        assert spec_eng.draft_pool.in_use == 0


def test_speculative_tp4_token_identical():
    """tp=4 mesh on the MHA override (same convention as the unified
    mesh test): the draft always runs plain single-device jit, only the
    target verify is shard_mapped — output must equal the solo legacy
    engine's."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 local devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    from repro.models import build

    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4,
                                                 kv_quant_bits=8)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = request_workload(cfg, 5, gen=4, lengths=(6, 10), seed=3)
    solo = ServeEngine(model, params, n_slots=2, max_len=24).run(reqs)
    mesh = make_mesh((1, 4), ("data", "model"))
    spec = ServeEngine(model, params, n_slots=2, max_len=24, mesh=mesh,
                       schedule="unified", max_batch_tokens=12,
                       page_size=8, speculative_k=2,
                       draft=_draft(key="mha4", n_kv_heads=4)).run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(spec[r["rid"]].tokens,
                                      solo[r["rid"]].tokens,
                                      err_msg=f"rid={r['rid']}")


def test_speculative_kv_rewind_invariant():
    """After every step, each decoding slot's page coverage — in BOTH
    pools — equals ``pages_for(prompt + generated - 1)``, which is
    exactly what a never-drafted run holds after its own observe: the
    rejected verify rows' pages are shrunk back the same cycle they were
    grown. The workload pairs an int8 target with the int4 draft so
    rejections actually happen, and the drained pools must balance."""
    cfg, model, params = regenerate.build_case("int8_kv")
    reqs = request_workload(cfg, regenerate.N_REQUESTS,
                            gen=regenerate.GEN, lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    # a draft from a DIFFERENT seed proposes essentially random tokens,
    # guaranteeing rejections (identity and rewind are structural — the
    # draft's quality only sets the acceptance rate). The per-step
    # coverage check below is a SYNC-loop property: the pipelined loop
    # legitimately holds extra pages for the in-flight ahead plan
    # between steps (its drained-pool equality lives in
    # test_pipelined_engine.py), so pin the synchronous loop here.
    eng = ServeEngine(model, params, n_slots=2, max_len=24,
                      schedule="unified", max_batch_tokens=12, page_size=8,
                      speculative_k=2, draft=_draft(key="seed1", seed=1),
                      pipeline=False)
    for r in reqs:
        eng.submit(r["tokens"], r["max_new_tokens"], rid=r["rid"])
    sched = eng.sched
    while not eng.idle:
        eng.step()
        for slot, seq in sched.active.items():
            if not seq.decoding:
                continue
            valid = seq.prompt_len + len(seq.generated) - 1
            want = sched.tables.pages_for(valid)
            assert sched.tables.n_owned(slot) == want, (
                f"target pool coverage {sched.tables.n_owned(slot)} != "
                f"never-drafted {want} pages for slot {slot}")
            assert sched.draft_tables.n_owned(slot) == want, (
                f"draft pool coverage {sched.draft_tables.n_owned(slot)} "
                f"!= never-drafted {want} pages for slot {slot}")
    assert sched.spec_drafted > sched.spec_accepted, \
        "workload produced no rejections — the invariant went untested"
    for pool in (eng.pool, eng.draft_pool):
        assert pool.in_use == 0, "drained engine must free all pages"
        assert pool.allocs == pool.frees


def test_speculative_engine_validation():
    cfg, model, params = regenerate.build_case("fp")
    with pytest.raises(ValueError, match="schedule"):
        ServeEngine(model, params, n_slots=2, max_len=24,
                    speculative_k=2, draft=_draft())
    with pytest.raises(ValueError, match="draft"):
        ServeEngine(model, params, n_slots=2, max_len=24,
                    schedule="unified", max_batch_tokens=12,
                    speculative_k=2)
    # every running slot packs k+1 verify rows, so the budget floor
    # scales with spec_k
    with pytest.raises(ValueError, match="max_batch_tokens"):
        ServeEngine(model, params, n_slots=2, max_len=24,
                    schedule="unified", max_batch_tokens=4,
                    speculative_k=2, draft=_draft())


# --------------------------------------------------- satellite regressions


def _mini_sched(eos_id=None):
    pool = PagePool(8, 8)
    tables = SlotPageTables(pool, 2, 4)
    return TokenBudgetScheduler(2, 8, pool=pool, tables=tables,
                                eos_id=eos_id)


def _seq(generated, max_new=8):
    return SeqState(req=Request(rid=0, prompt=np.zeros(4, np.int32),
                                max_new_tokens=max_new),
                    slot=0, prefill_done=4, generated=list(generated))


def test_finished_empty_generated_with_eos():
    """Regression: ``generated[-1]`` on an empty list raised IndexError
    when an eos_id was set and a slot was consulted before its first
    token (the speculative observe path does exactly that)."""
    sched = _mini_sched(eos_id=5)
    assert sched._finished(_seq([])) is False
    assert sched._finished(_seq([], max_new=0)) is True


def test_finished_eos_none_vs_token_zero():
    """Regression: eos_id=None must never match token 0 (or any token) —
    the check is structural, not an accident of ``None == 0`` being
    False."""
    assert _mini_sched(eos_id=None)._finished(_seq([0])) is False
    assert _mini_sched(eos_id=0)._finished(_seq([0])) is True
    assert _mini_sched(eos_id=5)._finished(_seq([3, 5])) is True
    assert _mini_sched(eos_id=5)._finished(_seq([5, 3])) is False


def test_device_time_within_step_time():
    """Device-time attribution: the timed span now blocks on the step
    output (``block_until_ready`` inside the span), so device_s measures
    execution, not enqueue — and it can never exceed the enclosing
    step_s span."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, 4, gen=4, lengths=(6, 10), seed=2)
    for kw in (dict(schedule="unified", max_batch_tokens=12, page_size=8),
               dict()):
        eng = ServeEngine(model, params, n_slots=2, max_len=24, **kw)
        eng.run(reqs)
        step_s = eng.metrics["step_s"]
        dev_s = eng.metrics["device_s"]
        assert len(step_s) == len(dev_s) > 0
        for d, s in zip(dev_s, step_s):
            assert 0.0 < d <= s, f"device span {d} outside step span {s}"
        assert eng.summary()["device_ms_mean"] > 0


def test_ttft_non_negative_and_asserted():
    """TTFT is a perf_counter difference end-to-end; summary() refuses to
    report a negative one (a mixed-clock regression guard)."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, 3, gen=2, lengths=(6,), seed=0)
    eng = ServeEngine(model, params, n_slots=2, max_len=16,
                      schedule="unified", max_batch_tokens=8, page_size=8)
    res = eng.run(reqs)
    assert all(r.ttft_s >= 0 for r in res.values())
    assert eng.summary()["ttft_s_mean"] >= 0
    res[reqs[0]["rid"]].ttft_s = -1e-3
    with pytest.raises(AssertionError, match="TTFT"):
        eng.summary()
