"""Continuous-batching serve engine: scheduler invariants (FIFO admission,
no slot leaks, exactly-once retirement) and the token-equality contract —
every request decoded by the engine matches a solo static greedy_generate
run of the same model/params/max_len, bit for bit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import request_workload
from repro.launch.engine import ServeEngine
from repro.launch.serve import greedy_generate

GEN = 6
MAX_LEN = 14 + GEN + 8          # longest workload prompt + gen + slack


@pytest.fixture(scope="module")
def served(tiny_cfg):
    """Tiny model with the serving-default int8 slot KV cache."""
    from repro.models import build
    cfg = tiny_cfg.scaled(kv_quant_bits=8)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def drained(served):
    """8 mixed-length requests through 3 slots (queue deeper than slots)."""
    cfg, model, params = served
    reqs = request_workload(cfg, 8, gen=GEN, lengths=(6, 10, 14), seed=3)
    engine = ServeEngine(model, params, n_slots=3, max_len=MAX_LEN)
    results = engine.run(reqs)
    return engine, reqs, results


# ---------------------------------------------------------------- equality

def test_engine_tokens_match_solo_oracle(served, drained):
    _, model, params = served
    engine, reqs, results = drained
    assert engine.quantized_kv
    for r in reqs:
        want = np.asarray(greedy_generate(
            model, params, jnp.asarray(r["tokens"])[None], r["max_new_tokens"],
            MAX_LEN))[0]
        got = results[r["rid"]].tokens
        np.testing.assert_array_equal(got, want, err_msg=f"rid={r['rid']}")
        assert results[r["rid"]].prompt_len == len(r["tokens"])


def test_engine_fp_cache_also_matches_oracle(tiny_cfg):
    """The slot machinery is cache-dtype agnostic: fp cache path too."""
    from repro.models import build
    model = build(tiny_cfg)
    params = model.init(jax.random.PRNGKey(1))
    reqs = request_workload(tiny_cfg, 4, gen=4, lengths=(6, 10), seed=5)
    engine = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN)
    assert not engine.quantized_kv
    results = engine.run(reqs)
    for r in reqs:
        want = np.asarray(greedy_generate(
            model, params, jnp.asarray(r["tokens"])[None],
            r["max_new_tokens"], MAX_LEN))[0]
        np.testing.assert_array_equal(results[r["rid"]].tokens, want)


# --------------------------------------------------------------- scheduler

def test_no_slot_leaks_after_drain(drained):
    engine, _, _ = drained
    assert engine.idle
    assert sorted(engine._free) == list(range(engine.n_slots))
    assert not engine._active


def test_every_request_retired_exactly_once(drained):
    engine, reqs, results = drained
    admits = [e for e in engine.events if e[0] == "admit"]
    retires = [e for e in engine.events if e[0] == "retire"]
    rids = [r["rid"] for r in reqs]
    assert sorted(r[1] for r in retires) == sorted(rids)
    assert sorted(a[1] for a in admits) == sorted(rids)
    assert sorted(results) == sorted(rids)
    for rid in rids:
        assert results[rid].retire_step >= results[rid].admit_step


def test_fifo_admission_order(drained):
    engine, reqs, _ = drained
    admit_order = [e[1] for e in engine.events if e[0] == "admit"]
    assert admit_order == [r["rid"] for r in reqs]


def test_slots_reused_and_never_double_booked(drained):
    engine, _, _ = drained
    occupied = set()
    per_slot_admits = {}
    for kind, rid, slot, _step in engine.events:
        if kind == "admit":
            assert slot not in occupied, f"slot {slot} double-booked"
            occupied.add(slot)
            per_slot_admits[slot] = per_slot_admits.get(slot, 0) + 1
        else:
            occupied.remove(slot)
    assert not occupied
    # 8 requests through 3 slots forces reuse
    assert max(per_slot_admits.values()) >= 2


def test_metrics_and_backpressure(drained):
    engine, reqs, results = drained
    s = engine.summary()
    assert s["n_requests"] == len(reqs) and s["n_slots"] == 3
    assert s["tok_per_s"] > 0 and s["wall_s"] > 0
    assert 0 < s["occupancy_mean"] <= 1.0
    # queue was deeper than the slot count at the start
    assert s["queue_depth_max"] >= len(reqs) - engine.n_slots
    assert s["generated_tokens"] == sum(r["max_new_tokens"] for r in reqs)
    for r in results.values():
        assert r.ttft_s > 0


# ------------------------------------------------------------------- edges

def test_single_token_request_retires_from_prefill(served):
    _, model, params = served
    engine = ServeEngine(model, params, n_slots=2, max_len=MAX_LEN)
    rid = engine.submit(np.arange(1, 9, dtype=np.int32), 1)
    engine.step()
    assert rid in engine.results and engine.idle
    assert len(engine.results[rid].tokens) == 9
    assert engine.metrics["decode_steps"] == 0


def test_submit_rejects_overflow_empty_dup_and_zero_budget(served):
    _, model, params = served
    engine = ServeEngine(model, params, n_slots=1, max_len=16)
    with pytest.raises(ValueError):
        engine.submit(np.arange(10, dtype=np.int32), 10)
    with pytest.raises(ValueError):
        engine.submit(np.zeros((0,), np.int32), 4)
    with pytest.raises(ValueError):
        engine.submit(np.arange(4, dtype=np.int32), 0)
    engine.submit(np.arange(4, dtype=np.int32), 2, rid=7)
    with pytest.raises(ValueError):
        engine.submit(np.arange(4, dtype=np.int32), 2, rid=7)


def test_unsupported_family_rejected_up_front():
    """Per-slot position vectors are a dense-family contract; ssm/hybrid
    models must fail loudly at construction, not decode garbage."""
    from repro.configs import get_config
    from repro.models import build
    model = build(get_config("rwkv6_7b").smoke())
    with pytest.raises(NotImplementedError):
        ServeEngine(model, None, n_slots=1, max_len=16)


def test_eos_early_retirement(served):
    """With eos_id covering the whole vocab the request stops after one
    decode regardless of max_new_tokens budget."""
    _, model, params = served
    prompt = np.arange(2, 10, dtype=np.int32)
    probe = ServeEngine(model, params, n_slots=1, max_len=MAX_LEN)
    first = int(probe.run([{"rid": 0, "tokens": prompt,
                            "max_new_tokens": 1}])[0].tokens[-1])
    engine = ServeEngine(model, params, n_slots=1, max_len=MAX_LEN,
                         eos_id=first)
    out = engine.run([{"rid": 0, "tokens": prompt, "max_new_tokens": GEN}])
    assert len(out[0].tokens) == len(prompt) + 1
    assert out[0].tokens[-1] == first


def test_serve_benchmark_contract():
    """serve.py stays a thin CLI over the engine with the old contract."""
    from repro.launch.serve import serve_benchmark
    out = serve_benchmark(arch="catlm_60m", batch=2, prompt_len=8, gen=4,
                          transform="fp", kv_bits=8)
    assert out["tokens"].shape == (2, 12)
    assert out["tok_per_s"] > 0
    assert out["engine"]["n_requests"] == 2
