"""Integration tests: the full PTQ pipeline on reduced configs.

Key contracts:
  * quantized model still runs (train fwd / prefill / decode) via the same
    model code (qlinear dispatch)
  * at high bits the quantized model matches fp closely
  * at W4A4 the paper's transform ordering holds on CE degradation:
    CAT(block) <= Hadamard <= none (on average)
  * weights are stored int8 (memory claim)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.pipeline import QuantizeConfig, eval_quantized, quantize_model
from repro.core.qlinear import QLinear
from repro.data import calibration_batches, make_batch
from repro.models import build

# Full-pipeline e2e runs: minutes on CPU. `pytest -m "not slow"` skips
# them; the int4/QLinear fast coverage lives in test_int4_packed.py.
pytestmark = pytest.mark.slow


def _setup(arch, seed=0):
    cfg = get_config(arch).smoke()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    calib = list(calibration_batches(cfg, n_seqs=8, seq_len=32, batch=4))
    return cfg, model, params, calib


@pytest.mark.parametrize("arch", ["catlm_60m", "gemma2_2b", "rwkv6_7b",
                                  "zamba2_7b", "whisper_small",
                                  "granite_moe_1b_a400m", "paligemma_3b"])
def test_quantize_all_families_runs(arch):
    cfg, model, params, calib = _setup(arch)
    qcfg = QuantizeConfig(w_bits=8, a_bits=8, transform="cat",
                          cat_block=16, w_method="rtn")
    qparams = quantize_model(model, params, qcfg, calib)
    # int8 storage on at least the attention projections
    leaves = [l for l in jax.tree.leaves(
        qparams, is_leaf=lambda x: isinstance(x, QLinear))
        if isinstance(l, QLinear)]
    assert leaves, arch
    assert all(l.qweight.dtype == jnp.int8 for l in leaves)
    # quantized model still runs a full loss
    batch = {k: jnp.asarray(v)
             for k, v in make_batch(cfg, 32, 2, seed=3).items()}
    lq, _ = jax.jit(model.loss)(qparams, batch)
    assert bool(jnp.isfinite(lq)), arch


def test_w8a8_near_lossless():
    cfg, model, params, calib = _setup("catlm_60m")
    qcfg = QuantizeConfig(w_bits=8, a_bits=8, transform="hadamard")
    qparams = quantize_model(model, params, qcfg, calib)
    ev = eval_quantized(model, params, qparams,
                        [make_batch(cfg, 64, 4, seed=9)])
    assert abs(ev["delta"]) < 0.05, ev


def test_quantized_decode_runs():
    cfg, model, params, calib = _setup("catlm_60m")
    qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat", cat_block=16)
    qparams = quantize_model(model, params, qcfg, calib)
    toks = jnp.asarray(make_batch(cfg, 16, 2, seed=5)["tokens"])
    cache = model.init_cache(2, 32)
    logits, cache = model.prefill(qparams, toks, cache)
    logits, cache = model.decode(qparams, jnp.argmax(logits, -1), cache)
    assert logits.shape == (2, 1, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


def test_transform_ordering_on_ce():
    """Paper Table-1 structure: at W4A4, CAT <= Hadamard <= none on CE
    degradation (averaged over seeds)."""
    deltas = {"none": [], "hadamard": [], "cat": []}
    for seed in range(2):
        cfg, model, params, calib = _setup("catlm_60m", seed=seed)
        evalb = [make_batch(cfg, 64, 4, seed=100 + seed)]
        for tr in deltas:
            qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform=tr,
                                  cat_block=32, w_method="rtn", seed=seed)
            qp = quantize_model(model, params, qcfg, calib)
            deltas[tr].append(eval_quantized(model, params, qp, evalb)["delta"])
    none_d = np.mean(deltas["none"])
    had_d = np.mean(deltas["hadamard"])
    cat_d = np.mean(deltas["cat"])
    assert had_d <= none_d + 0.02, deltas
    assert cat_d <= had_d + 0.02, deltas


def test_gptq_pipeline_beats_rtn_at_4bit():
    """Averaged over seeds: a single tiny eval batch is noise-dominated,
    so one seed can rank the methods either way."""
    outs = {"rtn": [], "gptq": []}
    for seed in (3, 4):
        cfg, model, params, calib = _setup("catlm_60m", seed=seed)
        evalb = [make_batch(cfg, 64, 4, seed=77 + seed)]
        for m in ("rtn", "gptq"):
            # a_bits=0 isolates weight quantization
            qcfg = QuantizeConfig(w_bits=4, a_bits=0, transform="none",
                                  w_method=m)
            qp = quantize_model(model, params, qcfg, calib)
            outs[m].append(eval_quantized(model, params, qp, evalb)["delta"])
    assert np.mean(outs["gptq"]) <= np.mean(outs["rtn"]) + 0.01, outs


def test_kv_cache_quant_small_effect():
    """KV8 barely changes decode logits; config flag wires through."""
    import dataclasses
    cfg = get_config("catlm_60m").smoke()
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.asarray(make_batch(cfg, 16, 2, seed=5)["tokens"])
    cache = model.init_cache(2, 32)
    logits_fp, _ = model.prefill(params, toks, cache)

    cfg_kv = cfg.scaled(kv_quant_bits=8)
    model_kv = build(cfg_kv)
    logits_kv, _ = model_kv.prefill(params, toks, model_kv.init_cache(2, 32))
    diff = float(jnp.max(jnp.abs(logits_fp.astype(jnp.float32)
                                 - logits_kv.astype(jnp.float32))))
    base = float(jnp.max(jnp.abs(logits_fp.astype(jnp.float32)))) + 1e-6
    assert 0 < diff < 0.25 * base, (diff, base)
