"""Int8 KV-cache coverage: quantize/dequantize roundtrip error bounds,
scalar- and vector-position cache writes, and quantized-vs-fp cache decode
drift on a seeded tiny model."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import (cache_update, cache_update_quantized,
                                 quantize_kv)


def _rng(seed=0):
    return np.random.default_rng(seed)


# ------------------------------------------------------------- quantize_kv

@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("seed", [0, 7])
def test_quantize_kv_roundtrip_error_bound(bits, seed):
    """|x - deq(x)| <= scale/2 elementwise (round-to-nearest on the grid)."""
    x = jnp.asarray(_rng(seed).standard_normal((2, 5, 3, 16)), jnp.float32)
    codes, scale = quantize_kv(x, bits)
    assert codes.dtype == jnp.int8 and scale.shape == (2, 5, 3, 1)
    deq = codes.astype(jnp.float32) * scale
    err = np.abs(np.asarray(deq - x))
    bound = np.asarray(scale) / 2 + 1e-6
    assert (err <= bound).all(), (err.max(), bound.min())


def test_quantize_kv_codes_range_and_scale_grouping():
    qmax = 127
    x = jnp.asarray(_rng(3).standard_normal((1, 4, 2, 8)) * 10, jnp.float32)
    codes, scale = quantize_kv(x, 8)
    c = np.asarray(codes)
    assert c.min() >= -qmax and c.max() <= qmax  # symmetric, amax on grid
    # per-(token, head) scale: the max-|x| element of each group hits qmax
    amax_groups = np.abs(np.asarray(x)).max(axis=-1)
    np.testing.assert_allclose(np.abs(c).max(axis=-1),
                               np.where(amax_groups > 0, qmax, 0))


def test_quantize_kv_zero_input_is_safe():
    codes, scale = quantize_kv(jnp.zeros((1, 2, 1, 4)), 8)
    assert np.asarray(codes).sum() == 0
    assert np.isfinite(np.asarray(scale)).all()


# ------------------------------------------------------------ cache_update

def test_cache_update_scalar_pos_writes_expected_rows():
    r = _rng(1)
    ck = cv = jnp.zeros((2, 10, 3, 4), jnp.float32)
    k = jnp.asarray(r.standard_normal((2, 3, 3, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((2, 3, 3, 4)), jnp.float32)
    ck2, cv2 = cache_update(ck, cv, k, v, jnp.int32(5))
    np.testing.assert_array_equal(np.asarray(ck2[:, 5:8]), np.asarray(k))
    np.testing.assert_array_equal(np.asarray(cv2[:, 5:8]), np.asarray(v))
    assert not np.asarray(ck2[:, :5]).any() and not np.asarray(ck2[:, 8:]).any()


def test_cache_update_vector_pos_per_slot_rows():
    r = _rng(2)
    b, smax = 3, 12
    ck = cv = jnp.zeros((b, smax, 2, 4), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, 1, 2, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, 1, 2, 4)), jnp.float32)
    pos = jnp.asarray([0, 4, 9], jnp.int32)
    ck2, cv2 = cache_update(ck, cv, k, v, pos)
    for i, p in enumerate([0, 4, 9]):
        np.testing.assert_array_equal(np.asarray(ck2[i, p]),
                                      np.asarray(k[i, 0]))
        np.testing.assert_array_equal(np.asarray(cv2[i, p]),
                                      np.asarray(v[i, 0]))
        rest = np.delete(np.asarray(ck2[i]), p, axis=0)
        assert not rest.any()


def test_cache_update_vector_equals_scalar_when_uniform():
    r = _rng(4)
    ck = cv = jnp.zeros((2, 8, 2, 4), jnp.float32)
    k = jnp.asarray(r.standard_normal((2, 2, 2, 4)), jnp.float32)
    v = jnp.asarray(r.standard_normal((2, 2, 2, 4)), jnp.float32)
    a = cache_update(ck, cv, k, v, jnp.int32(3))
    b = cache_update(ck, cv, k, v, jnp.full((2,), 3, jnp.int32))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("vector_pos", [False, True])
def test_cache_update_quantized_position_correctness(vector_pos):
    r = _rng(5)
    b, smax, kvh, hd = 2, 9, 2, 8
    ck = cv = jnp.zeros((b, smax, kvh, hd), jnp.int8)
    cks = cvs = jnp.zeros((b, smax, kvh, 1), jnp.float32)
    k = jnp.asarray(r.standard_normal((b, 1, kvh, hd)), jnp.float32)
    v = jnp.asarray(r.standard_normal((b, 1, kvh, hd)), jnp.float32)
    pos = (jnp.asarray([2, 6], jnp.int32) if vector_pos else jnp.int32(2))
    ck2, cks2, cv2, cvs2 = cache_update_quantized(ck, cks, cv, cvs, k, v,
                                                  pos, bits=8)
    kq, ks = quantize_kv(k, 8)
    vq, vs = quantize_kv(v, 8)
    rows = [2, 6] if vector_pos else [2, 2]
    for i, p in enumerate(rows):
        np.testing.assert_array_equal(np.asarray(ck2[i, p]),
                                      np.asarray(kq[i, 0]))
        np.testing.assert_array_equal(np.asarray(cks2[i, p]),
                                      np.asarray(ks[i, 0]))
        np.testing.assert_array_equal(np.asarray(cv2[i, p]),
                                      np.asarray(vq[i, 0]))
        np.testing.assert_array_equal(np.asarray(cvs2[i, p]),
                                      np.asarray(vs[i, 0]))
        # untouched rows stay zero (codes and scales)
        assert not np.delete(np.asarray(ck2[i]), p, axis=0).any()
        assert not np.delete(np.asarray(cks2[i]), p, axis=0).any()


# ----------------------------------------------- decode drift on tiny model

@pytest.fixture(scope="module")
def drift_setup(tiny_cfg):
    from repro.models import build
    cfg_fp = tiny_cfg
    cfg_q = tiny_cfg.scaled(kv_quant_bits=8)
    model_fp, model_q = build(cfg_fp), build(cfg_q)
    # kv_quant_bits doesn't enter init: the same params drive both caches
    params = model_fp.init(jax.random.PRNGKey(11))
    return model_fp, model_q, params


def test_quantized_cache_decode_drift_bounded(drift_setup):
    """int8 KV cache tracks the fp cache: small relative logit drift over
    a prefill + a few decode steps, and mostly identical greedy tokens."""
    from repro.data import make_batch
    from repro.launch.serve import greedy_generate
    model_fp, model_q, params = drift_setup
    toks = jnp.asarray(make_batch(model_fp.cfg, 16, 2, seed=9)["tokens"])
    out = {}
    logits = {}
    for name, model in (("fp", model_fp), ("q", model_q)):
        cache = model.init_cache(2, 32)
        l, cache = jax.jit(model.prefill)(params, toks, cache)
        logits[name] = [np.asarray(l)]
        tok = jnp.argmax(l[:, -1:], axis=-1)
        dec = jax.jit(model.decode)
        for _ in range(4):
            l, cache = dec(params, tok, cache)
            logits[name].append(np.asarray(l))
            tok = jnp.argmax(l[:, -1:], axis=-1)
        out[name] = greedy_generate(model, params, toks, 8, 32)
    for lf, lq in zip(logits["fp"], logits["q"]):
        rel = np.linalg.norm(lq - lf) / np.linalg.norm(lf)
        assert np.isfinite(rel) and rel < 0.15, rel
    # 8-bit cache rarely flips the argmax on a seeded tiny model
    agree = np.mean(np.asarray(out["fp"]) == np.asarray(out["q"]))
    assert agree >= 0.75, agree


def test_quantized_cache_is_int8_and_smaller(tiny_cfg):
    from repro.models import build
    model = build(tiny_cfg.scaled(kv_quant_bits=8))
    cache = model.init_cache(2, 64)
    assert cache["k"].dtype == jnp.int8 and cache["v"].dtype == jnp.int8
    assert cache["k_scale"].shape == cache["k"].shape[:-1] + (1,)
    fp_cache = build(tiny_cfg).init_cache(2, 64)
    q_bytes = sum(np.asarray(v).nbytes for k, v in cache.items() if k != "pos")
    f_bytes = sum(np.asarray(v).nbytes for k, v in fp_cache.items()
                  if k != "pos")
    assert q_bytes < f_bytes
