"""Golden-output regression: the engine's decoded token sequences for the
seeded workload must match the checked-in fixtures bit for bit, for all
three serving configs. A kernel or engine refactor that changes decoded
tokens — even by one greedy tie-break — fails here; intentional numerics
changes regenerate via ``tests/golden/regenerate.py`` (see its docstring).
"""
import json

import pytest

from golden import regenerate


@pytest.mark.parametrize("case", sorted(regenerate.CASES))
def test_engine_output_matches_golden(case):
    path = regenerate.fixture_path(case)
    with open(path) as f:
        golden = json.load(f)
    got = regenerate.run_case(case)
    assert golden["case"] == case
    assert set(got) == set(golden["tokens"]), (
        f"request-id set drifted from fixture {path}")
    for rid, want in golden["tokens"].items():
        assert got[rid] == want, (
            f"{case}: decoded tokens for rid={rid} diverged from {path}; "
            f"if intentional, regenerate via tests/golden/regenerate.py")


def test_golden_fixtures_are_self_consistent():
    """Fixture metadata matches the generator constants, so a regen with
    edited constants can't silently shrink coverage."""
    for case in regenerate.CASES:
        with open(regenerate.fixture_path(case)) as f:
            golden = json.load(f)
        assert golden["n_requests"] == regenerate.N_REQUESTS
        assert golden["gen"] == regenerate.GEN
        assert tuple(golden["lengths"]) == regenerate.LENGTHS
        assert golden["seed"] == regenerate.SEED
        assert len(golden["tokens"]) == regenerate.N_REQUESTS
