"""Unified token-budget scheduler: end-to-end engine correctness.

The unified step packs decode tokens and prefill chunks into ONE ragged
model invocation (``launch/scheduler.py`` + ``launch/executor.py`` +
``models.dense.ragged_step``). Per-row numerics are unchanged from the
legacy dispatches, so decoded tokens must be **bitwise identical** to the
checked-in golden fixtures — across budgets (which reshuffle step packing
arbitrarily), with and without a prefill-chunk cap, and at tensor
parallelism. The ragged paged-attention kernel path is rtol-level (like
legacy ``paged_kernel``) and is pinned at >= 0.9 token agreement.
"""
import json

import numpy as np
import pytest

from golden import regenerate

from repro.data import request_workload
from repro.launch.engine import ServeEngine


def _golden(case):
    with open(regenerate.fixture_path(case)) as f:
        return json.load(f)["tokens"]


@pytest.mark.parametrize("case", sorted(regenerate.CASES))
@pytest.mark.parametrize("kw", [
    dict(max_batch_tokens=6),                     # tight: chunked admission
    dict(max_batch_tokens=64),                    # loose: whole prompts fit
    dict(max_batch_tokens=8, prefill_chunk=4),    # chunk cap on top
], ids=["budget6", "budget64", "budget8chunk4"])
def test_unified_matches_golden_bitwise(case, kw):
    got = regenerate.run_case(case, schedule="unified", page_size=8, **kw)
    golden = _golden(case)
    for rid, want in golden.items():
        assert got[rid] == want, (
            f"{case} {kw}: unified tokens for rid={rid} diverged from the "
            f"legacy golden fixture")


def test_unified_matches_golden_at_tp2():
    """tp=2 mesh (gather mode) on the exact golden config: unified-step
    output stays bitwise equal to the single-device golden fixture
    (column slices of a matmul are exact; smoke catlm's n_kv_heads=2
    caps whole-head splits at tp=2)."""
    import jax

    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 local devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count)")
    from repro.distributed.compat import make_mesh

    cfg, model, params = regenerate.build_case("fp")
    mesh = make_mesh((1, 2), ("data", "model"))
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, schedule="unified",
                      max_batch_tokens=6, mesh=mesh)
    res = eng.run(reqs)
    golden = _golden("fp")
    for r in reqs:
        assert np.asarray(res[r["rid"]].tokens).tolist() \
            == golden[str(r["rid"])], f"tp=2 diverged for rid={r['rid']}"


def test_unified_mesh_tp4_token_identical():
    """tp=4 on an MHA override (same convention as test_paged_cache):
    the unified mesh engine must be token-identical to the solo legacy
    engine. Also pins that unified rejects dp meshes loudly."""
    import jax

    if len(jax.devices()) < 4:
        pytest.skip("needs >= 4 local devices (XLA_FLAGS="
                    "--xla_force_host_platform_device_count=8)")
    from repro.configs import get_config
    from repro.distributed.compat import make_mesh
    from repro.models import build

    cfg = get_config("catlm_60m").smoke().scaled(n_kv_heads=4,
                                                 kv_quant_bits=8)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reqs = request_workload(cfg, 5, gen=4, lengths=(6, 10), seed=3)
    solo = ServeEngine(model, params, n_slots=2, max_len=24).run(reqs)
    mesh = make_mesh((1, 4), ("data", "model"))
    uni = ServeEngine(model, params, n_slots=2, max_len=24, mesh=mesh,
                      schedule="unified", max_batch_tokens=6,
                      page_size=8).run(reqs)
    for r in reqs:
        np.testing.assert_array_equal(uni[r["rid"]].tokens,
                                      solo[r["rid"]].tokens,
                                      err_msg=f"rid={r['rid']}")
    with pytest.raises(NotImplementedError, match="tensor-parallel only"):
        ServeEngine(model, params, n_slots=2, max_len=24,
                    mesh=make_mesh((2, 2), ("data", "model")),
                    schedule="unified", max_batch_tokens=6)


@pytest.mark.parametrize("kw", [
    dict(max_batch_tokens=7),
    # prefill_chunk also caps the kernel's query-block width (narrower
    # than the packed width — the inv_* maps must stay packed-wide)
    dict(max_batch_tokens=8, prefill_chunk=4, page_size=8),
], ids=["budget7", "budget8chunk4"])
def test_unified_ragged_kernel_token_agreement(kw):
    """paged_kernel=True routes the whole mixed batch through the ragged
    Pallas kernel (pages stream once per work item) — rtol-level, so pin
    agreement instead of bitwise equality."""
    cfg, model, params = regenerate.build_case("int8_kv")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, schedule="unified",
                      paged_kernel=True, **kw)
    res = eng.run(reqs)
    golden = _golden("int8_kv")
    agree = np.mean([
        (np.asarray(res[r["rid"]].tokens)
         == np.asarray(golden[str(r["rid"])])).mean() for r in reqs])
    assert agree >= 0.9, f"token agreement {agree:.2f} < 0.9"


def test_pure_decode_fast_path_engages_and_agrees():
    """Steps whose plan is pure decode (no prefill/spec/cow) must
    dispatch through ``RaggedExecutor.decode_step`` — the compact
    slot-major batch the fused decode layer wants — and the engine's
    output stays pinned against the golden fixture like the ragged
    kernel path (rtol-level kernels, so >= 0.9 agreement)."""
    cfg, model, params = regenerate.build_case("int8_kv")
    reqs = request_workload(cfg, regenerate.N_REQUESTS, gen=regenerate.GEN,
                            lengths=regenerate.LENGTHS,
                            seed=regenerate.SEED)
    eng = ServeEngine(model, params, n_slots=regenerate.N_SLOTS,
                      max_len=regenerate.MAX_LEN, schedule="unified",
                      max_batch_tokens=8, paged_kernel=True, page_size=8)
    assert eng.exec.supports_decode_step
    calls = {"n": 0}
    orig = eng.exec.decode_step

    def counted(*a, **kw):
        calls["n"] += 1
        return orig(*a, **kw)

    eng.exec.decode_step = counted
    res = eng.run(reqs)
    assert calls["n"] > 0, "pure-decode fast path never engaged"
    golden = _golden("int8_kv")
    agree = np.mean([
        (np.asarray(res[r["rid"]].tokens)
         == np.asarray(golden[str(r["rid"])])).mean() for r in reqs])
    assert agree >= 0.9, f"token agreement {agree:.2f} < 0.9"
    s = eng.summary()
    assert s["launches_per_token"] > 0
    # host dispatches can't exceed one per engine step on this path
    assert s["dispatch_per_step"] <= 1.0 + 1e-9


def test_unified_eos_and_single_token_budgets():
    """eos retirement and max_new=1 requests behave identically to
    legacy under a budget that forces multi-step prefill."""
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, 5, gen=3, lengths=(6, 10), seed=3)
    reqs[1]["max_new_tokens"] = 1
    legacy = ServeEngine(model, params, n_slots=2, max_len=24)
    lres = legacy.run(reqs)
    eos = int(lres[0].tokens[lres[0].prompt_len])   # first generated token
    for n_slots, budget in ((2, 4), (3, 16)):
        l2 = ServeEngine(model, params, n_slots=n_slots, max_len=24,
                         eos_id=eos)
        u2 = ServeEngine(model, params, n_slots=n_slots, max_len=24,
                         eos_id=eos, schedule="unified",
                         max_batch_tokens=budget, page_size=8)
        lr, ur = l2.run(reqs), u2.run(reqs)
        for r in reqs:
            assert (lr[r["rid"]].tokens == ur[r["rid"]].tokens).all(), (
                n_slots, budget, r["rid"])
    assert u2.pool.in_use == 0, "drained unified engine must free all pages"


def test_unified_summary_and_validation():
    cfg, model, params = regenerate.build_case("fp")
    reqs = request_workload(cfg, 3, gen=2, lengths=(6,), seed=0)
    eng = ServeEngine(model, params, n_slots=2, max_len=16,
                      schedule="unified", max_batch_tokens=8, page_size=8)
    eng.run(reqs)
    s = eng.summary()
    assert s["schedule"] == "unified"
    assert s["max_batch_tokens"] == 8
    assert s["packed_tokens_max"] <= 8
    assert s["itl_p95_s"] >= s["itl_p50_s"] > 0
    assert s["resident_kv_bytes_peak"] > 0
    # legacy (slot) engines report the resident footprint too
    leg = ServeEngine(model, params, n_slots=2, max_len=16)
    leg.run(reqs)
    ls = leg.summary()
    assert ls["resident_kv_bytes_mean"] == ls["kv_capacity_bytes"]
    assert ls["itl_p95_s"] > 0 and ls["schedule"] == "legacy"
    with pytest.raises(ValueError, match="max_batch_tokens"):
        ServeEngine(model, params, n_slots=4, max_len=16,
                    schedule="unified", max_batch_tokens=2)
    with pytest.raises(ValueError, match="schedule"):
        ServeEngine(model, params, n_slots=2, max_len=16,
                    schedule="sjf")
    with pytest.raises(ValueError, match="max_batch_tokens"):
        ServeEngine(model, params, n_slots=2, max_len=16,
                    max_batch_tokens=8)   # needs schedule="unified"
