"""Shared fixtures + markers for the tier-1 suite.

Expensive shared objects (tiny model + params, calibration batches,
transforms) are built once per session. The ``slow`` marker gates the
>30s end-to-end cases so ``pytest -m "not slow"`` stays fast:

    PYTHONPATH=src python -m pytest -q -m "not slow"   # ~1 min on CPU
    PYTHONPATH=src python -m pytest -q                 # everything
"""
import jax
import numpy as np
import pytest


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: >30s end-to-end case (deselect with -m 'not slow')")


# ------------------------------------------------------------ tiny model --

@pytest.fixture(scope="session")
def tiny_cfg():
    from repro.configs import get_config
    return get_config("catlm_60m").smoke()


@pytest.fixture(scope="session")
def tiny_model(tiny_cfg):
    from repro.models import build
    return build(tiny_cfg)


@pytest.fixture(scope="session")
def tiny_params(tiny_model):
    return tiny_model.init(jax.random.PRNGKey(0))


@pytest.fixture(scope="session")
def tiny_calib(tiny_cfg):
    from repro.data import calibration_batches
    return list(calibration_batches(tiny_cfg, n_seqs=4, seq_len=32, batch=2))


@pytest.fixture(scope="session")
def tiny_quantized(tiny_model, tiny_params, tiny_calib):
    """W4A4 CAT-quantized params with int4-packed weights (the serving
    default) — shared by checkpoint/serving/packing tests."""
    from repro.core.pipeline import QuantizeConfig, quantize_model
    qcfg = QuantizeConfig(w_bits=4, a_bits=4, transform="cat", cat_block=16)
    return quantize_model(tiny_model, tiny_params, qcfg, tiny_calib)


# ------------------------------------------------------------ transforms --

@pytest.fixture(scope="session")
def hadamard_transform_128():
    from repro.core import transforms as T
    return T.make_hadamard(128, np.random.default_rng(0))


@pytest.fixture(scope="session")
def cat_transform_128():
    """Block-CAT (k=32, +Hadamard) for a correlated 128-d layer."""
    from repro.core import transforms as T
    rng = np.random.default_rng(1)
    mix = rng.standard_normal((128, 128)) / np.sqrt(128)
    x = rng.standard_normal((2048, 128)) @ mix
    w = rng.standard_normal((96, 128)) / np.sqrt(128)
    sx = jax.numpy.asarray(x.T @ x / x.shape[0], jax.numpy.float32)
    sw = jax.numpy.asarray(w.T @ w, jax.numpy.float32)
    return T.make_cat_block(sw, sx, k=32, hadamard=True, rng=rng)
